//! Standalone gather microbenchmark CLI (paper Fig. 6 / Fig. 7 shapes on
//! any system profile, any sweep).
//!
//! ```sh
//! cargo run --release --offline --example microbench -- system2 65536 2052
//! ```

use ptdirect::config::SystemProfile;
use ptdirect::coordinator::microbench::{fig6_grid, run_cell};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::util::bytes::human_bytes;
use ptdirect::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ptdirect::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sys = SystemProfile::by_name(args.first().map(String::as_str).unwrap_or("system1"))
        .ok_or_else(|| anyhow::anyhow!("unknown system"))?;
    let mut rng = Rng::new(17);

    let (ns, sizes) = if args.len() >= 3 {
        (
            vec![args[1].parse::<u64>()?],
            vec![args[2].parse::<u64>()?],
        )
    } else {
        fig6_grid()
    };

    let mut t = Table::new(
        &format!("gather microbenchmark — {} ({} / {})", sys.name, sys.cpu_name, sys.gpu_name),
        &["N", "feat", "ideal", "Py", "PyD naive", "PyD opt", "Py/ideal", "PyD/ideal"],
    );
    for &n in &ns {
        for &s in &sizes {
            let c = run_cell(&sys, n, s, &mut rng);
            t.row(&[
                n.to_string(),
                human_bytes(s),
                ms(c.ideal_s),
                ms(c.py_s),
                ms(c.pyd_naive_s),
                ms(c.pyd_s),
                ratio(c.py_slowdown()),
                ratio(c.pyd_slowdown()),
            ]);
        }
    }
    t.print();
    Ok(())
}

//! Whole-stack profiling harness (DESIGN.md §7).
//!
//! Measures the L3 hot paths in isolation:
//!   1. warp request counting — production O(#warps) vs the O(#elements)
//!      reference (the simulation hot path of every bench),
//!   2. feature gather — first-touch vs steady-state (allocator + staging
//!      pool effects),
//!   3. PJRT train-step execution + input-literal assembly,
//!   4. HLO program sizes per artifact.
//!
//! ```sh
//! cargo run --release --offline --example perf_profile
//! ```

use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::coordinator::report::{ms, Table};
use ptdirect::device::warp::{count_requests, count_requests_naive_ref, WarpModel};
use ptdirect::featurestore::FeatureStore;
use ptdirect::runtime::state::{StepBatch, TrainState};
use ptdirect::runtime::{Manifest, Runtime};
use ptdirect::util::rng::Rng;
use ptdirect::util::stats::Summary;
use ptdirect::util::timer::Timer;

fn time_n<F: FnMut()>(n: u32, mut f: F) -> Summary {
    let mut s = Summary::new();
    for _ in 0..n {
        let t = Timer::start();
        f();
        s.add(t.elapsed_s());
    }
    s
}

fn main() -> anyhow::Result<()> {
    ptdirect::util::logging::init();
    let sys = SystemProfile::system1();
    let mut rng = Rng::new(0x9E4F);

    // ---- 1. request counting ----
    let idx: Vec<u32> = (0..262_144).map(|_| rng.gen_range(4_000_000) as u32).collect();
    let model = WarpModel::default();
    let mut t = Table::new(
        "1. warp request counting (256K gathers x 4 KiB rows)",
        &["impl", "median ms", "ratio"],
    );
    let fast = time_n(9, || {
        std::hint::black_box(count_requests(&idx, 1024, model, true));
    });
    let slow = time_n(3, || {
        std::hint::black_box(count_requests_naive_ref(&idx, 1024, model, true));
    });
    t.row(&["O(#warps) production".into(), ms(fast.median()), "1.00x".into()]);
    t.row(&[
        "O(#elements) reference".into(),
        ms(slow.median()),
        format!("{:.1}x slower", slow.median() / fast.median()),
    ]);
    t.print();

    // ---- 2. feature gather ----
    let store = FeatureStore::build(100_000, 602, 41, AccessMode::CpuGather, &sys, 1)?;
    let gidx: Vec<u32> = (0..2304).map(|_| rng.gen_range(100_000) as u32).collect();
    let mut out = vec![0f32; gidx.len() * 602];
    let first = {
        let t0 = Timer::start();
        store.gather_into(&gidx, &mut out)?;
        t0.elapsed_s()
    };
    let steady = time_n(20, || {
        store.gather_into(&gidx, &mut out).unwrap();
    });
    let payload = (gidx.len() * 602 * 4) as f64;
    let mut t = Table::new(
        "2. feature gather (2304 x 602 f32 rows, Py staging path)",
        &["phase", "median ms", "GB/s"],
    );
    t.row(&["first touch".into(), ms(first), format!("{:.1}", payload / first / 1e9)]);
    t.row(&[
        "steady state".into(),
        ms(steady.median()),
        format!("{:.1}", payload / steady.median() / 1e9),
    ]);
    t.print();
    println!(
        "staging pool: {} hits / {} misses; roofline = single-core memcpy\n",
        store.staging_hits(),
        store.staging_misses()
    );

    // ---- 3/4. PJRT step + artifact stats ----
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        let manifest = Manifest::load(dir)?;
        let rt = Runtime::cpu()?;
        let mut t = Table::new(
            "3. PJRT train step (B=64, fanouts 5,5)",
            &["artifact", "compile s", "assemble ms", "execute ms", "HLO instrs"],
        );
        for name in ["sage_product", "gat_product", "sage_reddit"] {
            let spec = manifest.get(name)?;
            let loaded = rt.load(dir, spec)?;
            let mut state = TrainState::init(spec, 3)?;
            let n0 = spec.layer_sizes[0];
            let mut rng2 = Rng::new(5);
            let batch = StepBatch {
                x0: (0..n0 * spec.in_dim).map(|_| rng2.gen_f32_range(-0.5, 0.5)).collect(),
                nbrs: (0..spec.fanouts.len())
                    .map(|l| {
                        (0..spec.layer_sizes[l + 1] * spec.fanouts[l])
                            .map(|_| rng2.gen_range(spec.layer_sizes[l] as u64) as i32)
                            .collect()
                    })
                    .collect(),
                masks: (0..spec.fanouts.len())
                    .map(|l| vec![1.0; spec.layer_sizes[l + 1] * spec.fanouts[l]])
                    .collect(),
                labels: (0..spec.batch)
                    .map(|_| rng2.gen_range(spec.classes as u64) as i32)
                    .collect(),
            };
            // warmup
            state.step(&loaded, &batch)?;
            let mut exec = Summary::new();
            for _ in 0..10 {
                let m = state.step(&loaded, &batch)?;
                exec.add(m.exec_s);
            }
            // assembly cost = full step wall minus reported exec
            let mut wall = Summary::new();
            for _ in 0..10 {
                let t0 = Timer::start();
                state.step(&loaded, &batch)?;
                wall.add(t0.elapsed_s());
            }
            let hlo = std::fs::read_to_string(spec.hlo_path(dir))?;
            let instrs = hlo.lines().filter(|l| l.contains(" = ")).count();
            t.row(&[
                name.into(),
                format!("{:.2}", loaded.compile_s),
                ms(wall.median() - exec.median()),
                ms(exec.median()),
                instrs.to_string(),
            ]);
        }
        t.print();
    } else {
        println!("artifacts/ missing — run `make artifacts` for sections 3/4");
    }
    Ok(())
}

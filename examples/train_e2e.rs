//! End-to-end validation driver (DESIGN.md §7).
//!
//! Trains GraphSAGE on the ogbn-products preset through the full stack —
//! RMAT graph -> fan-out sampler -> feature store -> AOT train step on the
//! PJRT runtime — for several hundred steps in both access modes, logging
//! the loss curve and the paper's headline metrics (feature-copy time
//! reduction, epoch speedup).  See DESIGN.md §7 for the experiment index.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example train_e2e
//! ```
//!
//! Env knobs: PTDIRECT_E2E_STEPS (default 300), PTDIRECT_E2E_DATASET,
//! PTDIRECT_E2E_ARCH.

use ptdirect::config::{AccessMode, RunConfig};
use ptdirect::coordinator::report::{ms, pct, ratio, Table};
use ptdirect::coordinator::Trainer;

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> anyhow::Result<()> {
    ptdirect::util::logging::init();
    let steps: u32 = env_or("PTDIRECT_E2E_STEPS", "300").parse()?;
    let dataset = env_or("PTDIRECT_E2E_DATASET", "product");
    let arch = env_or("PTDIRECT_E2E_ARCH", "sage");

    let base = RunConfig {
        dataset: dataset.clone(),
        arch: arch.clone(),
        steps_per_epoch: steps,
        scale: 256,
        feature_budget: 128 << 20,
        seed: 0xE2E,
        ..RunConfig::default()
    };

    println!("# end-to-end: {arch} on {dataset}, {steps} steps per mode\n");
    let mut table = Table::new(
        "epoch breakdown (simulated testbed = System1)",
        &[
            "mode", "sample ms", "feature copy ms", "train ms", "other ms", "epoch ms",
            "loss start", "loss end", "acc end",
        ],
    );

    let mut results = Vec::new();
    for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned] {
        let cfg = RunConfig { mode, ..base.clone() };
        let mut trainer = Trainer::new(cfg)?;
        let r = trainer.run_epoch()?;
        let b = &r.breakdown_sim;
        table.row(&[
            mode.label().into(),
            ms(b.sample_s),
            ms(b.transfer_s),
            ms(b.train_s),
            ms(b.other_s),
            ms(b.total_s()),
            format!("{:.4}", r.losses.first().copied().unwrap_or(0.0)),
            format!("{:.4}", r.final_loss()),
            format!("{:.3}", r.accs.last().copied().unwrap_or(0.0)),
        ]);

        // loss curve, decimated to ~20 points
        println!("## loss curve ({})", mode.label());
        let stride = (r.losses.len() / 20).max(1);
        for (i, chunk) in r.losses.chunks(stride).enumerate() {
            let avg: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
            println!("step {:>5}: loss {:.4}", i * stride, avg);
        }
        println!();
        results.push(r);
    }
    table.print();

    let (py, pyd) = (&results[0], &results[1]);
    let copy_reduction = 1.0 - pyd.breakdown_sim.transfer_s / py.breakdown_sim.transfer_s;
    let speedup = py.breakdown_sim.total_s() / pyd.breakdown_sim.total_s();
    println!("headline metrics (paper: 47.1% avg feature-copy reduction, up to 1.6x speedup):");
    println!("  feature-copy time reduction: {}", pct(copy_reduction));
    println!("  end-to-end epoch speedup:    {}", ratio(speedup));
    println!(
        "  power: {:.0} W (Py) -> {:.0} W (PyD), saving {}",
        py.power.watts,
        pyd.power.watts,
        pct(1.0 - pyd.power.watts / py.power.watts)
    );

    // learning sanity: both modes must actually learn, identically seeded
    for (r, label) in [(py, "Py"), (pyd, "PyD")] {
        let first = r.losses.first().copied().unwrap_or(0.0);
        let last = r.final_loss();
        assert!(
            last < first,
            "{label}: loss did not decrease ({first} -> {last})"
        );
    }
    println!("\nloss decreased in both modes — full stack verified.");
    Ok(())
}

//! Pipeline introspection: run the staged sample→gather→train executor with
//! real stages and print overlap/backpressure statistics.
//!
//! Demonstrates the streaming-orchestrator substrate on its own: the
//! sampler and feature store run on worker threads behind bounded queues,
//! and the report shows where time went and which queue throttled.
//!
//! ```sh
//! cargo run --release --offline --example pipeline_inspect -- [queue_depth]
//! ```

use std::sync::Mutex;

use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::coordinator::report::{ms, Table};
use ptdirect::featurestore::FeatureStore;
use ptdirect::graph::DatasetPreset;
use ptdirect::pipeline::executor::run_pipeline;
use ptdirect::sampler::NeighborSampler;
use ptdirect::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ptdirect::util::logging::init();
    let depth: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    let preset = DatasetPreset::by_abbv("product").unwrap();
    let sys = SystemProfile::system1();
    let graph = preset.build_graph(1024, 7)?;
    let store = FeatureStore::build(
        graph.num_nodes(),
        preset.feat_dim as usize,
        preset.classes,
        AccessMode::UnifiedAligned,
        &sys,
        7,
    )?;
    let sampler = NeighborSampler::new(&graph, &[5, 5], preset.classes);
    let n_nodes = graph.num_nodes();

    println!(
        "pipeline over {} nodes, {} edges, queue depth {depth}",
        n_nodes,
        graph.num_edges()
    );

    let rng = Mutex::new(Rng::new(1));
    let trained = Mutex::new(0u64);
    let report = run_pipeline(
        64,
        depth,
        // stage 1: sample
        |i| {
            let mut rng = rng.lock().unwrap();
            let seeds: Vec<u32> = (0..64u32)
                .map(|k| ((i * 64 + k as u64) as usize % n_nodes) as u32)
                .collect();
            Ok(sampler.sample(&seeds, &mut rng))
        },
        // stage 2: gather features
        |mb| {
            let (x0, cost) = store.gather(&mb.src_nodes)?;
            Ok((mb, x0, cost))
        },
        // stage 3: "train" (consume; artifact-free so the example is fast)
        |(_mb, x0, _cost)| {
            let _checksum: f32 = x0.iter().take(64).sum();
            *trained.lock().unwrap() += 1;
            Ok(())
        },
    )?;

    let mut t = Table::new("pipeline report", &["metric", "value"]);
    t.row(&["items".into(), report.items.to_string()]);
    t.row(&["wall ms".into(), ms(report.wall_s)]);
    t.row(&["sample busy ms".into(), ms(report.stages.sample_s)]);
    t.row(&["gather busy ms".into(), ms(report.stages.gather_s)]);
    t.row(&["train busy ms".into(), ms(report.stages.train_s)]);
    let serial = report.stages.sample_s + report.stages.gather_s + report.stages.train_s;
    t.row(&["serial sum ms".into(), ms(serial)]);
    t.row(&[
        "overlap factor".into(),
        format!("{:.2}x", serial / report.wall_s.max(1e-9)),
    ]);
    t.row(&["q1 backpressure ms".into(), ms(report.q1_push_wait_s)]);
    t.row(&["q2 backpressure ms".into(), ms(report.q2_push_wait_s)]);
    t.row(&["q1 starvation ms".into(), ms(report.q1_pop_wait_s)]);
    t.row(&["q2 starvation ms".into(), ms(report.q2_pop_wait_s)]);
    t.print();
    Ok(())
}

//! Quickstart: the unified-tensor API in 60 lines.
//!
//! Mirrors the paper's Listing 1 -> Listing 2 migration: load features,
//! move them to the `unified` device (one line), and index them from the
//! (simulated) GPU — then run a few real training steps through the AOT
//! artifact if `make artifacts` has been run.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use ptdirect::config::{AccessMode, RunConfig, SystemProfile};
use ptdirect::coordinator::Trainer;
use ptdirect::tensor::{index_select, Device, Tensor};
use ptdirect::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ptdirect::util::logging::init();
    let sys = SystemProfile::system1();
    let mut rng = Rng::new(42);

    // ---- Listing 2, line 2: features = dataload().to("unified") ----
    let features = Tensor::rand_f32(&[10_000, 256], Device::Cpu, &mut rng, -1.0, 1.0);
    let features = features.to(Device::Unified);
    assert!(features.is_unified());

    // ---- Listing 2, line 11: input_features = features[neighbor_id] ----
    let neighbor_id: Vec<u32> = (0..512).map(|_| rng.gen_range(10_000) as u32).collect();
    let (batch, report) = index_select(&features, &neighbor_id, AccessMode::UnifiedAligned, &sys)?;
    println!(
        "gathered {:?} via zero-copy: {} PCIe requests, {:.1} us simulated, zero CPU gather time",
        batch.shape(),
        report.cost.requests,
        report.cost.time_s * 1e6
    );

    // Same gather, CPU-centric baseline for comparison:
    let (_, py) = index_select(&features, &neighbor_id, AccessMode::CpuGather, &sys)?;
    println!(
        "baseline Py path: {:.1} us simulated ({:.2}x slower), {:.1} us of CPU time",
        py.cost.time_s * 1e6,
        py.cost.time_s / report.cost.time_s,
        py.cost.cpu_time_s * 1e6
    );

    // ---- mixed-device arithmetic (paper Table 1) ----
    // A GPU tensor + a CPU tensor is the classic PyTorch device-mismatch
    // error; route the bias through the unified device and it just works,
    // placed per Table 3 (GPU operand + unified-propagation -> GPU output).
    let cpu_bias = Tensor::from_f32(&vec![0.5; 512 * 256], &[512, 256], Device::Cpu)?;
    assert!(batch.add(&cpu_bias).is_err(), "cuda + cpu must fail natively");
    let uni_bias = cpu_bias.to(Device::Unified);
    let shifted = batch.add(&uni_bias)?;
    println!(
        "cuda + unified -> device={} propagated={}",
        shifted.device(),
        shifted.propagated_to_cuda()
    );

    // ---- a few real training steps through the AOT artifact ----
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let cfg = RunConfig {
            dataset: "product".into(),
            arch: "sage".into(),
            mode: AccessMode::UnifiedAligned,
            steps_per_epoch: 20,
            scale: 2048,
            feature_budget: 16 << 20,
            ..RunConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let r = trainer.run_epoch()?;
        println!(
            "20 training steps: loss {:.4} -> {:.4} (real PJRT execution)",
            r.losses.first().unwrap(),
            r.final_loss()
        );
    } else {
        println!("artifacts/ not built — run `make artifacts` for the training demo");
    }
    Ok(())
}

"""AOT compiler: lower every model variant + standalone kernels to HLO text.

This is the single build-time entry point (``make artifacts``).  It lowers

  * one fused training step per (arch, dataset) variant of paper Fig. 8,
  * one inference step per variant,
  * the standalone aligned-gather kernel (runtime microbench cross-check),

to **HLO text** — not serialized ``HloModuleProto``: jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published ``xla`` rust crate binds) rejects; the HLO text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside the ``.hlo.txt`` files it writes ``manifest.txt``, a line-oriented
description of every artifact's calling convention (input/output names,
roles, dtypes, shapes) that ``rust/src/runtime/artifact.rs`` parses.  Python
never runs again after this step.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import gather_rows, gather_rows_aligned


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dims(shape) -> str:
    return "x".join(str(d) for d in shape) if shape else "scalar"


def _dtype_tag(dt) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


class Manifest:
    def __init__(self):
        self.lines = []

    def begin(self, name, kind, cfg: M.ModelConfig | None):
        self.lines.append(f"artifact {name}")
        self.lines.append(f"file {name}.hlo.txt")
        self.lines.append(f"kind {kind}")
        if cfg is not None:
            self.lines += [
                f"arch {cfg.arch}",
                f"batch {cfg.batch}",
                f"hidden {cfg.hidden}",
                f"in_dim {cfg.in_dim}",
                f"classes {cfg.classes}",
                f"fanouts {','.join(map(str, cfg.fanouts))}",
                f"layer_sizes {','.join(map(str, cfg.layer_sizes))}",
                f"lr {cfg.lr}",
                f"momentum {cfg.momentum}",
            ]

    def io(self, direction, role, name, spec):
        self.lines.append(
            f"{direction} {role} {name} {_dtype_tag(spec.dtype)} {_dims(spec.shape)}"
        )

    def end(self):
        self.lines.append("end")

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def lower_variant(cfg: M.ModelConfig, out_dir: str, manifest: Manifest, kinds):
    names = list(M.param_shapes(cfg).keys())
    nl = cfg.num_layers

    if "train" in kinds:
        args = M.example_inputs(cfg)
        t0 = time.time()
        lowered = jax.jit(M.make_train_step(cfg)).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{cfg.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.begin(cfg.name, "train", cfg)
        np_ = len(names)
        for i, n in enumerate(names):
            manifest.io("input", "param", n, args[i])
        for i, n in enumerate(names):
            manifest.io("input", "momentum", n, args[np_ + i])
        pos = 2 * np_
        manifest.io("input", "data", "x0", args[pos])
        pos += 1
        for l in range(nl):
            manifest.io("input", "data", f"nbr{l}", args[pos + l])
        pos += nl
        for l in range(nl):
            manifest.io("input", "data", f"mask{l}", args[pos + l])
        pos += nl
        manifest.io("input", "data", "labels", args[pos])
        f32s = jax.ShapeDtypeStruct((), jnp.float32)
        manifest.io("output", "metric", "loss", f32s)
        manifest.io("output", "metric", "acc", f32s)
        for i, n in enumerate(names):
            manifest.io("output", "param", n, args[i])
        for i, n in enumerate(names):
            manifest.io("output", "momentum", n, args[np_ + i])
        manifest.end()
        print(f"  {cfg.name}: {len(text)} chars in {time.time() - t0:.1f}s")

    if "infer" in kinds:
        args = M.example_infer_inputs(cfg)
        t0 = time.time()
        lowered = jax.jit(M.make_infer_step(cfg)).lower(*args)
        text = to_hlo_text(lowered)
        name = f"{cfg.name}_infer"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.begin(name, "infer", cfg)
        for i, n in enumerate(names):
            manifest.io("input", "param", n, args[i])
        pos = len(names)
        manifest.io("input", "data", "x0", args[pos])
        pos += 1
        for l in range(nl):
            manifest.io("input", "data", f"nbr{l}", args[pos + l])
        pos += nl
        for l in range(nl):
            manifest.io("input", "data", f"mask{l}", args[pos + l])
        manifest.io(
            "output",
            "metric",
            "logits",
            jax.ShapeDtypeStruct((cfg.batch, cfg.classes), jnp.float32),
        )
        manifest.end()
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s")


GATHER_ROWS = 4096
GATHER_FEATS = 128
GATHER_BATCH = 512


def lower_gather(out_dir: str, manifest: Manifest):
    """Standalone gather kernels (naive + aligned) for runtime cross-checks."""
    feats = jax.ShapeDtypeStruct((GATHER_ROWS, GATHER_FEATS), jnp.float32)
    idx = jax.ShapeDtypeStruct((GATHER_BATCH,), jnp.int32)
    for name, fn in (
        ("gather_naive", lambda x, i: (gather_rows(x, i),)),
        ("gather_aligned", lambda x, i: (gather_rows_aligned(x, i),)),
    ):
        t0 = time.time()
        lowered = jax.jit(fn).lower(feats, idx)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.begin(name, "gather", None)
        manifest.io("input", "data", "features", feats)
        manifest.io("input", "data", "idx", idx)
        manifest.io(
            "output",
            "metric",
            "rows",
            jax.ShapeDtypeStruct((GATHER_BATCH, GATHER_FEATS), jnp.float32),
        )
        manifest.end()
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="", help="comma list; default all")
    ap.add_argument("--batch", type=int, default=M.DEFAULT_BATCH)
    ap.add_argument("--hidden", type=int, default=M.DEFAULT_HIDDEN)
    ap.add_argument(
        "--fanouts", default=",".join(map(str, M.DEFAULT_FANOUTS))
    )
    ap.add_argument("--skip-infer", action="store_true")
    ap.add_argument("--skip-gather", action="store_true")
    args = ap.parse_args(argv)

    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    variants = M.all_variants(args.batch, fanouts, args.hidden)
    if args.variants:
        keep = set(args.variants.split(","))
        variants = [v for v in variants if v.name in keep]
        missing = keep - {v.name for v in variants}
        if missing:
            print(f"unknown variants: {sorted(missing)}", file=sys.stderr)
            return 2

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = Manifest()
    kinds = {"train"} | (set() if args.skip_infer else {"infer"})
    print(f"lowering {len(variants)} variants (kinds={sorted(kinds)}) ...")
    for cfg in variants:
        lower_variant(cfg, args.out_dir, manifest, kinds)
    if not args.skip_gather:
        lower_gather(args.out_dir, manifest)
    manifest.write(os.path.join(args.out_dir, "manifest.txt"))
    print(f"manifest: {os.path.join(args.out_dir, 'manifest.txt')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

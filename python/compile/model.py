"""Layer-2: GraphSAGE and GAT block models with fused training step.

The models operate on *message-flow-graph blocks* (the shape the rust
sampler emits, mirroring DGL's mini-batch structure the paper trains with):

    layer l consumes a source feature matrix  x_l   [n_l, d_l]
    and per-destination neighbor indices      nbr_l [n_{l+1}, K_l]  (into x_l)
    with a validity mask                      msk_l [n_{l+1}, K_l]
    destinations are the prefix x_l[:n_{l+1}] (self features).

All shapes are static: ``n_l = n_{l+1} * (1 + fanout_l)`` and the sampler
pads with duplicated indices + mask 0.  The training step is one fused HLO
program: forward, softmax cross-entropy, backward (via the kernels' custom
VJPs) and an SGD-with-momentum update — rust feeds params and batch, gets
back (loss, new params, new momenta).  Nothing here runs at serve time;
``aot.py`` lowers these functions once to ``artifacts/*.hlo.txt``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import gather_rows_aligned, gat_attention, sage_mean_agg


@dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one AOT model variant."""

    name: str  # artifact name, e.g. "sage_product"
    arch: str  # "sage" | "gat"
    in_dim: int  # dataset feature width (paper Table 4 "#Feat.")
    hidden: int
    classes: int
    batch: int  # root nodes per mini-batch (= n_L)
    fanouts: Tuple[int, ...]  # per layer, input-side first
    lr: float = 0.03
    momentum: float = 0.9

    @property
    def layer_sizes(self) -> List[int]:
        """n_0 >= n_1 >= ... >= n_L = batch (node counts per block level)."""
        sizes = [self.batch]
        for f in reversed(self.fanouts):
            sizes.append(sizes[-1] * (1 + f))
        return list(reversed(sizes))

    @property
    def num_layers(self) -> int:
        return len(self.fanouts)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered (by name) parameter shape table; rust allocates from this."""
    dims = [cfg.in_dim] + [cfg.hidden] * (cfg.num_layers - 1) + [cfg.hidden]
    shapes: Dict[str, Tuple[int, ...]] = {}
    for l in range(cfg.num_layers):
        d_in, d_out = dims[l], dims[l + 1]
        if cfg.arch == "sage":
            shapes[f"l{l}_w_self"] = (d_in, d_out)
            shapes[f"l{l}_w_nbr"] = (d_in, d_out)
            shapes[f"l{l}_b"] = (d_out,)
        elif cfg.arch == "gat":
            shapes[f"l{l}_w"] = (d_in, d_out)
            shapes[f"l{l}_a_dst"] = (d_out,)
            shapes[f"l{l}_a_nbr"] = (d_out,)
            shapes[f"l{l}_b"] = (d_out,)
        else:
            raise ValueError(cfg.arch)
    shapes["out_w"] = (cfg.hidden, cfg.classes)
    shapes["out_b"] = (cfg.classes,)
    return dict(sorted(shapes.items()))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Glorot-uniform init (python-side; rust has an equivalent initializer)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            params[name] = jax.random.uniform(sub, shape, jnp.float32, -limit, limit)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def sage_layer(params, l, x_src, nbr, mask, *, final: bool):
    """GraphSAGE layer: W_self . x_self + W_nbr . mean(x_nbrs)."""
    n_dst = nbr.shape[0]
    h_nbr = sage_mean_agg(x_src, nbr, mask)  # pallas kernel
    h = x_src[:n_dst] @ params[f"l{l}_w_self"] + h_nbr @ params[f"l{l}_w_nbr"]
    h = h + params[f"l{l}_b"]
    return h if final else jax.nn.relu(h)


def gat_layer(params, l, x_src, nbr, mask, *, final: bool):
    """Single-head GAT layer with self-loop in neighbor slot 0."""
    n_dst, k = nbr.shape
    z = x_src @ params[f"l{l}_w"]  # [n_src, d_out]
    z_dst = z[:n_dst]
    z_nbr = gather_rows_aligned(z, nbr.reshape(-1)).reshape(n_dst, k, -1)
    # self-loop slot: prepend the destination itself with mask 1
    z_all = jnp.concatenate([z_dst[:, None, :], z_nbr], axis=1)
    m_all = jnp.concatenate([jnp.ones((n_dst, 1), mask.dtype), mask], axis=1)
    h = gat_attention(z_dst, z_all, params[f"l{l}_a_dst"], params[f"l{l}_a_nbr"], m_all)
    h = h + params[f"l{l}_b"]
    return h if final else jax.nn.elu(h)


def forward(cfg: ModelConfig, params, x0, nbrs, masks):
    """Block forward pass -> logits [batch, classes]."""
    layer = sage_layer if cfg.arch == "sage" else gat_layer
    h = x0
    for l in range(cfg.num_layers):
        h = layer(params, l, h, nbrs[l], masks[l], final=False)
    logits = h[: cfg.batch] @ params["out_w"] + params["out_b"]
    return logits


def loss_fn(cfg: ModelConfig, params, x0, nbrs, masks, labels):
    """Mean softmax cross-entropy over the batch roots."""
    logits = forward(cfg, params, x0, nbrs, masks)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).squeeze(1)
    return nll.mean(), logits


def accuracy(logits, labels):
    return (logits.argmax(axis=-1) == labels).mean()


# --------------------------------------------------------------------------
# Training / inference steps (AOT entry points)
# --------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig):
    """Returns train_step(params, momenta, x0, *nbrs, *masks, labels).

    Output tuple: (loss, acc, *new_params, *new_momenta) in sorted-name
    order — the exact calling convention recorded in the artifact manifest.
    """
    names = list(param_shapes(cfg).keys())

    def train_step(*flat):
        np_ = len(names)
        params = dict(zip(names, flat[:np_]))
        momenta = dict(zip(names, flat[np_ : 2 * np_]))
        pos = 2 * np_
        x0 = flat[pos]
        pos += 1
        nl = cfg.num_layers
        nbrs = list(flat[pos : pos + nl])
        pos += nl
        masks = list(flat[pos : pos + nl])
        pos += nl
        labels = flat[pos]

        def scalar_loss(p):
            loss, logits = loss_fn(cfg, p, x0, nbrs, masks, labels)
            return loss, logits

        (loss, logits), grads = jax.value_and_grad(scalar_loss, has_aux=True)(params)
        acc = accuracy(logits, labels)
        new_params, new_moms = [], []
        for n in names:
            m = cfg.momentum * momenta[n] + grads[n]
            new_moms.append(m)
            new_params.append(params[n] - cfg.lr * m)
        return (loss, acc, *new_params, *new_moms)

    return train_step


def make_infer_step(cfg: ModelConfig):
    """Returns infer_step(params, x0, *nbrs, *masks) -> (logits,)."""
    names = list(param_shapes(cfg).keys())

    def infer_step(*flat):
        np_ = len(names)
        params = dict(zip(names, flat[:np_]))
        pos = np_
        x0 = flat[pos]
        pos += 1
        nl = cfg.num_layers
        nbrs = list(flat[pos : pos + nl])
        pos += nl
        masks = list(flat[pos : pos + nl])
        return (forward(cfg, params, x0, nbrs, masks),)

    return infer_step


def example_inputs(cfg: ModelConfig):
    """ShapeDtypeStructs for train_step, in calling-convention order."""
    shapes = param_shapes(cfg)
    f32 = jnp.float32
    i32 = jnp.int32
    args = []
    for _ in range(2):  # params then momenta
        args += [jax.ShapeDtypeStruct(s, f32) for s in shapes.values()]
    sizes = cfg.layer_sizes
    args.append(jax.ShapeDtypeStruct((sizes[0], cfg.in_dim), f32))  # x0
    for l in range(cfg.num_layers):
        args.append(jax.ShapeDtypeStruct((sizes[l + 1], cfg.fanouts[l]), i32))
    for l in range(cfg.num_layers):
        args.append(jax.ShapeDtypeStruct((sizes[l + 1], cfg.fanouts[l]), f32))
    args.append(jax.ShapeDtypeStruct((cfg.batch,), i32))  # labels
    return args


def example_infer_inputs(cfg: ModelConfig):
    """ShapeDtypeStructs for infer_step."""
    full = example_inputs(cfg)
    np_ = len(param_shapes(cfg))
    return full[:np_] + full[2 * np_ : -1]


# --------------------------------------------------------------------------
# Variant registry — one entry per (model, dataset) pair of paper Fig. 8.
# Feature widths and class counts follow paper Table 4; batch/fanouts are
# scaled for the CPU testbed (documented in DESIGN.md §2).
# --------------------------------------------------------------------------

DATASET_DIMS = {
    # name: (in_dim, classes)
    "reddit": (602, 41),
    "product": (100, 47),
    "twit": (343, 64),
    "sk": (293, 64),
    "paper": (128, 172),
    "wiki": (800, 64),
}

DEFAULT_BATCH = 64
DEFAULT_FANOUTS = (5, 5)
DEFAULT_HIDDEN = 64


def all_variants(
    batch: int = DEFAULT_BATCH,
    fanouts: Tuple[int, ...] = DEFAULT_FANOUTS,
    hidden: int = DEFAULT_HIDDEN,
) -> List[ModelConfig]:
    out = []
    for arch in ("sage", "gat"):
        for ds, (in_dim, classes) in DATASET_DIMS.items():
            out.append(
                ModelConfig(
                    name=f"{arch}_{ds}",
                    arch=arch,
                    in_dim=in_dim,
                    hidden=hidden,
                    classes=classes,
                    batch=batch,
                    fanouts=fanouts,
                )
            )
    return out

"""Layer-1 Pallas kernels for the PyTorch-Direct reproduction.

Every kernel here is authored with ``jax.experimental.pallas`` and executed
under ``interpret=True`` (the CPU PJRT client cannot run Mosaic custom-calls;
see DESIGN.md §3).  Each kernel is wrapped in a ``jax.custom_vjp`` whose
backward pass is hand-written in pure jnp, because interpret-mode pallas does
not support reverse-mode autodiff.  Correctness of both directions is checked
against :mod:`compile.kernels.ref` by the pytest/hypothesis suite.
"""

from compile.kernels.gather import (
    gather_rows,
    gather_rows_aligned,
    circular_shift,
)
from compile.kernels.sage_agg import sage_mean_agg
from compile.kernels.gat_attn import gat_attention

__all__ = [
    "gather_rows",
    "gather_rows_aligned",
    "circular_shift",
    "sage_mean_agg",
    "gat_attention",
]

"""Pure-jnp reference oracles for every Layer-1 kernel.

These are the ground truth the pallas kernels are validated against (values
via ``assert_allclose``, gradients via ``jax.grad`` of these functions vs the
kernels' hand-written custom VJPs).  They are intentionally written in the
most obvious way possible — no tiling, no alignment tricks — so a reader can
audit them against the paper's equations directly.
"""

from __future__ import annotations

import jax.numpy as jnp

LEAKY_SLOPE = 0.2  # slope used by GAT's LeakyReLU (Velickovic et al., 2018)
NEG_INF = -1e9  # additive mask value for softmax over padded neighbors


def gather_rows_ref(features: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Row gather: ``out[b] = features[idx[b]]``.

    This is the semantic content of PyTorch's ``tensor[index]`` advanced
    indexing that PyTorch-Direct reimplements for unified tensors (§4.5).
    """
    return jnp.take(features, idx, axis=0)


def sage_mean_agg_ref(
    src: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked mean over sampled neighbors.

    ``src``      [S, F]   source node features
    ``nbr_idx``  [D, K]   int32 indices into ``src`` (padded entries arbitrary)
    ``nbr_mask`` [D, K]   1.0 for real neighbors, 0.0 for padding
    returns      [D, F]   mean of the real neighbors' features (0 if none)
    """
    nbrs = jnp.take(src, nbr_idx, axis=0)  # [D, K, F]
    masked = nbrs * nbr_mask[:, :, None]
    deg = jnp.maximum(nbr_mask.sum(axis=1, keepdims=True), 1.0)  # [D, 1]
    return masked.sum(axis=1) / deg


def gat_attention_ref(
    h_dst: jnp.ndarray,
    h_nbr: jnp.ndarray,
    a_dst: jnp.ndarray,
    a_nbr: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Single-head GAT neighbor attention (Velickovic et al., 2018, eq. 3).

    ``h_dst``  [D, F]     projected destination features
    ``h_nbr``  [D, K, F]  projected neighbor features (slot 0 is the self loop)
    ``a_dst``  [F]        attention vector applied to the destination
    ``a_nbr``  [F]        attention vector applied to the neighbor
    ``mask``   [D, K]     1.0 real / 0.0 padded
    returns    [D, F]     attention-weighted neighbor sum
    """
    s = h_dst @ a_dst  # [D]
    r = h_nbr @ a_nbr  # [D, K]
    pre = s[:, None] + r
    e = jnp.where(pre >= 0, pre, LEAKY_SLOPE * pre)
    e = jnp.where(mask > 0, e, NEG_INF)
    alpha = jnp.exp(e - e.max(axis=1, keepdims=True))
    alpha = alpha * mask
    alpha = alpha / jnp.maximum(alpha.sum(axis=1, keepdims=True), 1e-9)
    return (alpha[:, :, None] * h_nbr).sum(axis=1)


def circular_shift_ref(idx: jnp.ndarray, feat_width: int, cl_elems: int) -> jnp.ndarray:
    """Per-row circular-shift offsets, paper §4.5 / Fig. 5.

    Thread ``t`` of the indexing kernel serves element ``(c + s_r) % F`` of
    row ``r`` where ``c`` is the in-row thread position.  The shift aligns the
    row's access stream with the warp/cacheline grid of *global thread ids*:

        s_r = (t_begin_r - row_start_r) mod cl_elems

    with ``t_begin_r`` the global thread id of the row's first element and
    ``row_start_r = idx[r] * F`` the row's first absolute element address.
    With this choice the paper's Fig. 5 toy example (warp 4, cacheline 4
    elements, 11 features, rows [0, 2, 4]) drops from 7 to 5 PCIe requests
    for row 2 — reproduced bit-exactly in the test suite and in the rust
    simulator (``rust/src/device/warp.rs``).
    """
    f_mod = feat_width % cl_elems
    rows = jnp.arange(idx.shape[0], dtype=jnp.int32)
    t_begin = (rows % cl_elems) * f_mod
    row_start = (idx.astype(jnp.int32) % cl_elems) * f_mod
    return ((t_begin - row_start) % cl_elems).astype(jnp.int32)

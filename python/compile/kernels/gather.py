"""Pallas row-gather kernels — the paper's indexing hot-spot (§4.5).

Two variants are provided:

``gather_rows``
    The straightforward blocked gather, equivalent to PyTorch's GPU indexing
    kernel *without* knowledge of memory alignment ("PyD Naive" in Fig. 7).

``gather_rows_aligned``
    The circular-shift variant (paper Fig. 5): each row's element stream is
    rotated by ``s_r = (t_begin_r - row_start_r) mod cl`` so the memory system
    sees cacheline-aligned request windows, then the outputs are written with
    identically rotated indices so the result is bit-identical to
    ``gather_rows``.  On real hardware the rotation changes the *access
    schedule* only; under ``interpret=True`` we execute the same arithmetic so
    the schedule model in ``rust/src/device/warp.rs`` and this kernel share
    one definition of the shift.

TPU adaptation (DESIGN.md §3): the warp of the CUDA kernel becomes the VPU
lane dimension; ``CL_ELEMS = 32`` models the 128-byte GPU cacheline at 4-byte
elements and doubles as the lane-rotation width.  The batch dimension is
tiled with a BlockSpec so each grid step touches one [BLOCK_B, F] VMEM tile
of the output while the feature table stays in HBM (ANY memory space).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128-byte cacheline / 4-byte feature elements — the constant the paper's
# alignment optimization is built around (§4.5).
CL_ELEMS = 32

# Rows of the output produced per grid step.  Chosen so a tile of the widest
# evaluated feature width (16 KiB = 4096 f32) stays ≤ 2 MiB of VMEM:
# 128 rows x 4096 elems x 4 B = 2 MiB.
BLOCK_B = 128


def circular_shift(idx: jnp.ndarray, feat_width: int, cl_elems: int = CL_ELEMS):
    """Per-row shift amounts; see :func:`compile.kernels.ref.circular_shift_ref`.

    Computed mod-first so the arithmetic stays in int32 even for tables with
    billions of elements (idx * feat_width would overflow otherwise).
    """
    f_mod = feat_width % cl_elems
    rows = jnp.arange(idx.shape[0], dtype=jnp.int32)
    t_begin = (rows % cl_elems) * f_mod  # == (rows * F) mod cl, up to a mod
    row_start = (idx.astype(jnp.int32) % cl_elems) * f_mod
    return ((t_begin - row_start) % cl_elems).astype(jnp.int32)


def _gather_kernel(feat_ref, idx_ref, out_ref):
    """One grid step: gather BLOCK_B rows of the feature table."""
    out_ref[...] = jnp.take(feat_ref[...], idx_ref[...], axis=0)


def _gather_aligned_kernel(feat_ref, idx_ref, shift_ref, out_ref):
    """Circular-shift gather: rotated read, identically rotated write.

    For each row ``b`` the element served at in-row position ``c`` is
    ``(c + s_b) % F`` — both on the read side (from the feature table) and on
    the write side (into the output), so ``out[b] == feat[idx[b]]`` exactly,
    while the generated address stream starts cacheline-aligned.
    """
    f = out_ref.shape[1]
    idx = idx_ref[...]
    shift = shift_ref[...]
    cols = jnp.arange(f, dtype=jnp.int32)
    # rotated column for every (row, in-row position): [BLOCK_B, F]
    rot = (cols[None, :] + shift[:, None]) % f
    rows = jnp.take(feat_ref[...], idx, axis=0)  # HBM reads, schedule = rot
    served = jnp.take_along_axis(rows, rot, axis=1)
    # un-rotate on write-out: out[b, rot[b, c]] = served[b, c]
    out = jnp.zeros_like(rows)
    b = jnp.arange(idx.shape[0], dtype=jnp.int32)[:, None]
    out_ref[...] = out.at[b, rot].set(served)


def _pad_batch(idx: jnp.ndarray, block: int):
    b = idx.shape[0]
    pad = (-b) % block
    if pad:
        idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
    return idx, b


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def gather_rows(features: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """``out[b] = features[idx[b]]`` via a blocked pallas kernel."""
    return _gather_rows_fwd_impl(features, idx)


def _gather_rows_fwd_impl(features, idx):
    n, f = features.shape
    idx_p, b = _pad_batch(idx, BLOCK_B)
    grid = (idx_p.shape[0] // BLOCK_B,)
    out = pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),  # whole table resident
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idx_p.shape[0], f), features.dtype),
        interpret=True,
    )(features, idx_p)
    return out[:b]


def _gather_rows_fwd(features, idx):
    return _gather_rows_fwd_impl(features, idx), (features.shape, idx)


def _gather_rows_bwd(res, g):
    (shape, idx) = res
    # VJP of a gather is a scatter-add of the cotangent rows.
    df = jnp.zeros(shape, g.dtype).at[idx].add(g)
    return (df, None)


gather_rows.defvjp(_gather_rows_fwd, _gather_rows_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def gather_rows_aligned(features: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Circular-shift aligned gather; numerically identical to ``gather_rows``."""
    return _gather_rows_aligned_fwd_impl(features, idx)


def _gather_rows_aligned_fwd_impl(features, idx):
    n, f = features.shape
    idx_p, b = _pad_batch(idx, BLOCK_B)
    shift = circular_shift(idx_p, f)
    grid = (idx_p.shape[0] // BLOCK_B,)
    out = pl.pallas_call(
        _gather_aligned_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idx_p.shape[0], f), features.dtype),
        interpret=True,
    )(features, idx_p, shift)
    return out[:b]


def _gather_rows_aligned_fwd(features, idx):
    return _gather_rows_aligned_fwd_impl(features, idx), (features.shape, idx)


gather_rows_aligned.defvjp(_gather_rows_aligned_fwd, _gather_rows_bwd)

"""Pallas GraphSAGE masked-mean neighbor aggregation (Hamilton et al., 2017).

The aggregation is the compute half of the paper's motivating workload
(Fig. 1): gather the sampled neighbors' feature rows and reduce them.  The
kernel fuses the per-destination gather with the masked mean so the neighbor
tile never round-trips through HBM.

Grid: one step per BLOCK_D destination rows.  The source feature table is a
single resident block (it is the *output* of the host→device transfer the
paper optimizes; by the time this kernel runs it already sits in device
memory).  VMEM budget per step: BLOCK_D x K x F elements for the neighbor
tile; callers keep K*F ≤ 64K elements (256 KiB fp32) which bounds the tile at
8 MiB for BLOCK_D = 32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 32


def _sage_kernel(src_ref, idx_ref, mask_ref, out_ref):
    nbrs = jnp.take(src_ref[...], idx_ref[...], axis=0)  # [BLOCK_D, K, F]
    mask = mask_ref[...]
    masked = nbrs * mask[:, :, None]
    deg = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    out_ref[...] = masked.sum(axis=1) / deg


def _pad(d, block, *arrays):
    pad = (-d) % block
    if pad == 0:
        return arrays
    out = []
    for a in arrays:
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, widths))
    return tuple(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def sage_mean_agg(
    src: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked mean of ``src[nbr_idx]`` over the K axis.  See ref oracle."""
    return _sage_fwd_impl(src, nbr_idx, nbr_mask)


def _sage_fwd_impl(src, nbr_idx, nbr_mask):
    s, f = src.shape
    d, k = nbr_idx.shape
    idx_p, mask_p = _pad(d, BLOCK_D, nbr_idx, nbr_mask)
    dp = idx_p.shape[0]
    grid = (dp // BLOCK_D,)
    out = pl.pallas_call(
        _sage_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, f), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_D, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_D, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_D, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp, f), src.dtype),
        interpret=True,
    )(src, idx_p, mask_p)
    return out[:d]


def _sage_fwd(src, nbr_idx, nbr_mask):
    return _sage_fwd_impl(src, nbr_idx, nbr_mask), (src.shape, nbr_idx, nbr_mask)


def _sage_bwd(res, g):
    (src_shape, nbr_idx, nbr_mask) = res
    # out[j] = sum_k m[j,k] * src[idx[j,k]] / deg[j]
    # d src[i] += sum_{(j,k): idx=i} m[j,k]/deg[j] * g[j]
    deg = jnp.maximum(nbr_mask.sum(axis=1, keepdims=True), 1.0)  # [D,1]
    w = nbr_mask / deg  # [D,K]
    contrib = w[:, :, None] * g[:, None, :]  # [D,K,F]
    flat_idx = nbr_idx.reshape(-1)
    flat_contrib = contrib.reshape(-1, g.shape[-1])
    dsrc = jnp.zeros(src_shape, g.dtype).at[flat_idx].add(flat_contrib)
    return (dsrc, None, None)


sage_mean_agg.defvjp(_sage_fwd, _sage_bwd)

"""Pallas single-head GAT neighbor attention (Velickovic et al., 2018).

Computes, per destination node j over its K sampled neighbors (slot 0 is the
self loop by the sampler's convention):

    e[j,k]   = LeakyReLU(a_dst . h_dst[j] + a_nbr . h_nbr[j,k])
    alpha    = softmax_k(e  masked over real neighbors)
    out[j]   = sum_k alpha[j,k] * h_nbr[j,k]

The kernel tiles destinations (BLOCK_D per grid step); the [BLOCK_D, K, F]
neighbor tile lives in VMEM for the whole softmax so the attention scores are
never re-read from HBM.  The backward pass is the hand-derived softmax
attention gradient, validated against ``jax.grad`` of the ref oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import LEAKY_SLOPE, NEG_INF

BLOCK_D = 32


def _attn_forward_math(h_dst, h_nbr, a_dst, a_nbr, mask):
    """Shared forward math (used by kernel body and the VJP residuals)."""
    s = h_dst @ a_dst  # [D]
    r = h_nbr @ a_nbr  # [D, K]
    pre = s[:, None] + r
    e = jnp.where(pre >= 0, pre, LEAKY_SLOPE * pre)
    e = jnp.where(mask > 0, e, NEG_INF)
    alpha = jnp.exp(e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True)))
    alpha = alpha * mask
    alpha = alpha / jnp.maximum(alpha.sum(axis=1, keepdims=True), 1e-9)
    out = (alpha[:, :, None] * h_nbr).sum(axis=1)
    return out, alpha, pre


def _gat_kernel(hd_ref, hn_ref, ad_ref, an_ref, mask_ref, out_ref):
    out, _, _ = _attn_forward_math(
        hd_ref[...], hn_ref[...], ad_ref[...], an_ref[...], mask_ref[...]
    )
    out_ref[...] = out


def _pad(d, block, *arrays):
    pad = (-d) % block
    if pad == 0:
        return arrays
    out = []
    for a in arrays:
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, widths))
    return tuple(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def gat_attention(h_dst, h_nbr, a_dst, a_nbr, mask):
    """Masked single-head GAT attention; see module docstring."""
    return _gat_fwd_impl(h_dst, h_nbr, a_dst, a_nbr, mask)


def _gat_fwd_impl(h_dst, h_nbr, a_dst, a_nbr, mask):
    d, f = h_dst.shape
    k = h_nbr.shape[1]
    hd, hn, m = _pad(d, BLOCK_D, h_dst, h_nbr, mask)
    dp = hd.shape[0]
    grid = (dp // BLOCK_D,)
    out = pl.pallas_call(
        _gat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_D, f), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_D, k, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_D, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_D, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp, f), h_dst.dtype),
        interpret=True,
    )(hd, hn, a_dst, a_nbr, m)
    return out[:d]


def _gat_fwd(h_dst, h_nbr, a_dst, a_nbr, mask):
    out = _gat_fwd_impl(h_dst, h_nbr, a_dst, a_nbr, mask)
    return out, (h_dst, h_nbr, a_dst, a_nbr, mask)


def _gat_bwd(res, g):
    h_dst, h_nbr, a_dst, a_nbr, mask = res
    _, alpha, pre = _attn_forward_math(h_dst, h_nbr, a_dst, a_nbr, mask)

    # d out / d alpha and the softmax Jacobian.
    d_alpha = jnp.einsum("df,dkf->dk", g, h_nbr)
    inner = (alpha * d_alpha).sum(axis=1, keepdims=True)
    d_e = alpha * (d_alpha - inner)
    # LeakyReLU' and the padding mask (masked slots carry no gradient).
    lrelu_grad = jnp.where(pre >= 0, 1.0, LEAKY_SLOPE)
    d_pre = d_e * lrelu_grad * mask

    d_s = d_pre.sum(axis=1)  # [D]
    d_h_dst = d_s[:, None] * a_dst[None, :]
    d_a_dst = d_s @ h_dst
    d_h_nbr = alpha[:, :, None] * g[:, None, :] + d_pre[:, :, None] * a_nbr[None, None, :]
    d_a_nbr = jnp.einsum("dk,dkf->f", d_pre, h_nbr)
    return (d_h_dst, d_h_nbr, d_a_dst, d_a_nbr, None)


gat_attention.defvjp(_gat_fwd, _gat_bwd)

"""PCIe request-coalescing model shared with the rust simulator.

This module is the *specification* of how the simulated GPU turns an
irregular gather into PCIe read requests; ``rust/src/device/warp.rs``
implements the identical model in O(#cachelines) and the cross-language
fixture test (``python/tests/test_coalesce.py`` +
``rust/tests/coalesce_fixture.rs``) pins both to the same numbers, including
the paper's Fig. 5 toy example (7 -> 5 requests for row 2).

Model (Min et al. 2020, EMOGI; paper §4.5): threads are assigned
contiguously over the flattened (row, feature) access sequence; each warp of
``warp`` threads issues one PCIe read request per *distinct cacheline*
touched by its threads.  The circular-shift optimization rotates each row's
in-row access order by

    s_r = (t_begin_r - row_start_r) mod cl

so the row's stream lines up with the warp/cacheline grid of global thread
ids (see kernels/gather.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

# Real-hardware constants: 32-thread warps, 128-byte cachelines, 4-byte feats.
WARP = 32
CACHELINE_BYTES = 128


@dataclass(frozen=True)
class GatherTraffic:
    """Request statistics for one gather."""

    requests: int  # total PCIe read requests
    cachelines: int  # distinct cachelines touched (lower bound on requests)
    bytes_moved: int  # requests * cacheline_bytes (I/O amplification incl.)
    useful_bytes: int  # rows * feat_bytes actually consumed


def element_stream(
    idx: Sequence[int], feat_elems: int, cl_elems: int, shifted: bool
) -> Iterable[int]:
    """Absolute element addresses in thread order, optionally circular-shifted."""
    t_begin = 0
    for r in idx:
        start = r * feat_elems
        s = ((t_begin - start) % cl_elems) if shifted else 0
        for c in range(feat_elems):
            yield start + ((c + s) % feat_elems)
        t_begin += feat_elems


def count_requests(
    idx: Sequence[int],
    feat_elems: int,
    *,
    warp: int = WARP,
    cl_elems: int = CACHELINE_BYTES // 4,
    shifted: bool = False,
) -> GatherTraffic:
    """Count per-warp distinct-cacheline requests for a gather."""
    requests = 0
    all_lines = set()
    warp_lines: set = set()
    n_in_warp = 0
    for addr in element_stream(idx, feat_elems, cl_elems, shifted):
        warp_lines.add(addr // cl_elems)
        all_lines.add(addr // cl_elems)
        n_in_warp += 1
        if n_in_warp == warp:
            requests += len(warp_lines)
            warp_lines = set()
            n_in_warp = 0
    if n_in_warp:
        requests += len(warp_lines)
    cl_bytes = cl_elems * 4
    return GatherTraffic(
        requests=requests,
        cachelines=len(all_lines),
        bytes_moved=requests * cl_bytes,
        useful_bytes=len(idx) * feat_elems * 4,
    )


def per_row_requests(
    idx: Sequence[int],
    feat_elems: int,
    *,
    warp: int = WARP,
    cl_elems: int = CACHELINE_BYTES // 4,
    shifted: bool = False,
) -> List[int]:
    """Requests attributed per gathered row (a warp request touching rows
    a and b counts once for each — matches the paper's Fig. 5 narration
    which counts the requests servicing row 2)."""
    counts = [0] * len(idx)
    # (addr, row) pairs in thread order
    pairs: List[Tuple[int, int]] = []
    t_begin = 0
    for rpos, r in enumerate(idx):
        start = r * feat_elems
        s = ((t_begin - start) % cl_elems) if shifted else 0
        for c in range(feat_elems):
            pairs.append((start + ((c + s) % feat_elems), rpos))
        t_begin += feat_elems
    for w in range(0, len(pairs), warp):
        by_row = {}
        for addr, rpos in pairs[w : w + warp]:
            by_row.setdefault(rpos, set()).add(addr // cl_elems)
        for rpos, lines in by_row.items():
            counts[rpos] += len(lines)
    return counts

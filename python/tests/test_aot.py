"""AOT pipeline: HLO text is emitted, parseable, and manifest-consistent."""

import os
import subprocess
import sys

import pytest

from compile import model as M
from compile.aot import Manifest, to_hlo_text, lower_variant

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")


def _small_cfg(arch="sage"):
    return M.ModelConfig(
        name=f"{arch}_tiny",
        arch=arch,
        in_dim=12,
        hidden=8,
        classes=5,
        batch=4,
        fanouts=(2, 2),
    )


def test_hlo_text_has_entry_computation():
    cfg = _small_cfg()
    lowered = jax.jit(M.make_train_step(cfg)).lower(*M.example_inputs(cfg))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text


def test_hlo_text_ids_are_reassignable():
    """The text must parse back through xla_client (same parser family as
    HloModuleProto::from_text_file on the rust side)."""
    cfg = _small_cfg("gat")
    lowered = jax.jit(M.make_infer_step(cfg)).lower(*M.example_infer_inputs(cfg))
    text = to_hlo_text(lowered)
    # round-trip sanity: parameter count shows up in the entry signature
    n_inputs = len(M.example_infer_inputs(cfg))
    assert text.count("parameter(") >= n_inputs


def test_manifest_roundtrip(tmp_path):
    cfg = _small_cfg()
    man = Manifest()
    lower_variant(cfg, str(tmp_path), man, kinds={"train"})
    man.write(tmp_path / "manifest.txt")
    lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert lines[0] == "artifact sage_tiny"
    assert "end" in lines
    n_params = len(M.param_shapes(cfg))
    inputs = [l for l in lines if l.startswith("input ")]
    outputs = [l for l in lines if l.startswith("output ")]
    # params + momenta + x0 + 2 nbrs + 2 masks + labels
    assert len(inputs) == 2 * n_params + 1 + 2 * 2 + 1
    # loss + acc + params + momenta
    assert len(outputs) == 2 + 2 * n_params
    assert (tmp_path / "sage_tiny.hlo.txt").exists()


def test_manifest_dims_format():
    man = Manifest()
    man.begin("x", "train", None)
    man.io("input", "data", "s", jax.ShapeDtypeStruct((), jnp.float32))
    man.io("input", "data", "v", jax.ShapeDtypeStruct((3, 4), jnp.int32))
    man.end()
    assert "input data s f32 scalar" in man.lines
    assert "input data v i32 3x4" in man.lines


def test_cli_unknown_variant_errors():
    from compile.aot import main

    assert main(["--out-dir", "/tmp/nowhere_aot", "--variants", "nope"]) == 2

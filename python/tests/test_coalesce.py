"""PCIe coalescing model: paper Fig. 5 fixture + invariants.

The same numbers are pinned on the rust side (rust/tests/coalesce_fixture.rs)
so the python specification and the rust implementation cannot drift apart.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.coalesce import count_requests, per_row_requests

# Paper Fig. 4/5 toy scaling: warp 32/8 = 4 threads, cacheline 128/8 = 16 B
# = 4 elements; 11 features per node; gather rows 0, 2, 4.
FIG5 = dict(idx=[0, 2, 4], feat_elems=11, warp=4, cl_elems=4)


def test_fig5_row2_seven_to_five():
    """The paper's headline toy numbers: row 2 takes 7 requests naive, 5 shifted."""
    naive = per_row_requests(shifted=False, **FIG5)
    opt = per_row_requests(shifted=True, **FIG5)
    assert naive[1] == 7
    assert opt[1] == 5


def test_fig5_totals():
    naive = count_requests(shifted=False, **FIG5)
    opt = count_requests(shifted=True, **FIG5)
    assert naive.requests == 16
    assert opt.requests == 13
    assert opt.requests < naive.requests


def test_aligned_width_shift_is_noop():
    """F a multiple of the cacheline -> shift never changes anything."""
    kw = dict(idx=[5, 1, 9, 3], feat_elems=64, warp=32, cl_elems=32)
    assert count_requests(shifted=False, **kw) == count_requests(shifted=True, **kw)


def test_misaligned_2052B_features_real_constants():
    """Fig. 7's worst case: 2052-byte rows (513 f32) at warp 32 / 128 B lines.

    Naive accesses straddle lines (~2 requests per warp); the shift restores
    ~1 per interior warp, giving the paper's ~1.9x request reduction.
    """
    idx = list(np.random.default_rng(0).integers(0, 4_000_000, size=64))
    naive = count_requests(idx, 513)
    opt = count_requests(idx, 513, shifted=True)
    ratio = naive.requests / opt.requests
    assert 1.6 < ratio <= 2.0, ratio


def test_io_amplification_accounting():
    t = count_requests([0, 2], 11, warp=4, cl_elems=4)
    assert t.useful_bytes == 2 * 11 * 4
    assert t.bytes_moved == t.requests * 16
    assert t.bytes_moved >= t.useful_bytes


@settings(max_examples=30, deadline=None)
@given(
    idx=st.lists(st.integers(0, 5000), min_size=1, max_size=40),
    mult=st.integers(2, 12),
    extra=st.integers(0, 31),
    cl=st.sampled_from([4, 8, 16, 32]),
)
def test_shift_never_increases_requests_when_gate_passes(idx, mult, extra, cl):
    """The rust kernel gate (WarpModel::shift_applies) requires f >= 2*cl;
    within that regime the shift never increases requests.  (For
    cl <= f < 2*cl the wrap segment can fragment accesses — that is exactly
    why the gate exists; see test below.)"""
    f = cl * mult + (extra % cl)
    naive = count_requests(idx, f, warp=cl, cl_elems=cl)
    opt = count_requests(idx, f, warp=cl, cl_elems=cl, shifted=True)
    assert opt.requests <= naive.requests
    assert opt.cachelines == naive.cachelines  # same data touched


def test_shift_can_fragment_short_rows():
    """Documents the f < 2*cl fragmentation that motivates the gate."""
    import random

    random.seed(0)
    violated = False
    for _ in range(200):
        idx = [random.randint(0, 3000) for _ in range(random.randint(4, 30))]
        f = random.randint(17, 31)  # cl=16: between cl and 2*cl
        a = count_requests(idx, f, warp=16, cl_elems=16).requests
        b = count_requests(idx, f, warp=16, cl_elems=16, shifted=True).requests
        if b > a:
            violated = True
            break
    assert violated, "expected at least one fragmentation case below the gate"


@settings(max_examples=30, deadline=None)
@given(
    idx=st.lists(st.integers(0, 5000), min_size=1, max_size=30),
    f=st.integers(1, 100),
)
def test_requests_bounded_by_cachelines_and_threads(idx, f):
    t = count_requests(idx, f)
    assert t.requests >= t.cachelines
    assert t.requests <= len(idx) * f  # at most one request per element

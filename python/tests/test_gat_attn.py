"""GAT attention kernel vs oracle: values + the hand-derived softmax VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import gat_attention
from compile.kernels.ref import gat_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _mk(d, k, f, seed, mask_p=0.8):
    rng = np.random.default_rng(seed)
    h_dst = jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
    h_nbr = jnp.asarray(rng.standard_normal((d, k, f)), jnp.float32)
    a_dst = jnp.asarray(rng.standard_normal(f), jnp.float32)
    a_nbr = jnp.asarray(rng.standard_normal(f), jnp.float32)
    mask = np.asarray((rng.random((d, k)) < mask_p), np.float32)
    mask[:, 0] = 1.0  # sampler convention: self-loop slot always valid
    return h_dst, h_nbr, a_dst, a_nbr, jnp.asarray(mask)


@pytest.mark.parametrize("d,k,f", [(4, 3, 5), (32, 6, 16), (50, 11, 8)])
def test_values_match_ref(d, k, f):
    args = _mk(d, k, f, 0)
    assert_allclose(
        np.asarray(gat_attention(*args)),
        np.asarray(gat_attention_ref(*args)),
        rtol=1e-5,
        atol=1e-6,
    )


def test_attention_weights_are_convex():
    """With all-equal neighbor features the output equals that feature."""
    d, k, f = 8, 4, 6
    h_dst, _, a_dst, a_nbr, mask = _mk(d, k, f, 1)
    row = jnp.asarray(np.random.default_rng(2).standard_normal(f), jnp.float32)
    h_nbr = jnp.broadcast_to(row, (d, k, f))
    out = np.asarray(gat_attention(h_dst, h_nbr, a_dst, a_nbr, mask))
    assert_allclose(out, np.broadcast_to(np.asarray(row), (d, f)), rtol=1e-5)


@pytest.mark.parametrize("argnum", [0, 1, 2, 3])
def test_grads_match_ref(argnum):
    args = _mk(16, 5, 7, 3)
    w = jnp.asarray(np.random.default_rng(4).standard_normal((16, 7)), jnp.float32)

    def lk(x):
        a = list(args)
        a[argnum] = x
        return (gat_attention(*a) * w).sum()

    def lr(x):
        a = list(args)
        a[argnum] = x
        return (gat_attention_ref(*a) * w).sum()

    g_k = jax.grad(lk)(args[argnum])
    g_r = jax.grad(lr)(args[argnum])
    assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(1, 40),
    k=st.integers(1, 8),
    f=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(d, k, f, seed):
    args = _mk(d, k, f, seed)
    assert_allclose(
        np.asarray(gat_attention(*args)),
        np.asarray(gat_attention_ref(*args)),
        rtol=1e-4,
        atol=1e-5,
    )

"""Model-level tests: shapes, loss decrease, train-step calling convention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = dict(batch=8, fanouts=(3, 3), hidden=16)


def _cfg(arch, ds="product"):
    in_dim, classes = M.DATASET_DIMS[ds]
    return M.ModelConfig(
        name=f"{arch}_{ds}",
        arch=arch,
        in_dim=in_dim,
        hidden=SMALL["hidden"],
        classes=classes,
        batch=SMALL["batch"],
        fanouts=SMALL["fanouts"],
        lr=0.05,
    )


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    sizes = cfg.layer_sizes
    x0 = jnp.asarray(rng.standard_normal((sizes[0], cfg.in_dim)) * 0.3, jnp.float32)
    nbrs, masks = [], []
    for l in range(cfg.num_layers):
        nbrs.append(
            jnp.asarray(rng.integers(0, sizes[l], size=(sizes[l + 1], cfg.fanouts[l])), jnp.int32)
        )
        masks.append(jnp.ones((sizes[l + 1], cfg.fanouts[l]), jnp.float32))
    labels = jnp.asarray(rng.integers(0, cfg.classes, size=cfg.batch), jnp.int32)
    return x0, nbrs, masks, labels


def test_layer_sizes():
    cfg = _cfg("sage")
    # batch 8, fanouts (3,3): n2=8, n1=8*4=32, n0=32*4=128
    assert cfg.layer_sizes == [128, 32, 8]


@pytest.mark.parametrize("arch", ["sage", "gat"])
def test_forward_shape(arch):
    cfg = _cfg(arch)
    params = M.init_params(cfg)
    x0, nbrs, masks, _ = _batch(cfg)
    logits = M.forward(cfg, params, x0, nbrs, masks)
    assert logits.shape == (cfg.batch, cfg.classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["sage", "gat"])
def test_loss_decreases_over_steps(arch):
    """Real learning signal: fitting a fixed batch must reduce the loss."""
    cfg = _cfg(arch)
    params = M.init_params(cfg, seed=1)
    momenta = {k: jnp.zeros_like(v) for k, v in params.items()}
    x0, nbrs, masks, labels = _batch(cfg, seed=1)
    step = jax.jit(M.make_train_step(cfg))
    names = list(M.param_shapes(cfg).keys())

    losses = []
    for _ in range(25):
        flat = [params[n] for n in names] + [momenta[n] for n in names]
        flat += [x0, *nbrs, *masks, labels]
        out = step(*flat)
        loss = float(out[0])
        losses.append(loss)
        new_p = out[2 : 2 + len(names)]
        new_m = out[2 + len(names) : 2 + 2 * len(names)]
        params = dict(zip(names, new_p))
        momenta = dict(zip(names, new_m))
    assert losses[-1] < losses[0] * 0.8, losses


def test_train_step_output_arity():
    cfg = _cfg("sage")
    names = list(M.param_shapes(cfg).keys())
    args = M.example_inputs(cfg)
    vals = [jnp.zeros(a.shape, a.dtype) for a in args]
    out = M.make_train_step(cfg)(*vals)
    assert len(out) == 2 + 2 * len(names)


def test_example_inputs_cover_calling_convention():
    cfg = _cfg("gat")
    args = M.example_inputs(cfg)
    n_params = len(M.param_shapes(cfg))
    # params + momenta + x0 + nbrs + masks + labels
    assert len(args) == 2 * n_params + 1 + 2 * cfg.num_layers + 1
    assert args[2 * n_params].shape == (cfg.layer_sizes[0], cfg.in_dim)


def test_infer_matches_forward():
    cfg = _cfg("sage")
    params = M.init_params(cfg, seed=2)
    names = list(M.param_shapes(cfg).keys())
    x0, nbrs, masks, _ = _batch(cfg, seed=2)
    (logits,) = M.make_infer_step(cfg)(*[params[n] for n in names], x0, *nbrs, *masks)
    want = M.forward(cfg, params, x0, nbrs, masks)
    assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-6)


def test_all_variants_registry():
    vs = M.all_variants()
    assert len(vs) == 12  # 2 archs x 6 datasets (paper Fig. 8)
    assert {v.arch for v in vs} == {"sage", "gat"}
    reddit = next(v for v in vs if v.name == "sage_reddit")
    assert reddit.in_dim == 602  # paper Table 4

"""Gather kernels vs the pure-jnp oracle: values, gradients, alignment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import circular_shift, gather_rows, gather_rows_aligned
from compile.kernels.ref import circular_shift_ref, gather_rows_ref

jax.config.update("jax_platform_name", "cpu")


def _mk(n, f, b, seed):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)
    return feats, idx


@pytest.mark.parametrize("kernel", [gather_rows, gather_rows_aligned])
@pytest.mark.parametrize(
    "n,f,b",
    [(16, 4, 8), (100, 11, 33), (128, 32, 128), (257, 7, 130), (64, 129, 5)],
)
def test_gather_matches_ref(kernel, n, f, b):
    feats, idx = _mk(n, f, b, 0)
    assert_allclose(np.asarray(kernel(feats, idx)), np.asarray(gather_rows_ref(feats, idx)))


@pytest.mark.parametrize("kernel", [gather_rows, gather_rows_aligned])
def test_gather_grad_is_scatter_add(kernel):
    feats, idx = _mk(50, 9, 40, 1)
    w = jnp.asarray(np.random.default_rng(2).standard_normal((40, 9)), jnp.float32)

    def loss_k(x):
        return (kernel(x, idx) * w).sum()

    def loss_r(x):
        return (gather_rows_ref(x, idx) * w).sum()

    assert_allclose(
        np.asarray(jax.grad(loss_k)(feats)),
        np.asarray(jax.grad(loss_r)(feats)),
        rtol=1e-6,
    )


def test_aligned_equals_naive_exactly():
    """The circular shift must be a pure schedule change: bit-identical output."""
    feats, idx = _mk(300, 513, 190, 3)
    a = np.asarray(gather_rows(feats, idx))
    b = np.asarray(gather_rows_aligned(feats, idx))
    assert (a == b).all()


def test_circular_shift_matches_ref():
    idx = jnp.asarray([0, 2, 4, 7, 100], jnp.int32)
    got = np.asarray(circular_shift(idx, 11, 4))
    want = np.asarray(circular_shift_ref(idx, 11, 4))
    assert (got == want).all()


def test_circular_shift_fig5_offsets():
    """Paper Fig. 5: rows [0,2,4], F=11, cacheline 4 elems -> row 2 shifts by 1."""
    idx = jnp.asarray([0, 2, 4], jnp.int32)
    s = np.asarray(circular_shift(idx, 11, 4))
    # row0: t_begin 0, start 0 -> 0; row2: (11 - 22) % 4 = 1; row4: (22 - 44) % 4 = 2
    assert s.tolist() == [0, 1, 2]


def test_circular_shift_zero_when_aligned():
    """Rows whose width is a multiple of the cacheline never need shifting."""
    idx = jnp.asarray([0, 3, 9, 17], jnp.int32)
    s = np.asarray(circular_shift(idx, 128, 32))
    assert (s == 0).all()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 80),
    f=st.integers(1, 70),
    b=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_hypothesis_sweep(n, f, b, seed):
    feats, idx = _mk(n, f, b, seed)
    got = np.asarray(gather_rows_aligned(feats, idx))
    want = np.asarray(gather_rows_ref(feats, idx))
    assert_allclose(got, want)


@settings(max_examples=15, deadline=None)
@given(f=st.integers(1, 200), cl=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 10**6))
def test_shift_bounds(f, cl, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 10_000, size=17), jnp.int32)
    s = np.asarray(circular_shift(idx, f, cl))
    assert ((0 <= s) & (s < cl)).all()

"""SAGE masked-mean aggregation kernel vs oracle: values + hand-written VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import sage_mean_agg
from compile.kernels.ref import sage_mean_agg_ref

jax.config.update("jax_platform_name", "cpu")


def _mk(s, f, d, k, seed, mask_p=0.7):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.standard_normal((s, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, s, size=(d, k)), jnp.int32)
    mask = jnp.asarray((rng.random((d, k)) < mask_p).astype(np.float32))
    return src, idx, mask


@pytest.mark.parametrize("s,f,d,k", [(10, 3, 4, 2), (64, 16, 32, 5), (100, 7, 77, 10)])
def test_values_match_ref(s, f, d, k):
    src, idx, mask = _mk(s, f, d, k, 0)
    assert_allclose(
        np.asarray(sage_mean_agg(src, idx, mask)),
        np.asarray(sage_mean_agg_ref(src, idx, mask)),
        rtol=1e-6,
    )


def test_all_masked_row_is_zero():
    src, idx, mask = _mk(20, 4, 6, 3, 1)
    mask = mask.at[2].set(0.0)
    out = np.asarray(sage_mean_agg(src, idx, mask))
    assert_allclose(out[2], np.zeros(4))


def test_grad_matches_ref():
    src, idx, mask = _mk(40, 6, 25, 4, 2)
    w = jnp.asarray(np.random.default_rng(3).standard_normal((25, 6)), jnp.float32)

    g_k = jax.grad(lambda x: (sage_mean_agg(x, idx, mask) * w).sum())(src)
    g_r = jax.grad(lambda x: (sage_mean_agg_ref(x, idx, mask) * w).sum())(src)
    assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-5, atol=1e-6)


def test_duplicate_neighbors_accumulate():
    """Same source row sampled twice contributes twice (paper: no dedup)."""
    src = jnp.asarray([[1.0, 2.0], [10.0, 20.0]], jnp.float32)
    idx = jnp.asarray([[1, 1]], jnp.int32)
    mask = jnp.ones((1, 2), jnp.float32)
    assert_allclose(np.asarray(sage_mean_agg(src, idx, mask)), [[10.0, 20.0]])


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(2, 50),
    f=st.integers(1, 20),
    d=st.integers(1, 70),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(s, f, d, k, seed):
    src, idx, mask = _mk(s, f, d, k, seed)
    assert_allclose(
        np.asarray(sage_mean_agg(src, idx, mask)),
        np.asarray(sage_mean_agg_ref(src, idx, mask)),
        rtol=1e-5,
        atol=1e-6,
    )

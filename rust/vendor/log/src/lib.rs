//! Minimal vendored stand-in for the `log` crate (the offline build has no
//! registry access).  Implements the subset ptdirect uses: the five level
//! macros, `Level`/`LevelFilter`, the `Log` trait, `set_boxed_logger`,
//! `set_max_level`, and `max_level`.  Semantics match the real facade for
//! that subset; anything else is intentionally absent.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (adds `Off` below `Error`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of an in-flight record (level + target).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait: something that consumes records.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata<'_>) -> bool {
        false
    }

    fn log(&self, _: &Record<'_>) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger (a no-op logger before installation).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(boxed) => &**boxed,
        None => &NOP,
    }
}

#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    logger().log(&record);
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+))
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+))
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+))
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+))
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_like_the_real_facade() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(Level::Error <= LevelFilter::Trace);
    }

    #[test]
    fn macros_are_safe_without_a_logger() {
        set_max_level(LevelFilter::Trace);
        info!("no logger installed: {}", 42);
        set_max_level(LevelFilter::Off);
    }
}

//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real crate links `libxla_extension`, which is not present in this
//! build environment.  This stub keeps the exact API surface ptdirect's
//! runtime layer compiles against:
//!
//! * [`Literal`] is fully functional (typed shape + byte storage, round
//!   trips through `to_vec`/`get_first_element`), so training state can be
//!   constructed and inspected without PJRT.
//! * [`PjRtClient::cpu`] and everything downstream of it return a clear
//!   "PJRT unavailable" error, which callers surface verbatim; the
//!   coordinator's native backend (see ptdirect::runtime::native) is the
//!   execution path that works everywhere.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type: a message, plus `Display`/`Error` impls so it threads
/// through the host crate's error conversions unchanged.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(s: impl Into<String>) -> Error {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is not available in this build (xla is a stub; use the native backend)"
    ))
}

/// Element types ptdirect materializes literals for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn size_of(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// A typed, shaped value — the one piece of the real crate that works fully
/// in the stub (host-memory storage, no device involved).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if numel * ty.size_of() != data.len() {
            return Err(Error(format!(
                "literal data is {} bytes but shape {:?} of {:?} needs {}",
                data.len(),
                dims,
                ty,
                numel * ty.size_of()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            data: data.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Read the literal back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let size = std::mem::size_of::<T>();
        let n = self.data.len() / size;
        let mut out: Vec<T> = Vec::with_capacity(n);
        // SAFETY: the destination buffer is freshly allocated with capacity
        // for `n` elements of T; the source holds exactly `n * size` bytes
        // (enforced at construction) and a byte-wise copy is valid for the
        // plain-old-data types implementing NativeType.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                n * size,
            );
            out.set_len(n);
        }
        Ok(out)
    }

    /// First element of the literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("literal is empty".into()))
    }

    /// Decompose a tuple literal.  The stub never produces tuples (they only
    /// come back from PJRT execution), so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text.  The stub validates the file exists and is
/// readable; the contents are carried opaquely.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle.  Construction fails in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (never constructible through the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert_eq!(lit.dims(), &[3]);
    }

    #[test]
    fn literal_round_trips_i32() {
        let vals = [7i32, -9];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vals);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn pjrt_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT"));
    }
}

//! Minimal vendored stand-in for `anyhow` (no registry access offline).
//! Provides the boxed dynamic [`Error`], the [`Result`] alias, and the
//! [`anyhow!`] macro — the subset the examples use.

use std::fmt;

/// Boxed dynamic error.  Like the real crate, `Error` deliberately does
/// *not* implement `std::error::Error`, which keeps the blanket
/// `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

impl Error {
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            inner: message.to_string().into(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            let _ = "x".parse::<i32>()?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(format!("{err}").contains("invalid digit"));
        assert!(format!("{err:?}").contains("invalid digit"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
    }
}

//! End-to-end trainer runs for EVERY access mode on a small synthetic
//! graph, through the native backend (no AOT artifacts required), pinning
//! the paper's core correctness property: the access mode changes *cost*,
//! never *numerics* — identically-seeded runs must produce bitwise
//! identical loss trajectories in all six modes, including `Tiered`.

use ptdirect::config::{AccessMode, Backend, RunConfig};
use ptdirect::coordinator::Trainer;

const STEPS: u32 = 8;

fn cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        // Force the built-in trainer so this test is hermetic even when
        // AOT artifacts happen to exist in the checkout.
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        ..RunConfig::default()
    }
}

#[test]
fn every_access_mode_shares_one_loss_trajectory() {
    let mut runs: Vec<(AccessMode, Vec<f32>, Vec<f32>)> = Vec::new();
    for mode in AccessMode::all() {
        let mut trainer = Trainer::new(cfg(mode)).unwrap();
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        for _ in 0..2 {
            let r = trainer.run_epoch().unwrap();
            assert_eq!(r.steps, STEPS as u64, "{mode:?}");
            losses.extend_from_slice(&r.losses);
            accs.extend_from_slice(&r.accs);
        }
        assert_eq!(losses.len(), 2 * STEPS as usize);
        assert!(losses.iter().all(|l| l.is_finite()), "{mode:?}");
        runs.push((mode, losses, accs));
    }
    let (ref_mode, ref_losses, ref_accs) = &runs[0];
    for (mode, losses, accs) in &runs[1..] {
        assert_eq!(
            losses, ref_losses,
            "{mode:?} loss trajectory diverged from {ref_mode:?}"
        );
        assert_eq!(
            accs, ref_accs,
            "{mode:?} accuracy trajectory diverged from {ref_mode:?}"
        );
    }
}

#[test]
fn native_training_actually_learns() {
    let mut trainer = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let first = trainer.run_epoch().unwrap().mean_loss();
    let mut last = first;
    for _ in 0..4 {
        last = trainer.run_epoch().unwrap().mean_loss();
    }
    assert!(
        last < first,
        "mean loss did not improve across epochs: {first} -> {last}"
    );
}

#[test]
fn modes_disagree_on_cost_not_on_numerics() {
    // Same seed, two trainers: losses identical, simulated transfer not.
    let mut ua = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r_ua = ua.run_epoch().unwrap();
    let mut py = Trainer::new(cfg(AccessMode::CpuGather)).unwrap();
    let r_py = py.run_epoch().unwrap();
    assert_eq!(r_ua.losses, r_py.losses);
    assert!(r_py.breakdown_sim.transfer_s > r_ua.breakdown_sim.transfer_s);
    assert!(r_py.cpu_gather_s > 0.0);
    assert_eq!(r_ua.cpu_gather_s, 0.0);
}

#[test]
fn tiered_epoch_accounts_every_row_and_undercuts_unified() {
    let mut ua = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r_ua = ua.run_epoch().unwrap();
    let mut tiered = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let r_ti = tiered.run_epoch().unwrap();

    // identical numerics (also covered by the all-modes test; kept here so
    // a tiering regression reads as a tiering failure)
    assert_eq!(r_ti.losses, r_ua.losses);

    let stats = r_ti.tier.expect("tiered epoch reports tier stats");
    // hit + miss must cover exactly the gathered rows: batch 64 roots
    // expanded by fanouts [5, 5] -> 64 * 6 * 6 rows per step.
    let rows_per_step = 64 * 6 * 6;
    assert_eq!(stats.hits + stats.misses, STEPS as u64 * rows_per_step);
    assert!(stats.hits > 0, "degree-ranked hot set never hit");
    assert!(stats.hot_bytes <= stats.capacity_bytes);

    assert!(
        r_ti.breakdown_sim.transfer_s < r_ua.breakdown_sim.transfer_s,
        "tiered {} !< unified {}",
        r_ti.breakdown_sim.transfer_s,
        r_ua.breakdown_sim.transfer_s
    );
}

#[test]
fn tiered_hit_rate_stays_healthy_across_epochs() {
    // LFU promotion adapts the degree-ranked seed placement toward the
    // actual access frequencies; across epochs the hit rate must not
    // collapse (cold-start warming itself is pinned by the store-level
    // tests and the tiering_sweep bench).
    let mut trainer = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let e1 = trainer.run_epoch().unwrap().tier.unwrap();
    let mut last = e1;
    for _ in 0..2 {
        last = trainer.run_epoch().unwrap().tier.unwrap();
    }
    assert!(
        last.hit_rate() > e1.hit_rate() - 0.05,
        "hit rate collapsed while warming: {} -> {}",
        e1.hit_rate(),
        last.hit_rate()
    );
    assert!(last.hot_bytes <= last.capacity_bytes);
}

//! End-to-end trainer suite (the former `e2e_train.rs` + `e2e_training.rs`
//! merged): one config builder, two sections.
//!
//! * **Hermetic section** — every access mode on a small synthetic graph
//!   through the native backend (no AOT artifacts required), pinning the
//!   paper's core correctness property: the access mode changes *cost*,
//!   never *numerics* — identically-seeded runs must produce bitwise
//!   identical loss trajectories in all eight modes, including `Tiered`,
//!   `Sharded` at any GPU count, and `Nvme` at any host fraction.
//! * **Artifact section** — the same stack through PJRT when
//!   `make artifacts` has produced a manifest; skipped (with a note)
//!   otherwise.

use ptdirect::config::{AccessMode, Backend, RunConfig, ShardPolicy};
use ptdirect::coordinator::Trainer;

const STEPS: u32 = 8;

/// Hermetic config: native backend, no artifacts needed.
fn cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        // Force the built-in trainer so these tests are hermetic even when
        // AOT artifacts happen to exist in the checkout.
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        ..RunConfig::default()
    }
}

/// Artifact-gated config: same knobs as [`cfg`], but through PJRT (when
/// available) against the checked-in manifest.
fn artifact_cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        backend: Backend::Auto,
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        ..cfg(mode)
    }
}

fn artifacts_present() -> bool {
    let ok = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

// ---------------- hermetic section (native backend) ----------------

#[test]
fn every_access_mode_shares_one_loss_trajectory() {
    let mut runs: Vec<(AccessMode, Vec<f32>, Vec<f32>)> = Vec::new();
    for mode in AccessMode::all() {
        let mut trainer = Trainer::new(cfg(mode)).unwrap();
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        for _ in 0..2 {
            let r = trainer.run_epoch().unwrap();
            assert_eq!(r.steps, STEPS as u64, "{mode:?}");
            losses.extend_from_slice(&r.losses);
            accs.extend_from_slice(&r.accs);
        }
        assert_eq!(losses.len(), 2 * STEPS as usize);
        assert!(losses.iter().all(|l| l.is_finite()), "{mode:?}");
        runs.push((mode, losses, accs));
    }
    let (ref_mode, ref_losses, ref_accs) = &runs[0];
    for (mode, losses, accs) in &runs[1..] {
        assert_eq!(
            losses, ref_losses,
            "{mode:?} loss trajectory diverged from {ref_mode:?}"
        );
        assert_eq!(
            accs, ref_accs,
            "{mode:?} accuracy trajectory diverged from {ref_mode:?}"
        );
    }
}

#[test]
fn sharded_n1_and_n4_share_the_loss_trajectory_with_every_mode() {
    // Sharding is placement metadata over the one table: whatever the GPU
    // count or policy, the loss trajectory must stay bitwise identical to
    // the single-GPU reference modes.
    let mut reference = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let ref_losses = reference.run_epoch().unwrap().losses;
    for (num_gpus, policy) in [
        (1, ShardPolicy::Hash),
        (4, ShardPolicy::Hash),
        (4, ShardPolicy::Degree),
        (4, ShardPolicy::Contig),
    ] {
        let mut c = cfg(AccessMode::Sharded);
        c.num_gpus = num_gpus;
        c.shard_policy = policy;
        let mut t = Trainer::new(c).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(
            r.losses, ref_losses,
            "sharded N={num_gpus} {policy:?} numerics diverged"
        );
    }
}

#[test]
fn sharded_n1_cost_degenerates_to_tiered_bit_exactly() {
    let mut ti = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let r_ti = ti.run_epoch().unwrap();
    let mut c = cfg(AccessMode::Sharded);
    c.num_gpus = 1;
    let mut sh = Trainer::new(c).unwrap();
    let r_sh = sh.run_epoch().unwrap();
    assert_eq!(r_sh.breakdown_sim.transfer_s, r_ti.breakdown_sim.transfer_s);
    assert_eq!(r_sh.bytes_on_link, r_ti.bytes_on_link);
    assert_eq!(r_sh.requests, r_ti.requests);
    assert_eq!(r_sh.losses, r_ti.losses);
}

#[test]
fn sharded_epoch_accounts_every_row_and_scales_past_one_gpu() {
    let mut c1 = cfg(AccessMode::Sharded);
    c1.num_gpus = 1;
    let r1 = Trainer::new(c1).unwrap().run_epoch().unwrap();
    let mut c4 = cfg(AccessMode::Sharded);
    c4.num_gpus = 4;
    c4.shard_policy = ShardPolicy::Degree;
    let r4 = Trainer::new(c4).unwrap().run_epoch().unwrap();

    // local + peer + host rows must cover exactly the *fetched* rows:
    // batch 64 roots expanded by fanouts [5, 5] request 64 * 6 * 6 per
    // step, and the default gather dedup compacts that to the epoch's
    // unique-row count before the store prices it.
    let rows_per_step = 64 * 6 * 6;
    for (r, n) in [(&r1, 1u64), (&r4, 4u64)] {
        let stats = r.shard.as_ref().expect("sharded epoch reports shard stats");
        assert_eq!(stats.num_gpus() as u64, n);
        assert_eq!(r.dedup.requested_rows, STEPS as u64 * rows_per_step);
        assert_eq!(stats.totals().rows_served(), r.dedup.unique_rows);
        assert!(r.dedup.unique_rows < r.dedup.requested_rows);
    }
    assert_eq!(r1.shard.as_ref().unwrap().totals().peer_rows, 0);
    assert!(r4.shard.as_ref().unwrap().totals().peer_rows > 0);
    // Four GPUs split the batch and add NVLink capacity: transfer time
    // must not regress versus one GPU.
    assert!(
        r4.breakdown_sim.transfer_s <= r1.breakdown_sim.transfer_s,
        "sharded N=4 {} slower than N=1 {}",
        r4.breakdown_sim.transfer_s,
        r1.breakdown_sim.transfer_s
    );
}

#[test]
fn nvme_shares_the_loss_trajectory_at_every_host_frac() {
    // Storage placement is metadata over the one table: whatever fraction
    // of the rows spills to NVMe, the loss trajectory must stay bitwise
    // identical to the single-tier reference modes.
    let mut reference = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let ref_losses = reference.run_epoch().unwrap().losses;
    for host_frac in [0.0, 0.1, 0.5, 1.0] {
        let mut c = cfg(AccessMode::Nvme);
        c.host_frac = host_frac;
        let mut t = Trainer::new(c).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(
            r.losses, ref_losses,
            "nvme host_frac={host_frac} numerics diverged"
        );
    }
}

#[test]
fn nvme_host_frac_one_cost_degenerates_to_tiered_bit_exactly() {
    let mut ti = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let r_ti = ti.run_epoch().unwrap();
    let mut c = cfg(AccessMode::Nvme);
    c.host_frac = 1.0;
    let mut nv = Trainer::new(c).unwrap();
    let r_nv = nv.run_epoch().unwrap();
    assert_eq!(r_nv.breakdown_sim.transfer_s, r_ti.breakdown_sim.transfer_s);
    assert_eq!(r_nv.bytes_on_link, r_ti.bytes_on_link);
    assert_eq!(r_nv.requests, r_ti.requests);
    assert_eq!(r_nv.losses, r_ti.losses);
    let stats = r_nv.nvme.expect("nvme epoch reports storage stats");
    assert_eq!(stats.storage_rows, 0, "host_frac 1 never touches storage");
    assert_eq!(stats.ios, 0);
}

#[test]
fn nvme_epoch_accounts_every_row_and_pays_for_spilling() {
    let mut c_res = cfg(AccessMode::Nvme);
    c_res.host_frac = 1.0;
    let r_res = Trainer::new(c_res).unwrap().run_epoch().unwrap();
    let mut c_sp = cfg(AccessMode::Nvme);
    c_sp.host_frac = 0.1;
    let r_sp = Trainer::new(c_sp).unwrap().run_epoch().unwrap();

    // GPU hits + host rows + storage rows must cover exactly the
    // *fetched* rows: batch 64 roots expanded by fanouts [5, 5] request
    // 64 * 6 * 6 per step, compacted by the default gather dedup.
    let rows_per_step = 64 * 6 * 6;
    for r in [&r_res, &r_sp] {
        let stats = r.nvme.as_ref().expect("nvme epoch reports storage stats");
        assert_eq!(r.dedup.requested_rows, STEPS as u64 * rows_per_step);
        assert_eq!(stats.rows_served(), r.dedup.unique_rows);
        assert!(stats.amplification() >= 1.0);
    }
    let sp = r_sp.nvme.as_ref().unwrap();
    assert!(sp.storage_rows > 0, "a 10% host tier must spill");
    assert!(sp.ios > 0);
    // Spilling trades PCIe cacheline reads for NVMe block reads: strictly
    // slower, never cheaper, and still CPU-free (GPU-initiated).
    assert!(
        r_sp.breakdown_sim.transfer_s > r_res.breakdown_sim.transfer_s,
        "nvme spill {} !> host-resident {}",
        r_sp.breakdown_sim.transfer_s,
        r_res.breakdown_sim.transfer_s
    );
    assert_eq!(r_sp.cpu_gather_s, 0.0);
    assert!(r_sp.power.storage_util > 0.0);
    assert_eq!(r_res.power.storage_util, 0.0);
}

#[test]
fn no_dedup_restores_per_occurrence_accounting() {
    // The regression anchor: with --no-dedup the store prices the
    // duplicated stream exactly as before this PR, so hit + miss covers
    // every requested occurrence again.
    let mut c = cfg(AccessMode::Tiered);
    c.dedup = false;
    let r = Trainer::new(c).unwrap().run_epoch().unwrap();
    let stats = r.tier.expect("tiered epoch reports tier stats");
    let rows_per_step = 64 * 6 * 6;
    assert_eq!(stats.hits + stats.misses, STEPS as u64 * rows_per_step);
    assert!(!r.dedup.enabled);
    assert_eq!(r.dedup.unique_rows, r.dedup.requested_rows);
    assert_eq!(r.dedup.bytes_saved, 0);
}

#[test]
fn native_training_actually_learns() {
    let mut trainer = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let first = trainer.run_epoch().unwrap().mean_loss();
    let mut last = first;
    for _ in 0..4 {
        last = trainer.run_epoch().unwrap().mean_loss();
    }
    assert!(
        last < first,
        "mean loss did not improve across epochs: {first} -> {last}"
    );
}

#[test]
fn modes_disagree_on_cost_not_on_numerics() {
    // Same seed, two trainers: losses identical, simulated transfer not.
    let mut ua = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r_ua = ua.run_epoch().unwrap();
    let mut py = Trainer::new(cfg(AccessMode::CpuGather)).unwrap();
    let r_py = py.run_epoch().unwrap();
    assert_eq!(r_ua.losses, r_py.losses);
    assert!(r_py.breakdown_sim.transfer_s > r_ua.breakdown_sim.transfer_s);
    assert!(r_py.cpu_gather_s > 0.0);
    assert_eq!(r_ua.cpu_gather_s, 0.0);
}

#[test]
fn tiered_epoch_accounts_every_row_and_undercuts_unified() {
    let mut ua = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r_ua = ua.run_epoch().unwrap();
    let mut tiered = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let r_ti = tiered.run_epoch().unwrap();

    // identical numerics (also covered by the all-modes test; kept here so
    // a tiering regression reads as a tiering failure)
    assert_eq!(r_ti.losses, r_ua.losses);

    let stats = r_ti.tier.expect("tiered epoch reports tier stats");
    // hit + miss must cover exactly the *fetched* rows: batch 64 roots
    // expanded by fanouts [5, 5] request 64 * 6 * 6 rows per step, which
    // the default gather dedup compacts to the epoch's unique count.
    let rows_per_step = 64 * 6 * 6;
    assert_eq!(r_ti.dedup.requested_rows, STEPS as u64 * rows_per_step);
    assert_eq!(stats.hits + stats.misses, r_ti.dedup.unique_rows);
    assert!(stats.hits > 0, "degree-ranked hot set never hit");
    assert!(stats.hot_bytes <= stats.capacity_bytes);

    assert!(
        r_ti.breakdown_sim.transfer_s < r_ua.breakdown_sim.transfer_s,
        "tiered {} !< unified {}",
        r_ti.breakdown_sim.transfer_s,
        r_ua.breakdown_sim.transfer_s
    );
}

#[test]
fn tiered_hit_rate_stays_healthy_across_epochs() {
    // LFU promotion adapts the degree-ranked seed placement toward the
    // actual access frequencies; across epochs the hit rate must not
    // collapse (cold-start warming itself is pinned by the store-level
    // tests and the tiering_sweep bench).
    let mut trainer = Trainer::new(cfg(AccessMode::Tiered)).unwrap();
    let e1 = trainer.run_epoch().unwrap().tier.unwrap();
    let mut last = e1;
    for _ in 0..2 {
        last = trainer.run_epoch().unwrap().tier.unwrap();
    }
    assert!(
        last.hit_rate() > e1.hit_rate() - 0.05,
        "hit rate collapsed while warming: {} -> {}",
        e1.hit_rate(),
        last.hit_rate()
    );
    assert!(last.hot_bytes <= last.capacity_bytes);
}

// ---------------- artifact section (PJRT backend) ----------------

#[test]
fn access_mode_changes_cost_not_numerics_through_pjrt() {
    if !artifacts_present() {
        return;
    }
    let mut losses = Vec::new();
    for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned] {
        let mut t = Trainer::new(artifact_cfg(mode)).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(r.steps, 8);
        losses.push(r.losses.clone());
    }
    assert_eq!(losses[0], losses[1], "Py and PyD numerics diverged");
}

#[test]
fn pyd_epoch_is_faster_and_cooler_in_sim() {
    if !artifacts_present() {
        return;
    }
    let mut t_py = Trainer::new(artifact_cfg(AccessMode::CpuGather)).unwrap();
    let py = t_py.run_epoch().unwrap();
    let mut t_pyd = Trainer::new(artifact_cfg(AccessMode::UnifiedAligned)).unwrap();
    let pyd = t_pyd.run_epoch().unwrap();
    assert!(py.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
    assert!(py.breakdown_sim.total_s() > pyd.breakdown_sim.total_s());
    assert!(py.power.watts > pyd.power.watts);
    // non-transfer components nearly identical (paper §5.4)
    let rel = |a: f64, b: f64| (a - b).abs() / a.max(1e-12);
    assert!(rel(py.breakdown_sim.sample_s, pyd.breakdown_sim.sample_s) < 1e-9);
    assert!(rel(py.breakdown_sim.train_s, pyd.breakdown_sim.train_s) < 1e-9);
}

#[test]
fn multi_epoch_training_converges_through_pjrt() {
    if !artifacts_present() {
        return;
    }
    let mut c = artifact_cfg(AccessMode::UnifiedAligned);
    c.steps_per_epoch = 18;
    let mut t = Trainer::new(c).unwrap();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..8 {
        let r = t.run_epoch().unwrap();
        if first_loss.is_none() {
            first_loss = r.losses.first().copied();
        }
        last_loss = r.final_loss();
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.75,
        "no convergence: {first} -> {last_loss}"
    );
}

#[test]
fn uvm_mode_runs_and_is_slower_than_pyd() {
    if !artifacts_present() {
        return;
    }
    // The paper's regime: the feature table exceeds GPU memory, so UVM
    // thrashes (with a roomy GPU and a tiny test table, UVM would simply
    // cache everything and win — which is why the paper's baselines only
    // use UVM as a strawman for *oversized* graphs).
    let mut c_uvm = artifact_cfg(AccessMode::Uvm);
    c_uvm.system.gpu_mem_bytes = 64 << 10;
    let mut t_uvm = Trainer::new(c_uvm).unwrap();
    let uvm = t_uvm.run_epoch().unwrap();
    let mut t_pyd = Trainer::new(artifact_cfg(AccessMode::UnifiedAligned)).unwrap();
    let pyd = t_pyd.run_epoch().unwrap();
    assert_eq!(uvm.losses, pyd.losses, "UVM numerics must match too");
    assert!(uvm.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
}

#[test]
fn gpu_resident_gated_by_capacity() {
    if !artifacts_present() {
        return;
    }
    let mut c = artifact_cfg(AccessMode::GpuResident);
    c.system.gpu_mem_bytes = 1 << 16; // 64 KiB "GPU"
    match Trainer::new(c) {
        Err(ptdirect::Error::GpuOom { .. }) => {}
        Err(e) => panic!("expected GpuOom, got {e}"),
        Ok(_) => panic!("expected GpuOom, trainer built"),
    }
}

#[test]
fn inference_path_serves_batches() {
    // Forward-only serving over the same data path (paper §4.1: training
    // *and inference*); accuracy with untrained params ~ chance.
    if !artifacts_present() {
        return;
    }
    let mut runner =
        ptdirect::coordinator::InferenceRunner::new(artifact_cfg(AccessMode::UnifiedAligned))
            .unwrap();
    let r = runner.run(6).unwrap();
    assert_eq!(r.batches, 6);
    assert!(r.exec_latency.median() > 0.0);
    assert!(r.sim_latency.median() > 0.0);
    assert!((0.0..=1.0).contains(&r.accuracy));
    assert!(r.breakdown_sim.transfer_s > 0.0);
}

#[test]
fn artifact_config_mismatch_is_rejected() {
    if !artifacts_present() {
        return;
    }
    let mut c = artifact_cfg(AccessMode::UnifiedAligned);
    c.batch = 32; // artifacts were built for batch 64
    assert!(Trainer::new(c).is_err());
}

//! Cross-layer properties of the quantized cold-tier feature storage
//! (`--precision fp32|fp16|int8`, DESIGN.md §13):
//!
//! * `fp32` is the identity — the quantized builder reproduces the plain
//!   builder bit-for-bit (values *and* transfer costs) in all eight
//!   access modes, so every pre-existing report is unchanged.
//! * Quantization happens once at table build, so *within* a precision
//!   all eight modes still share one bitwise loss trajectory — the
//!   repo's core invariant survives narrowing.
//! * `fp16`/`int8` trajectories track the fp32 reference inside
//!   documented tolerance bands (the repo's first tolerance-based
//!   equivalence, via `util::approx`), and their round-trip error obeys
//!   the per-format bounds through the public store API.
//! * Narrower rows strictly reduce what every transfer-paying mode
//!   moves: link bytes in all seven paying modes, NVMe block I/Os in
//!   storage mode.

use ptdirect::config::{AccessMode, Backend, Precision, RunConfig, SystemProfile};
use ptdirect::coordinator::Trainer;
use ptdirect::featurestore::quant;
use ptdirect::featurestore::FeatureStore;
use ptdirect::util::approx::{approx_eq, approx_eq_slice};

const STEPS: u32 = 8;

/// Documented tolerance bands for quantized loss trajectories vs the
/// fp32 reference (absolute, per step — see DESIGN.md §13).  fp16 keeps
/// 11 significand bits, so per-element feature error is ~5e-4 relative;
/// int8 rows span ~[-0.05, 1.05] giving scale ≈ 1.1/255 and per-element
/// error ≤ scale/2 ≈ 2.2e-3 — both orders of magnitude below these
/// bands, which absorb amplification through aggregation and softmax.
const FP16_LOSS_TOL: f32 = 2e-2;
const INT8_LOSS_TOL: f32 = 1.5e-1;

/// Hermetic config: native backend, no artifacts needed (the
/// `e2e_train.rs` builder with a precision knob).
fn cfg(mode: AccessMode, precision: Precision) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        precision,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        ..RunConfig::default()
    }
}

fn epoch(c: RunConfig) -> ptdirect::coordinator::EpochReport {
    Trainer::new(c).unwrap().run_epoch().unwrap()
}

#[test]
fn fp32_matches_the_unquantized_builder_bit_exactly() {
    // The pinned degeneracy link: `--precision fp32` must leave every
    // existing report untouched, because the quantized builder with the
    // identity format IS the plain builder.
    let sys = SystemProfile::system1();
    let idx: Vec<u32> = (0..300).map(|i| (i * 7) % 500).collect();
    for mode in AccessMode::all() {
        let plain = FeatureStore::build(500, 24, 8, mode, &sys, 42).unwrap();
        let quantized = FeatureStore::build_quantized(
            500,
            24,
            8,
            mode,
            &sys,
            42,
            Precision::Fp32,
            None,
            None,
            None,
        )
        .unwrap();
        let (a, ca) = plain.gather(&idx).unwrap();
        let (b, cb) = quantized.gather(&idx).unwrap();
        assert_eq!(a, b, "{mode:?} fp32 values diverged");
        assert_eq!(ca.time_s, cb.time_s, "{mode:?}");
        assert_eq!(ca.bytes_on_link, cb.bytes_on_link, "{mode:?}");
        assert_eq!(ca.useful_bytes, cb.useful_bytes, "{mode:?}");
        assert_eq!(ca.requests, cb.requests, "{mode:?}");
    }
}

#[test]
fn all_modes_share_one_loss_trajectory_at_every_precision_and_track_fp32() {
    // Quantize-once-at-build: within a precision, all eight modes gather
    // the same already-dequantized table, so the bitwise cross-mode
    // equality survives narrowing; only the fp32 *reference* moves, and
    // only within the documented band.
    for precision in [Precision::Fp16, Precision::Int8] {
        let tol = match precision {
            Precision::Fp16 => FP16_LOSS_TOL,
            _ => INT8_LOSS_TOL,
        };
        let mut reference: Option<(Vec<f32>, Vec<f32>)> = None;
        for mode in AccessMode::all() {
            let r32 = epoch(cfg(mode, Precision::Fp32));
            let rq = epoch(cfg(mode, precision));
            assert_eq!(rq.steps, STEPS as u64, "{mode:?} {precision:?}");
            assert!(
                rq.losses.iter().all(|l| l.is_finite()),
                "{mode:?} {precision:?} non-finite loss"
            );
            // Band vs fp32 (abs-tol arm only: losses sit near ln(8), so
            // the band, not ULP distance, is the spec).
            approx_eq_slice(&r32.losses, &rq.losses, tol, 0).unwrap_or_else(|e| {
                panic!("{mode:?} {precision:?} loss left the ±{tol} band: {e}")
            });
            // Bitwise across modes at this precision.
            match &reference {
                None => reference = Some((rq.losses.clone(), rq.accs.clone())),
                Some((ref_losses, ref_accs)) => {
                    assert_eq!(
                        &rq.losses, ref_losses,
                        "{mode:?} {precision:?} loss trajectory diverged across modes"
                    );
                    assert_eq!(
                        &rq.accs, ref_accs,
                        "{mode:?} {precision:?} accuracy trajectory diverged across modes"
                    );
                }
            }
        }
    }
}

#[test]
fn narrower_precision_strictly_reduces_link_bytes_in_every_paying_mode() {
    // The whole point of quantized cold tiers: fp32 -> fp16 -> int8 must
    // strictly shrink what crosses the links, in every mode that pays
    // for transfers.  (product rows are 100 floats, so rows span 4 / 2 /
    // 1 cachelines and even request-granular models narrow strictly.)
    for mode in AccessMode::all() {
        if mode == AccessMode::GpuResident {
            continue; // priced link-free below
        }
        let mut bytes = Vec::new();
        for precision in Precision::all() {
            let mut c = cfg(mode, precision);
            if mode == AccessMode::Sharded {
                c.num_gpus = 4; // exercise the peer path too
            }
            if mode == AccessMode::Nvme {
                c.host_frac = 0.2; // force real storage traffic
            }
            let r = epoch(c);
            if mode == AccessMode::Nvme {
                let ios = r.nvme.expect("nvme epoch reports storage stats").ios;
                bytes.push((precision, r.bytes_on_link, Some(ios)));
            } else {
                bytes.push((precision, r.bytes_on_link, None));
            }
        }
        for pair in bytes.windows(2) {
            let (p_wide, b_wide, io_wide) = pair[0];
            let (p_narrow, b_narrow, io_narrow) = pair[1];
            assert!(
                b_wide > b_narrow && b_narrow > 0,
                "{mode:?}: {p_wide:?} moved {b_wide} B, {p_narrow:?} moved {b_narrow} B \
                 (expected a strict reduction)"
            );
            if let (Some(iw), Some(inn)) = (io_wide, io_narrow) {
                assert!(
                    iw > inn && inn > 0,
                    "{mode:?}: {p_wide:?} issued {iw} block IOs, {p_narrow:?} {inn} \
                     (expected a strict reduction)"
                );
            }
        }
    }
    // GPU-resident gathers never touch a link, at any precision.
    for precision in Precision::all() {
        assert_eq!(epoch(cfg(AccessMode::GpuResident, precision)).bytes_on_link, 0);
    }
}

#[test]
fn round_trip_error_bounds_hold_through_the_store() {
    // Gather the same rows from a plain fp32 store and each quantized
    // store; the element-wise error must obey the per-format bounds
    // (fp16: half an fp16 ULP == 4096 f32 ULPs for normals, abs 2^-25
    // near zero; int8: scale/2 per row).
    let sys = SystemProfile::system1();
    let (rows, dim) = (600usize, 100usize);
    let idx: Vec<u32> = (0..rows as u32).collect();
    let build = |p| {
        FeatureStore::build_quantized(
            rows,
            dim,
            8,
            AccessMode::UnifiedAligned,
            &sys,
            7,
            p,
            None,
            None,
            None,
        )
        .unwrap()
    };
    let (f32_vals, _) = build(Precision::Fp32).gather(&idx).unwrap();

    let (f16_vals, _) = build(Precision::Fp16).gather(&idx).unwrap();
    for (i, (&x, &y)) in f32_vals.iter().zip(f16_vals.iter()).enumerate() {
        assert!(
            approx_eq(x, y, 3.0e-8, 4096),
            "fp16 element {i}: {x} -> {y} exceeds half-ULP bound"
        );
    }

    let (i8_vals, _) = build(Precision::Int8).gather(&idx).unwrap();
    for (r, (orig, quantized)) in f32_vals
        .chunks_exact(dim)
        .zip(i8_vals.chunks_exact(dim))
        .enumerate()
    {
        // Recompute the row's affine params from the fp32 original —
        // the same data the builder derived them from.
        let p = quant::int8_row_params(orig);
        let bound = p.scale * 0.5 * (1.0 + 1e-5) + 1e-7;
        for (i, (&x, &y)) in orig.iter().zip(quantized.iter()).enumerate() {
            assert!(
                (x - y).abs() <= bound,
                "int8 row {r} element {i}: {x} -> {y} exceeds scale/2 = {bound}"
            );
        }
    }
}

#[test]
fn quantized_training_still_learns() {
    // The band test bounds per-step drift; this pins the end-to-end
    // claim that int8 features remain *useful* — the loss still falls
    // across epochs, as it does for fp32.
    let mut t = Trainer::new(cfg(AccessMode::UnifiedAligned, Precision::Int8)).unwrap();
    let first = t.run_epoch().unwrap().mean_loss();
    let mut last = first;
    for _ in 0..4 {
        last = t.run_epoch().unwrap().mean_loss();
    }
    assert!(
        last < first,
        "int8 mean loss did not improve across epochs: {first} -> {last}"
    );
}

//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a notice)
//! otherwise, so `cargo test` stays green on a fresh checkout while CI
//! with artifacts exercises the full path.

use std::path::{Path, PathBuf};

use ptdirect::runtime::state::{StepBatch, TrainState};
use ptdirect::runtime::{ArtifactKind, Manifest, Runtime};
use ptdirect::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn synthetic_batch(spec: &ptdirect::runtime::ArtifactSpec, seed: u64) -> StepBatch {
    let mut rng = Rng::new(seed);
    let n0 = spec.layer_sizes[0];
    let x0: Vec<f32> = (0..n0 * spec.in_dim)
        .map(|_| rng.gen_f32_range(-0.5, 0.5))
        .collect();
    let mut nbrs = Vec::new();
    let mut masks = Vec::new();
    for l in 0..spec.fanouts.len() {
        let n_dst = spec.layer_sizes[l + 1];
        let f = spec.fanouts[l];
        let n_src = spec.layer_sizes[l];
        nbrs.push(
            (0..n_dst * f)
                .map(|_| rng.gen_range(n_src as u64) as i32)
                .collect(),
        );
        masks.push(vec![1.0f32; n_dst * f]);
    }
    let labels: Vec<i32> = (0..spec.batch)
        .map(|_| rng.gen_range(spec.classes as u64) as i32)
        .collect();
    StepBatch {
        x0,
        nbrs,
        masks,
        labels,
    }
}

#[test]
fn manifest_covers_all_fig8_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.artifacts.len() >= 26); // 12 train + 12 infer + 2 gather
    for arch in ["sage", "gat"] {
        for ds in ["reddit", "product", "twit", "sk", "paper", "wiki"] {
            let spec = m.get(&format!("{arch}_{ds}")).unwrap();
            assert_eq!(spec.kind, ArtifactKind::Train);
            assert!(spec.param_elems() > 0);
            assert!(spec.hlo_path(&dir).exists());
        }
    }
}

#[test]
fn train_step_learns_fixed_batch() {
    // Repeating one batch must drive the loss down — real learning through
    // the full artifact (fwd + custom-VJP bwd + SGD momentum update).
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let spec = m.get("sage_product").unwrap();
    let rt = Runtime::cpu().unwrap();
    let loaded = rt.load(&dir, spec).unwrap();
    let mut state = TrainState::init(spec, 7).unwrap();
    let batch = synthetic_batch(spec, 1234);

    let mut losses = Vec::new();
    let mut accs = Vec::new();
    for _ in 0..12 {
        let metrics = state.step(&loaded, &batch).unwrap();
        assert!(metrics.loss.is_finite());
        losses.push(metrics.loss);
        accs.push(metrics.acc);
    }
    // the fixed batch is pure noise (no label signal), so the model is
    // memorizing — expect a steady monotone-ish decrease, not a collapse
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.1),
        "loss did not decrease: {losses:?}"
    );
    assert!(accs.last().unwrap() >= &accs[0]);
    assert_eq!(state.steps, 12);
}

#[test]
fn gat_artifact_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let spec = m.get("gat_product").unwrap();
    let rt = Runtime::cpu().unwrap();
    let loaded = rt.load(&dir, spec).unwrap();
    let mut state = TrainState::init(spec, 9).unwrap();
    let batch = synthetic_batch(spec, 99);
    let m1 = state.step(&loaded, &batch).unwrap();
    let m2 = state.step(&loaded, &batch).unwrap();
    assert!(m1.loss.is_finite() && m2.loss.is_finite());
    assert_ne!(m1.loss, m2.loss, "params must have been updated");
}

#[test]
fn gather_artifacts_match_rust_gather_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    for name in ["gather_naive", "gather_aligned"] {
        let spec = m.get(name).unwrap();
        let loaded = rt.load(&dir, spec).unwrap();
        let rows = spec.inputs[0].dims[0];
        let feat = spec.inputs[0].dims[1];
        let batch = spec.inputs[1].dims[0];
        let mut rng = Rng::new(3);
        let table: Vec<f32> = (0..rows * feat)
            .map(|_| rng.gen_f32_range(-1.0, 1.0))
            .collect();
        let idx: Vec<i32> = (0..batch)
            .map(|_| rng.gen_range(rows as u64) as i32)
            .collect();
        let lt = ptdirect::runtime::client::literal_f32(&table, &[rows, feat]).unwrap();
        let li = ptdirect::runtime::client::literal_i32(&idx, &[batch]).unwrap();
        let outs = loaded.execute(&[&lt, &li]).unwrap();
        let got = outs[0].to_vec::<f32>().unwrap();
        let idx_u: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
        let mut want = vec![0f32; batch * feat];
        ptdirect::tensor::indexing::gather_rows_into(&table, feat, &idx_u, &mut want);
        assert_eq!(got, want, "{name} diverges from the rust gather");
    }
}

#[test]
fn step_rejects_malformed_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let spec = m.get("sage_product").unwrap();
    let rt = Runtime::cpu().unwrap();
    let loaded = rt.load(&dir, spec).unwrap();
    let mut state = TrainState::init(spec, 7).unwrap();
    let mut batch = synthetic_batch(spec, 5);
    batch.x0.truncate(10); // wrong length
    assert!(state.step(&loaded, &batch).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.get("sage_imagenet").is_err());
}

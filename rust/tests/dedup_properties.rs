//! Cross-layer properties of the minibatch gather-deduplication subsystem
//! (`sampler::compact::GatherPlan`, DESIGN.md §10):
//!
//! * **numerics** — dedup on vs off produces bitwise identical loss and
//!   accuracy trajectories in all eight access modes (scatter ∘
//!   gather-unique is the identity on row values);
//! * **traffic** — on a graph with overlapping neighborhoods, dedup
//!   strictly reduces the simulated link bytes in every transfer-paying
//!   mode (py/pyd/tiered/sharded/nvme) and never increases transfer time;
//! * **accounting** — requested ≥ unique, ratio ≥ 1, the unique set is
//!   exactly the distinct requested set, and `--no-dedup` restores the
//!   pre-PR per-occurrence accounting bit-exactly (same losses, same
//!   bytes, same tier counters).

use ptdirect::config::{AccessMode, Backend, RunConfig, ShardPolicy};
use ptdirect::coordinator::Trainer;
use ptdirect::featurestore::FeatureStore;
use ptdirect::sampler::GatherPlan;
use ptdirect::util::proptest::{check, prop_assert, Gen};

const STEPS: u32 = 8;

/// Hermetic config mirroring `e2e_train.rs`: native backend, no
/// artifacts, sharded runs get real partitioning.
fn cfg(mode: AccessMode, dedup: bool) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        dedup,
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        ..RunConfig::default()
    }
}

#[test]
fn losses_bitwise_identical_with_dedup_on_and_off_in_all_modes() {
    for mode in AccessMode::all() {
        let mut on = Trainer::new(cfg(mode, true)).unwrap();
        let mut off = Trainer::new(cfg(mode, false)).unwrap();
        for epoch in 0..2 {
            let r_on = on.run_epoch().unwrap();
            let r_off = off.run_epoch().unwrap();
            assert_eq!(
                r_on.losses, r_off.losses,
                "{mode:?} epoch {epoch}: dedup changed the loss trajectory"
            );
            assert_eq!(
                r_on.accs, r_off.accs,
                "{mode:?} epoch {epoch}: dedup changed the accuracy trajectory"
            );
        }
    }
}

#[test]
fn dedup_strictly_reduces_link_bytes_in_every_transfer_paying_mode() {
    // The acceptance shape of the PR: on an R-MAT graph with overlapping
    // neighborhoods (the product preset's generator), dedup-on must move
    // strictly fewer bytes over the links in every mode that pays for
    // transfers, without ever costing more simulated time.
    for mode in [
        AccessMode::CpuGather,
        AccessMode::UnifiedNaive,
        AccessMode::UnifiedAligned,
        AccessMode::Tiered,
        AccessMode::Sharded,
        AccessMode::Nvme,
    ] {
        let r_on = Trainer::new(cfg(mode, true)).unwrap().run_epoch().unwrap();
        let r_off = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        assert!(
            r_on.bytes_on_link < r_off.bytes_on_link,
            "{mode:?}: dedup bytes {} !< naive {}",
            r_on.bytes_on_link,
            r_off.bytes_on_link
        );
        assert!(
            r_on.breakdown_sim.transfer_s <= r_off.breakdown_sim.transfer_s,
            "{mode:?}: dedup transfer {} > naive {}",
            r_on.breakdown_sim.transfer_s,
            r_off.breakdown_sim.transfer_s
        );
    }
    // UVM's resident set already absorbs intra-batch duplicates (a
    // repeated row is a page hit, not a second migration), so dedup can
    // only tie its link bytes — never worsen them.
    let r_on = Trainer::new(cfg(AccessMode::Uvm, true)).unwrap().run_epoch().unwrap();
    let r_off = Trainer::new(cfg(AccessMode::Uvm, false)).unwrap().run_epoch().unwrap();
    assert!(r_on.bytes_on_link <= r_off.bytes_on_link);
    assert!(r_on.breakdown_sim.transfer_s <= r_off.breakdown_sim.transfer_s);

    // GpuResident moves nothing over links in either case; its win is the
    // row count in the dedup report, checked in the accounting test.
    let r_gpu = Trainer::new(cfg(AccessMode::GpuResident, true))
        .unwrap()
        .run_epoch()
        .unwrap();
    assert_eq!(r_gpu.bytes_on_link, 0);
    assert!(r_gpu.dedup.unique_rows < r_gpu.dedup.requested_rows);
}

#[test]
fn dedup_accounting_is_consistent_across_modes() {
    let rows_per_step = 64 * 6 * 6; // batch 64, fanouts [5, 5]
    for mode in AccessMode::all() {
        let r = Trainer::new(cfg(mode, true)).unwrap().run_epoch().unwrap();
        assert!(r.dedup.enabled, "{mode:?}");
        assert_eq!(r.dedup.requested_rows, STEPS as u64 * rows_per_step, "{mode:?}");
        assert!(r.dedup.unique_rows <= r.dedup.requested_rows, "{mode:?}");
        assert!(
            r.dedup.unique_rows < r.dedup.requested_rows,
            "{mode:?}: overlapping neighborhoods must deduplicate"
        );
        assert!(r.dedup.ratio() > 1.0, "{mode:?}");
        // 100-dim f32 rows: bytes saved must match the row delta exactly.
        assert_eq!(
            r.dedup.bytes_saved,
            (r.dedup.requested_rows - r.dedup.unique_rows) * 100 * 4,
            "{mode:?}"
        );
    }
}

#[test]
fn no_dedup_runs_are_bit_reproducible() {
    // The regression anchor must itself be deterministic: two identical
    // --no-dedup runs produce identical reports (losses, bytes, requests,
    // transfer time), which is what anchors "reproduces pre-PR numbers".
    for mode in [AccessMode::CpuGather, AccessMode::Tiered, AccessMode::Nvme] {
        let a = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        let b = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        assert_eq!(a.losses, b.losses, "{mode:?}");
        assert_eq!(a.bytes_on_link, b.bytes_on_link, "{mode:?}");
        assert_eq!(a.requests, b.requests, "{mode:?}");
        assert_eq!(a.breakdown_sim.transfer_s, b.breakdown_sim.transfer_s, "{mode:?}");
        assert_eq!(a.dedup.requested_rows, b.dedup.requested_rows, "{mode:?}");
    }
}

#[test]
fn dedup_and_overlap_engine_compose() {
    // Depth-0 anchoring must survive dedup: the overlapped timeline at
    // depth 0 still returns the (now smaller) serial sum bit-exactly.
    for dedup in [true, false] {
        let mut c = cfg(AccessMode::UnifiedAligned, dedup);
        c.prefetch_depth = 0;
        c.skip_train = true;
        let r = Trainer::new(c).unwrap().run_epoch().unwrap();
        assert_eq!(r.overlap.overlapped_s, r.breakdown_sim.total_s(), "dedup={dedup}");
    }
}

#[test]
fn store_level_scatter_gather_identity_property() {
    // Random duplicated request streams against a real store: the planned
    // gather must be bitwise identical to the naive gather in every mode,
    // while pricing exactly the unique stream.
    let sys = ptdirect::config::SystemProfile::system1();
    check(12, |g: &mut Gen| {
        let n = g.usize_in(1, 300);
        let idx = g.vec_u32(n, 0, 79); // heavy duplication over 80 rows
        let plan = GatherPlan::build(&idx);
        plan.validate(&idx).map_err(|e| e)?;
        for mode in AccessMode::all() {
            let st = FeatureStore::build(80, 12, 4, mode, &sys, 7).expect("store");
            let (naive, _) = st.gather(&idx).expect("naive gather");
            let fresh = FeatureStore::build(80, 12, 4, mode, &sys, 7).expect("store");
            let mut planned = vec![0f32; idx.len() * 12];
            let cost = fresh.gather_planned(&plan, &mut planned).expect("planned");
            prop_assert(planned == naive, format!("{mode:?}: numerics diverged"))?;
            prop_assert(
                cost.useful_bytes == plan.unique_rows() as u64 * 12 * 4,
                format!("{mode:?}: cost not on the unique stream"),
            )?;
        }
        Ok(())
    });
}

//! Lock-down layer for the shared paged feature cache (DESIGN.md §12):
//!
//! * **differential** — a frozen, verbatim copy of the pre-refactor
//!   row-granular `TieredCache` (static preseed + LFU min-heap
//!   promotion) replayed against the paged cache at `--page-rows 1`
//!   over random traces, capacities, and rankings: cold streams and
//!   every counter must match bit-exactly, for both the static and the
//!   LFU spelling;
//! * **anchor spellings** — at the trainer level, the explicit
//!   `--eviction static --page-rows 1` knobs reproduce the legacy
//!   `--no-tier-promote` reports bit-exactly in all eight access modes,
//!   and the knobs are inert in the modes that have no tier;
//! * **refcounts** — pinned pages are never evicted, refcounts return
//!   to zero after every gather and after every balanced pin/unpin;
//! * **residency conservation** — resident pages never exceed the page
//!   budget, pages partition the row space, and the resident-row gauge
//!   equals the sum of resident page spans.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use ptdirect::config::{AccessMode, Backend, EvictionPolicy, RunConfig, ShardPolicy, SystemProfile};
use ptdirect::coordinator::Trainer;
use ptdirect::featurestore::{FeatureStore, PageCache, TierConfig, TieredCache};
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

// ---------------------------------------------------------------------------
// Frozen pre-refactor reference: the row-granular TieredCache exactly as it
// shipped before the paged-cache refactor.  Do not "improve" this code — its
// value is that it is the old arithmetic, verbatim.
// ---------------------------------------------------------------------------

struct ReferenceRowCache {
    hot: Vec<bool>,
    freq: Vec<u64>,
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    hot_rows: usize,
    capacity_rows: usize,
    promote: bool,
    hits: u64,
    misses: u64,
    promotions: u64,
    evictions: u64,
}

impl ReferenceRowCache {
    fn new(
        rows: usize,
        row_bytes: u64,
        sys: &SystemProfile,
        hot_frac: f64,
        reserve_bytes: u64,
        promote: bool,
        ranking: Option<&[u32]>,
    ) -> ReferenceRowCache {
        let budget_bytes = sys.gpu_mem_bytes.saturating_sub(reserve_bytes);
        let budget_rows = if row_bytes == 0 {
            0
        } else {
            (budget_bytes / row_bytes).min(rows as u64) as usize
        };
        let target = (hot_frac.clamp(0.0, 1.0) * rows as f64).floor() as usize;
        let capacity_rows = target.min(budget_rows);
        let mut cache = ReferenceRowCache {
            hot: vec![false; rows],
            freq: vec![0; rows],
            heap: BinaryHeap::new(),
            hot_rows: 0,
            capacity_rows,
            promote,
            hits: 0,
            misses: 0,
            promotions: 0,
            evictions: 0,
        };
        // Pre-refactor preseed: the ranking's first `capacity_rows`
        // distinct in-range ids (`placement::ranked_prefix`), inserted
        // without counting as promotions; no ranking = cold start.
        if let Some(order) = ranking {
            for &r in order {
                if cache.hot_rows >= cache.capacity_rows {
                    break;
                }
                if (r as usize) < rows && !cache.hot[r as usize] {
                    cache.insert_hot(r);
                }
            }
        }
        cache
    }

    fn record(&mut self, idx: &[u32]) -> Vec<u32> {
        let mut cold = Vec::new();
        for &r in idx {
            let ri = r as usize;
            self.freq[ri] += 1;
            if self.hot[ri] {
                self.hits += 1;
            } else {
                self.misses += 1;
                cold.push(r);
            }
        }
        if self.promote && self.capacity_rows > 0 && !cold.is_empty() {
            let mut candidates = cold.clone();
            candidates.sort_unstable();
            candidates.dedup();
            for r in candidates {
                self.maybe_promote(r);
            }
        }
        cold
    }

    fn maybe_promote(&mut self, r: u32) {
        if self.hot[r as usize] {
            return;
        }
        if self.hot_rows < self.capacity_rows {
            self.insert_hot(r);
            self.promotions += 1;
            return;
        }
        match self.refresh_min() {
            Some((min_freq, _)) if self.freq[r as usize] > min_freq => {
                self.evict_min();
                self.insert_hot(r);
                self.promotions += 1;
            }
            _ => {}
        }
    }

    fn insert_hot(&mut self, r: u32) {
        self.hot[r as usize] = true;
        self.hot_rows += 1;
        self.heap.push(Reverse((self.freq[r as usize], r)));
    }

    fn refresh_min(&mut self) -> Option<(u64, u32)> {
        while let Some(&Reverse((f, row))) = self.heap.peek() {
            if !self.hot[row as usize] {
                self.heap.pop();
            } else if self.freq[row as usize] != f {
                self.heap.pop();
                self.heap.push(Reverse((self.freq[row as usize], row)));
            } else {
                return Some((f, row));
            }
        }
        None
    }

    fn evict_min(&mut self) {
        if self.refresh_min().is_some() {
            let Reverse((_, row)) = self.heap.pop().unwrap();
            self.hot[row as usize] = false;
            self.hot_rows -= 1;
            self.evictions += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Shared generators
// ---------------------------------------------------------------------------

fn random_ranking(g: &mut Gen, rows: usize) -> Option<Vec<u32>> {
    if g.bool() {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(g.seed ^ 0xC0FFEE).shuffle(&mut order);
        Some(order)
    } else {
        None
    }
}

fn random_gathers(g: &mut Gen, rows: usize) -> Vec<Vec<u32>> {
    let n_gathers = g.usize_in(1, 8);
    (0..n_gathers)
        .map(|_| {
            let len = g.usize_in(1, 200);
            g.vec_u32(len, 0, (rows - 1) as u32)
        })
        .collect()
}

/// Hermetic trainer config mirroring `e2e_train.rs` / `dedup_properties.rs`.
fn trainer_cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: 4,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        ..RunConfig::default()
    }
}

fn assert_reports_bit_equal(
    a: &ptdirect::coordinator::EpochReport,
    b: &ptdirect::coordinator::EpochReport,
    what: &str,
) {
    assert_eq!(a.losses, b.losses, "{what}: losses diverged");
    assert_eq!(a.accs, b.accs, "{what}: accuracies diverged");
    assert_eq!(a.bytes_on_link, b.bytes_on_link, "{what}: link bytes diverged");
    assert_eq!(a.requests, b.requests, "{what}: request counts diverged");
    assert_eq!(
        a.breakdown_sim.transfer_s, b.breakdown_sim.transfer_s,
        "{what}: simulated transfer time diverged"
    );
    assert_eq!(a.tier, b.tier, "{what}: tier stats diverged");
    assert_eq!(
        a.shard.as_ref().map(|s| s.per_gpu.clone()),
        b.shard.as_ref().map(|s| s.per_gpu.clone()),
        "{what}: shard stats diverged"
    );
    assert_eq!(a.nvme, b.nvme, "{what}: nvme stats diverged");
}

// ---------------------------------------------------------------------------
// 1. Differential: paged cache @ page_rows = 1 vs the frozen reference
// ---------------------------------------------------------------------------

#[test]
fn page_rows_one_replays_the_frozen_row_cache_bit_exactly() {
    // Both the LFU (promote on) and static (promote off) spellings, over
    // random tables, budgets, rankings, and traces: the paged cache at
    // row granularity *is* the old cache — same cold streams, same
    // counters, same hot set, gather after gather.
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 32);
        let row_bytes = dim as u64 * 4;
        let mut sys = SystemProfile::system1();
        // Shrink the GPU so the byte budget actually binds sometimes.
        sys.gpu_mem_bytes = g.u64_in(0, 80) * row_bytes;
        let hot_frac = g.f64_in(0.0, 1.0);
        let reserve = g.u64_in(0, 8) * row_bytes;
        let promote = g.bool();
        let ranking = random_ranking(g, rows);

        let mut reference = ReferenceRowCache::new(
            rows,
            row_bytes,
            &sys,
            hot_frac,
            reserve,
            promote,
            ranking.as_deref(),
        );
        let mut paged = TieredCache::new(
            rows,
            row_bytes,
            &sys,
            &TierConfig {
                hot_frac,
                reserve_bytes: reserve,
                promote,
                ranking: ranking.clone(),
                page_rows: 1,
                eviction: EvictionPolicy::Lfu,
            },
        );

        prop_assert(
            paged.capacity_rows() == reference.capacity_rows,
            format!(
                "capacity diverged: paged {} vs reference {}",
                paged.capacity_rows(),
                reference.capacity_rows
            ),
        )?;
        for (i, idx) in random_gathers(g, rows).into_iter().enumerate() {
            let cold_ref = reference.record(&idx);
            let cold_new = paged.record(&idx);
            prop_assert(
                cold_new == cold_ref,
                format!("gather {i}: cold stream diverged (promote={promote})"),
            )?;
            let s = paged.stats();
            prop_assert(
                s.hits == reference.hits
                    && s.misses == reference.misses
                    && s.promotions == reference.promotions
                    && s.evictions == reference.evictions,
                format!(
                    "gather {i}: counters diverged: paged {}/{}/{}/{} vs \
                     reference {}/{}/{}/{}",
                    s.hits,
                    s.misses,
                    s.promotions,
                    s.evictions,
                    reference.hits,
                    reference.misses,
                    reference.promotions,
                    reference.evictions
                ),
            )?;
            prop_assert(
                paged.hot_rows() == reference.hot_rows,
                format!("gather {i}: hot_rows diverged"),
            )?;
            for r in 0..rows as u32 {
                prop_assert(
                    paged.is_hot(r) == reference.hot[r as usize],
                    format!("gather {i}: hot set diverged at row {r}"),
                )?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Trainer-level anchor: explicit static/page-1 knobs == legacy reports
//    in all eight access modes
// ---------------------------------------------------------------------------

#[test]
fn static_page1_reproduces_legacy_reports_in_all_eight_modes() {
    for mode in AccessMode::all() {
        // Legacy spelling of the static walk: promotion off, knobs at
        // their defaults (exactly the pre-refactor no-promote path).
        let mut legacy = trainer_cfg(mode);
        legacy.tier_promote = false;
        // New spelling: the ISSUE's pinned anchor flags, stated
        // explicitly.
        let mut anchor = trainer_cfg(mode);
        anchor.tier_promote = false;
        anchor.eviction = EvictionPolicy::Static;
        anchor.page_rows = 1;

        let r_legacy = Trainer::new(legacy).unwrap().run_epoch().unwrap();
        let r_anchor = Trainer::new(anchor).unwrap().run_epoch().unwrap();
        assert_reports_bit_equal(&r_legacy, &r_anchor, &format!("{mode:?} static anchor"));

        // With the policy pinned to Static, the promote flag itself is
        // inert — promotion-on-but-never-admitting is the same walk.
        let mut static_promote = trainer_cfg(mode);
        static_promote.eviction = EvictionPolicy::Static;
        static_promote.page_rows = 1;
        let r_sp = Trainer::new(static_promote).unwrap().run_epoch().unwrap();
        assert_reports_bit_equal(&r_legacy, &r_sp, &format!("{mode:?} static+promote"));
    }
}

#[test]
fn page_cache_knobs_are_inert_outside_the_tier_modes() {
    // Modes without a hot tier must not read the knobs at all: cranking
    // them produces byte-identical reports.
    for mode in [
        AccessMode::CpuGather,
        AccessMode::UnifiedNaive,
        AccessMode::UnifiedAligned,
        AccessMode::Uvm,
        AccessMode::GpuResident,
    ] {
        let base = trainer_cfg(mode);
        let mut cranked = trainer_cfg(mode);
        cranked.page_rows = 64;
        cranked.eviction = EvictionPolicy::Clock;
        let r_base = Trainer::new(base).unwrap().run_epoch().unwrap();
        let r_cranked = Trainer::new(cranked).unwrap().run_epoch().unwrap();
        assert_reports_bit_equal(&r_base, &r_cranked, &format!("{mode:?} knob inertness"));
    }
}

#[test]
fn losses_are_bitwise_invariant_across_policies_and_page_sizes() {
    // The repo's single-source-of-truth invariant extended to the new
    // knobs: placement policy and page granularity may move cost, never
    // numerics.  Reference: the untouched default config per mode.
    for mode in [AccessMode::Tiered, AccessMode::Sharded, AccessMode::Nvme] {
        let reference = Trainer::new(trainer_cfg(mode)).unwrap().run_epoch().unwrap();
        for policy in EvictionPolicy::all() {
            for page_rows in [1usize, 8] {
                let mut c = trainer_cfg(mode);
                c.eviction = policy;
                c.page_rows = page_rows;
                let r = Trainer::new(c).unwrap().run_epoch().unwrap();
                assert_eq!(
                    r.losses, reference.losses,
                    "{mode:?} {policy:?} page_rows={page_rows}: losses diverged"
                );
                assert_eq!(
                    r.accs, reference.accs,
                    "{mode:?} {policy:?} page_rows={page_rows}: accuracies diverged"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Refcount invariants
// ---------------------------------------------------------------------------

#[test]
fn refcounts_return_to_zero_after_every_gather() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let page_rows = g.usize_in(1, 16);
        let policy = *g.choose(&EvictionPolicy::all());
        let cap = g.usize_in(0, rows);
        let ranking: Vec<u32> = (0..rows as u32).collect();
        let mut cache = PageCache::build(rows, 64, page_rows, policy, cap, Some(&ranking));
        for idx in random_gathers(g, rows) {
            cache.record(&idx);
            prop_assert(
                cache.pinned_pages() == 0,
                format!("{policy:?}: pages left pinned after record"),
            )?;
            for p in 0..cache.num_pages() as u32 {
                prop_assert(
                    cache.refcount_of(p) == 0,
                    format!("{policy:?}: page {p} refcount nonzero after record"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn pinned_pages_survive_arbitrary_traffic_and_unpin_balances() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(4, 300);
        let page_rows = g.usize_in(1, 8);
        let policy = *g.choose(&[EvictionPolicy::Lfu, EvictionPolicy::Lru, EvictionPolicy::Clock]);
        let cap = g.usize_in(1, rows);
        let ranking: Vec<u32> = (0..rows as u32).collect();
        let mut cache = PageCache::build(rows, 64, page_rows, policy, cap, Some(&ranking));

        // Pin a random subset of whatever is resident.
        let resident = cache.resident_page_ids();
        let pin_pages: Vec<u32> = resident
            .iter()
            .copied()
            .filter(|_| g.bool())
            .collect();
        let pin_rows: Vec<u32> = pin_pages
            .iter()
            .map(|&p| p * page_rows as u32) // first row of each pinned page
            .collect();
        cache.pin_rows(&pin_rows);

        for idx in random_gathers(g, rows) {
            cache.record(&idx);
            for &p in &pin_pages {
                prop_assert(
                    cache.is_resident_page(p),
                    format!("{policy:?}: pinned page {p} was evicted"),
                )?;
                prop_assert(
                    cache.refcount_of(p) > 0,
                    format!("{policy:?}: pinned page {p} lost its refcount"),
                )?;
            }
        }

        cache.unpin_rows(&pin_rows);
        prop_assert(cache.pinned_pages() == 0, "unpin did not balance the pin")?;
        for p in 0..cache.num_pages() as u32 {
            prop_assert(
                cache.refcount_of(p) == 0,
                format!("page {p} refcount nonzero after balanced unpin"),
            )?;
        }
        let s = cache.stats();
        prop_assert(s.pins == s.unpins, "pin/unpin counters unbalanced")
    });
}

#[test]
fn store_level_pins_balance_and_never_change_gathered_values() {
    // FeatureStore-level: pin/unpin around gathers is invisible to the
    // data (placement metadata only) and the tier counters balance.
    let sys = SystemProfile::system1();
    check(10, |g: &mut Gen| {
        let rows = g.usize_in(4, 200);
        let dim = g.usize_in(1, 24);
        let cfg = TierConfig {
            hot_frac: g.f64_in(0.1, 1.0),
            reserve_bytes: 0,
            promote: g.bool(),
            ranking: None,
            page_rows: g.usize_in(1, 8),
            eviction: *g.choose(&EvictionPolicy::all()),
        };
        let plain = FeatureStore::build_tiered(rows, dim, 8, &sys, 7, cfg.clone())
            .map_err(|e| e.to_string())?;
        let pinned = FeatureStore::build_tiered(rows, dim, 8, &sys, 7, cfg)
            .map_err(|e| e.to_string())?;
        for idx in random_gathers(g, rows) {
            let (want, _) = plain.gather(&idx).map_err(|e| e.to_string())?;
            let (got, _) = pinned.gather(&idx).map_err(|e| e.to_string())?;
            pinned.pin_rows(&idx);
            pinned.unpin_rows(&idx);
            prop_assert(got == want, "pinning changed gathered values")?;
        }
        let s = pinned.tier_stats().expect("tiered store has stats");
        prop_assert(s.pins == s.unpins, "store-level pin/unpin counters unbalanced")
    });
}

// ---------------------------------------------------------------------------
// 4. Residency conservation
// ---------------------------------------------------------------------------

#[test]
fn residency_never_exceeds_the_budget_and_pages_partition_the_rows() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(1, 400);
        let page_rows = g.usize_in(1, 32);
        let policy = *g.choose(&EvictionPolicy::all());
        let cap = g.usize_in(0, rows + page_rows);
        let ranking = random_ranking(g, rows);
        let mut cache =
            PageCache::build(rows, 64, page_rows, policy, cap, ranking.as_deref());

        // Pages partition the row space: every row lands in exactly one
        // page, and the page spans tile [0, rows) without overlap.
        let num_pages = cache.num_pages();
        prop_assert(
            num_pages == rows.div_ceil(page_rows),
            "page count is not ceil(rows / page_rows)",
        )?;
        let span_sum: usize = (0..num_pages).map(|p| cache.page_span(p)).sum();
        prop_assert(span_sum == rows, "page spans do not tile the table")?;
        for r in 0..rows as u32 {
            prop_assert(
                cache.page_of(r) == r / page_rows as u32,
                format!("row {r} maps to the wrong page"),
            )?;
        }

        for idx in random_gathers(g, rows) {
            cache.record(&idx);
            prop_assert(
                cache.resident_pages() <= cache.capacity_pages(),
                format!(
                    "{policy:?}: {} resident pages exceed budget {}",
                    cache.resident_pages(),
                    cache.capacity_pages()
                ),
            )?;
            prop_assert(
                cache.resident_rows() <= cache.capacity_pages() * cache.page_rows(),
                format!("{policy:?}: resident rows exceed the row budget"),
            )?;
            let by_span: usize = cache
                .resident_page_ids()
                .iter()
                .map(|&p| cache.page_span(p as usize))
                .sum();
            prop_assert(
                by_span == cache.resident_rows(),
                format!("{policy:?}: resident-row gauge diverges from page spans"),
            )?;
        }
        Ok(())
    });
}

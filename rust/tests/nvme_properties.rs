//! Property-based tests (via the in-tree `util::proptest` harness) for the
//! NVMe three-tier store's invariants (DESIGN.md §8):
//!
//!  * GPU hits + host rows + storage rows equal the rows requested,
//!    whatever the placement, promotion history, or host fraction;
//!  * the `host_frac` endpoints reproduce the reference modes: 1.0 is
//!    bit-exactly the tiered cost model (nothing spills), 0.0 with a cold
//!    GPU tier serves every row from storage;
//!  * block-read I/O amplification is always ≥ 1, the SSD's link bytes
//!    are exactly `ios × block_bytes`, and duplicate rows never re-read;
//!  * gathered values always match `SyntheticFeatures::fill_row` — the
//!    storage split is placement metadata, never a second copy;
//!  * deepening the NVMe queue never makes a read slower (the
//!    queue-depth bound is monotone).

use ptdirect::config::SystemProfile;
use ptdirect::featurestore::{FeatureStore, NvmeStoreConfig, SyntheticFeatures, TierConfig};
use ptdirect::interconnect::{count_block_ios, NvmeLink};
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

fn random_nvme_cfg(g: &mut Gen, rows: usize) -> NvmeStoreConfig {
    let ranking = if g.bool() {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(g.seed ^ 0xC0FFEE).shuffle(&mut order);
        Some(order)
    } else {
        None
    };
    NvmeStoreConfig {
        host_frac: g.f64_in(0.0, 1.0),
        tier: TierConfig {
            hot_frac: g.f64_in(0.0, 1.0),
            reserve_bytes: 0,
            promote: g.bool(),
            ranking,
            ..TierConfig::default()
        },
    }
}

fn random_gathers(g: &mut Gen, rows: usize) -> Vec<Vec<u32>> {
    let n_gathers = g.usize_in(1, 6);
    (0..n_gathers)
        .map(|_| {
            let len = g.usize_in(1, 200);
            g.vec_u32(len, 0, (rows - 1) as u32)
        })
        .collect()
}

#[test]
fn rows_conserve_across_the_three_tiers() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 400);
        let dim = g.usize_in(1, 64);
        let cfg = random_nvme_cfg(g, rows);
        let host_cap = (cfg.host_frac * rows as f64).floor() as usize;
        let store = FeatureStore::build_nvme(rows, dim, 8, &SystemProfile::system1(), g.seed, cfg)
            .map_err(|e| e.to_string())?;
        let mut requested = 0u64;
        for idx in random_gathers(g, rows) {
            store.gather(&idx).map_err(|e| e.to_string())?;
            requested += idx.len() as u64;
        }
        let stats = store.nvme_stats().expect("nvme store has stats");
        prop_assert(
            stats.rows_served() == requested,
            format!(
                "gpu {} + host {} + storage {} != requested {requested}",
                stats.tier.hits, stats.host_rows, stats.storage_rows
            ),
        )?;
        prop_assert(
            stats.host_resident_rows == host_cap
                && stats.spilled_rows == rows - host_cap,
            format!(
                "placement split {}/{} violates host_frac cap {host_cap} of {rows}",
                stats.host_resident_rows, stats.spilled_rows
            ),
        )
    });
}

#[test]
fn io_amplification_at_least_one_and_link_bytes_are_block_granular() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 400);
        let dim = g.usize_in(1, 64);
        let sys = SystemProfile::system1();
        let cfg = random_nvme_cfg(g, rows);
        let store = FeatureStore::build_nvme(rows, dim, 8, &sys, g.seed, cfg)
            .map_err(|e| e.to_string())?;
        for idx in random_gathers(g, rows) {
            let (_, cost) = store.gather(&idx).map_err(|e| e.to_string())?;
            prop_assert(
                cost.split.local_bytes + cost.split.host_bytes + cost.split.storage_bytes
                    == cost.useful_bytes,
                "per-gather byte split does not cover the batch",
            )?;
        }
        let stats = store.nvme_stats().unwrap();
        prop_assert(
            stats.amplification() >= 1.0 - 1e-12,
            format!("amplification {} < 1", stats.amplification()),
        )?;
        prop_assert(
            stats.storage_bytes_on_link == stats.ios * sys.nvme.block_bytes,
            format!(
                "link bytes {} != {} IOs x {} B blocks",
                stats.storage_bytes_on_link, stats.ios, sys.nvme.block_bytes
            ),
        )?;
        prop_assert(
            stats.storage_bytes_on_link >= stats.storage_distinct_bytes,
            "block reads must cover every distinct requested byte",
        )
    });
}

#[test]
fn host_frac_one_is_bit_exactly_tiered() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let sys = SystemProfile::system1();
        let seed = g.seed;
        let mut cfg = random_nvme_cfg(g, rows);
        cfg.host_frac = 1.0;
        let tier_cfg = cfg.tier.clone();
        let nvme = FeatureStore::build_nvme(rows, dim, 8, &sys, seed, cfg)
            .map_err(|e| e.to_string())?;
        let tiered = FeatureStore::build_tiered(rows, dim, 8, &sys, seed, tier_cfg)
            .map_err(|e| e.to_string())?;
        for idx in random_gathers(g, rows) {
            let (_, nv) = nvme.gather(&idx).map_err(|e| e.to_string())?;
            let (_, ti) = tiered.gather(&idx).map_err(|e| e.to_string())?;
            prop_assert(
                nv.time_s == ti.time_s
                    && nv.bytes_on_link == ti.bytes_on_link
                    && nv.requests == ti.requests
                    && nv.useful_bytes == ti.useful_bytes,
                format!(
                    "host_frac 1 diverged from tiered: {} vs {} s, {} vs {} B",
                    nv.time_s, ti.time_s, nv.bytes_on_link, ti.bytes_on_link
                ),
            )?;
            prop_assert(nv.split.storage_bytes == 0, "host_frac 1 read storage")?;
        }
        Ok(())
    });
}

#[test]
fn host_frac_zero_with_cold_gpu_tier_serves_everything_from_storage() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let sys = SystemProfile::system1();
        let cfg = NvmeStoreConfig {
            host_frac: 0.0,
            tier: TierConfig {
                hot_frac: 0.0,
                reserve_bytes: 0,
                promote: false,
                ranking: None,
                ..TierConfig::default()
            },
        };
        let store = FeatureStore::build_nvme(rows, dim, 8, &sys, g.seed, cfg)
            .map_err(|e| e.to_string())?;
        let mut requested = 0u64;
        for idx in random_gathers(g, rows) {
            let (_, cost) = store.gather(&idx).map_err(|e| e.to_string())?;
            requested += idx.len() as u64;
            prop_assert(
                cost.split.host_bytes == 0 && cost.split.local_bytes == 0,
                "fully spilled store leaked rows to a faster tier",
            )?;
        }
        let stats = store.nvme_stats().unwrap();
        prop_assert(
            stats.storage_rows == requested && stats.host_rows == 0,
            format!("storage {} / host {} != {requested} / 0", stats.storage_rows, stats.host_rows),
        )
    });
}

#[test]
fn gathered_values_match_fill_row_regardless_of_spill_placement() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 200);
        let dim = g.usize_in(1, 48);
        let classes = 8u32;
        let seed = g.seed ^ 0xFEA7;
        let cfg = random_nvme_cfg(g, rows);
        let store =
            FeatureStore::build_nvme(rows, dim, classes, &SystemProfile::system1(), seed, cfg)
                .map_err(|e| e.to_string())?;
        let synth = SyntheticFeatures::new(dim, classes, seed);
        let mut want_row = vec![0f32; dim];
        for idx in random_gathers(g, rows) {
            let (vals, _) = store.gather(&idx).map_err(|e| e.to_string())?;
            for (chunk, &r) in vals.chunks_exact(dim).zip(&idx) {
                synth.fill_row(r, &mut want_row);
                prop_assert(
                    chunk == want_row.as_slice(),
                    format!("row {r} diverged from SyntheticFeatures::fill_row"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn deeper_queues_never_slow_a_read_down() {
    check(30, |g: &mut Gen| {
        let slots = g.vec_u32(g.usize_in(1, 500), 0, 50_000);
        let row_bytes = g.u64_in(4, 8192);
        let mut sys = SystemProfile::system1();
        let traffic = count_block_ios(&slots, row_bytes, sys.nvme.block_bytes);
        let mut last = f64::INFINITY;
        for qd in [1u32, 4, 16, 64, 256, 4096] {
            sys.nvme.queue_depth = qd;
            let t = NvmeLink::new(&sys).read(&traffic).time_s;
            prop_assert(
                t <= last + 1e-15,
                format!("read got slower when queue depth grew to {qd}"),
            )?;
            last = t;
        }
        Ok(())
    });
}

#[test]
fn duplicate_rows_in_a_batch_never_reread_blocks() {
    check(25, |g: &mut Gen| {
        let base = g.vec_u32(g.usize_in(1, 200), 0, 10_000);
        let row_bytes = g.u64_in(4, 4096);
        let bs = 4096;
        let once = count_block_ios(&base, row_bytes, bs);
        let mut doubled = base.clone();
        doubled.extend_from_slice(&base);
        let twice = count_block_ios(&doubled, row_bytes, bs);
        prop_assert(
            twice.ios == once.ios && twice.bytes_on_link == once.bytes_on_link,
            format!("duplicated batch re-read blocks: {} -> {}", once.ios, twice.ios),
        )?;
        prop_assert(
            twice.useful_bytes == 2 * once.useful_bytes
                && twice.distinct_bytes == once.distinct_bytes,
            "useful/distinct byte accounting wrong under duplication",
        )
    });
}

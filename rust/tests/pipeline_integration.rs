//! Integration over the streaming pipeline with real sampler + feature
//! store stages, plus failure injection.

use std::sync::Mutex;

use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::error::Error;
use ptdirect::featurestore::FeatureStore;
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::pipeline::executor::run_pipeline;
use ptdirect::pipeline::queue::BoundedQueue;
use ptdirect::sampler::NeighborSampler;
use ptdirect::util::rng::Rng;

#[test]
fn pipelined_epoch_with_real_stages() {
    let sys = SystemProfile::system1();
    let graph = rmat(2000, 20_000, RmatParams::default(), 5).unwrap();
    let store =
        FeatureStore::build(2000, 32, 8, AccessMode::UnifiedAligned, &sys, 5).unwrap();
    let sampler = NeighborSampler::new(&graph, &[3, 3], 8);
    let rng = Mutex::new(Rng::new(9));

    let total_rows = Mutex::new(0usize);
    let report = run_pipeline(
        40,
        4,
        |i| {
            let seeds: Vec<u32> = (0..16u32).map(|k| (i as u32 * 16 + k) % 2000).collect();
            Ok(sampler.sample(&seeds, &mut rng.lock().unwrap()))
        },
        |mb| {
            let (x0, cost) = store.gather(&mb.src_nodes)?;
            Ok((mb, x0, cost))
        },
        |(mb, x0, _cost)| {
            assert_eq!(x0.len(), mb.src_nodes.len() * 32);
            *total_rows.lock().unwrap() += mb.src_nodes.len();
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(report.items, 40);
    // 16 roots * (1+3) * (1+3) = 256 rows per batch
    assert_eq!(*total_rows.lock().unwrap(), 40 * 256);
    assert!(report.stages.sample_s > 0.0 && report.stages.gather_s > 0.0);
}

#[test]
fn gather_failure_mid_pipeline_aborts_without_hanging() {
    let sys = SystemProfile::system1();
    let graph = rmat(500, 3000, RmatParams::default(), 6).unwrap();
    let store = FeatureStore::build(500, 8, 4, AccessMode::CpuGather, &sys, 6).unwrap();
    let sampler = NeighborSampler::new(&graph, &[2], 4);
    let rng = Mutex::new(Rng::new(1));

    let r = run_pipeline(
        100,
        2,
        |i| {
            let seeds: Vec<u32> = vec![(i % 500) as u32; 4];
            Ok((i, sampler.sample(&seeds, &mut rng.lock().unwrap())))
        },
        |(i, mb)| {
            if i == 7 {
                // inject an out-of-bounds gather
                store.gather(&[9999]).map(|_| ())?;
            }
            let (x0, _) = store.gather(&mb.src_nodes)?;
            Ok(x0)
        },
        |_x0| Ok(()),
    );
    match r {
        Err(Error::IndexOutOfBounds { .. }) => {}
        Err(e) => panic!("unexpected error {e}"),
        Ok(_) => panic!("expected injected failure"),
    }
}

#[test]
fn closed_queue_rejects_producers_immediately() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    q.push(1).unwrap();
    q.close();
    assert!(q.push(2).is_err());
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), None);
}

#[test]
fn deep_pipeline_stress_no_deadlock() {
    // Rapid-fire tiny items through depth-1 queues from multiple runs; a
    // regression guard for the close-on-error protocol.
    for round in 0..5u64 {
        let fail_at = round * 13 + 3;
        let _ = run_pipeline(
            64,
            1,
            |i| Ok(i),
            move |b| {
                if b == fail_at {
                    Err(Error::Pipeline("boom".into()))
                } else {
                    Ok(b)
                }
            },
            |_f| Ok(()),
        );
    }
}

//! End-to-end integration: the whole stack (graph -> sampler -> feature
//! store -> PJRT train step) across access modes.

use ptdirect::config::{AccessMode, RunConfig};
use ptdirect::coordinator::Trainer;

fn artifacts_present() -> bool {
    let ok = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: 8,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        ..RunConfig::default()
    }
}

#[test]
fn access_mode_changes_cost_not_numerics() {
    // The paper's core correctness property: unified-tensor access is a
    // *transfer* optimization — identically seeded runs in Py and PyD mode
    // must produce bitwise-identical loss sequences.
    if !artifacts_present() {
        return;
    }
    let mut losses = Vec::new();
    for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned] {
        let mut t = Trainer::new(cfg(mode)).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(r.steps, 8);
        losses.push(r.losses.clone());
    }
    assert_eq!(losses[0], losses[1], "Py and PyD numerics diverged");
}

#[test]
fn pyd_epoch_is_faster_and_cooler_in_sim() {
    if !artifacts_present() {
        return;
    }
    let mut t_py = Trainer::new(cfg(AccessMode::CpuGather)).unwrap();
    let py = t_py.run_epoch().unwrap();
    let mut t_pyd = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let pyd = t_pyd.run_epoch().unwrap();
    assert!(py.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
    assert!(py.breakdown_sim.total_s() > pyd.breakdown_sim.total_s());
    assert!(py.power.watts > pyd.power.watts);
    // non-transfer components nearly identical (paper §5.4)
    let rel = |a: f64, b: f64| (a - b).abs() / a.max(1e-12);
    assert!(rel(py.breakdown_sim.sample_s, pyd.breakdown_sim.sample_s) < 1e-9);
    assert!(rel(py.breakdown_sim.train_s, pyd.breakdown_sim.train_s) < 1e-9);
}

#[test]
fn multi_epoch_training_converges() {
    if !artifacts_present() {
        return;
    }
    let mut c = cfg(AccessMode::UnifiedAligned);
    c.steps_per_epoch = 18;
    let mut t = Trainer::new(c).unwrap();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..8 {
        let r = t.run_epoch().unwrap();
        if first_loss.is_none() {
            first_loss = r.losses.first().copied();
        }
        last_loss = r.final_loss();
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.75,
        "no convergence: {first} -> {last_loss}"
    );
}

#[test]
fn uvm_mode_runs_and_is_slower_than_pyd() {
    if !artifacts_present() {
        return;
    }
    // The paper's regime: the feature table exceeds GPU memory, so UVM
    // thrashes (with a roomy GPU and a tiny test table, UVM would simply
    // cache everything and win — which is why the paper's baselines only
    // use UVM as a strawman for *oversized* graphs).
    let mut c_uvm = cfg(AccessMode::Uvm);
    c_uvm.system.gpu_mem_bytes = 64 << 10;
    let mut t_uvm = Trainer::new(c_uvm).unwrap();
    let uvm = t_uvm.run_epoch().unwrap();
    let mut t_pyd = Trainer::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let pyd = t_pyd.run_epoch().unwrap();
    assert_eq!(uvm.losses, pyd.losses, "UVM numerics must match too");
    assert!(uvm.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
}

#[test]
fn gpu_resident_gated_by_capacity() {
    if !artifacts_present() {
        return;
    }
    let mut c = cfg(AccessMode::GpuResident);
    c.system.gpu_mem_bytes = 1 << 16; // 64 KiB "GPU"
    match Trainer::new(c) {
        Err(ptdirect::Error::GpuOom { .. }) => {}
        Err(e) => panic!("expected GpuOom, got {e}"),
        Ok(_) => panic!("expected GpuOom, trainer built"),
    }
}

#[test]
fn inference_path_serves_batches() {
    // Forward-only serving over the same data path (paper §4.1: training
    // *and inference*); accuracy with untrained params ~ chance.
    if !artifacts_present() {
        return;
    }
    let mut runner =
        ptdirect::coordinator::InferenceRunner::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r = runner.run(6).unwrap();
    assert_eq!(r.batches, 6);
    assert!(r.exec_latency.median() > 0.0);
    assert!(r.sim_latency.median() > 0.0);
    assert!((0.0..=1.0).contains(&r.accuracy));
    assert!(r.breakdown_sim.transfer_s > 0.0);
}

#[test]
fn artifact_config_mismatch_is_rejected() {
    if !artifacts_present() {
        return;
    }
    let mut c = cfg(AccessMode::UnifiedAligned);
    c.batch = 32; // artifacts were built for batch 64
    assert!(Trainer::new(c).is_err());
}

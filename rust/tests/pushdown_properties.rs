//! Cross-layer properties of aggregation push-down
//! (`sampler::aggregate::AggregatePlan` + `FeatureStore::pushdown_cost`,
//! DESIGN.md §14):
//!
//! * **numerics** — the pinned-order reduction is bitwise identical in
//!   all eight access modes at every storage precision (the aggregate is
//!   computed once from the gathered block; placement can never touch
//!   it), and the trainer's loss/accuracy trajectories are bitwise
//!   identical with the knob on or off;
//! * **traffic** — with fanout > 1, push-down strictly reduces the
//!   simulated link bytes in every transfer-paying mode (uvm is priced
//!   but not gated — DESIGN.md §14 documents its ideal-link compromise);
//! * **composition** — dedup shrinks the pushed self stream, leaves the
//!   aggregate stream untouched, and the composed run still beats raw;
//! * **anchoring** — `--no-pushdown` runs are bit-reproducible with an
//!   all-zero push-down report and no near-memory power term (the
//!   pre-PR accounting, untouched);
//! * **bookkeeping** — pushed-down epochs leave every page-cache pin
//!   balanced (`pins == unpins`, nothing blocked).

use ptdirect::config::{AccessMode, Backend, Precision, RunConfig, ShardPolicy, SystemProfile};
use ptdirect::coordinator::{ServingEngine, Trainer};
use ptdirect::featurestore::FeatureStore;
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::sampler::{AggregatePlan, NeighborSampler};
use ptdirect::util::rng::Rng;

const STEPS: u32 = 6;

/// Hermetic config mirroring `dedup_properties.rs`: native backend, no
/// artifacts, sharded runs get real partitioning.
fn cfg(mode: AccessMode, pushdown: bool) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        aggregate_pushdown: pushdown,
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        ..RunConfig::default()
    }
}

#[test]
fn reduction_bitwise_identical_across_modes_and_precisions() {
    // The pushed-down aggregate is defined as the pinned ascending-id
    // reduction over the gathered block — gather values are mode-invariant
    // at a fixed precision, so the aggregate must be too, bit for bit.
    let sys = SystemProfile::system1();
    let g = rmat(500, 5000, RmatParams::default(), 11).unwrap();
    let s = NeighborSampler::new(&g, &[7], 8);
    let mut rng = Rng::new(3);
    let seeds: Vec<u32> = (0..24u32).map(|i| i * 19 % 500).collect();
    let mb = s.sample(&seeds, &mut rng);
    let plan = AggregatePlan::build(&mb).unwrap();
    let f = 16usize;
    for precision in Precision::all() {
        let mut reference: Option<Vec<u32>> = None;
        for mode in AccessMode::all() {
            let st = FeatureStore::build_quantized(
                500, f, 8, mode, &sys, 7, precision, None, None, None,
            )
            .unwrap();
            let (x0, _) = st.gather(&mb.src_nodes).unwrap();
            let mut agg = vec![0f32; plan.n_dst() * f];
            let mut counts = vec![0u32; plan.n_dst()];
            plan.aggregate_gathered(&x0, f, &mut agg, &mut counts).unwrap();
            assert_eq!(counts, plan.counts(), "{mode:?} {precision:?}");
            let bits: Vec<u32> = agg.iter().map(|v| v.to_bits()).collect();
            match &reference {
                None => reference = Some(bits),
                Some(r) => assert_eq!(
                    &bits, r,
                    "{mode:?} {precision:?}: placement changed the aggregate"
                ),
            }
        }
    }
}

#[test]
fn losses_bitwise_identical_with_pushdown_on_and_off_in_all_modes() {
    // Push-down is a pricing change only: the training numerics may never
    // notice the knob, in any mode.
    for mode in AccessMode::all() {
        let r_on = Trainer::new(cfg(mode, true)).unwrap().run_epoch().unwrap();
        let r_off = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&r_on.losses), bits(&r_off.losses), "{mode:?}: loss trajectory moved");
        assert_eq!(bits(&r_on.accs), bits(&r_off.accs), "{mode:?}: accuracy trajectory moved");
    }
}

#[test]
fn pushdown_strictly_reduces_link_bytes_in_every_transfer_paying_mode() {
    // Default fanouts are > 1, so the aggregate stream (one row + count
    // per destination) must strictly undercut shipping raw neighbor rows
    // wherever a link is paid at all.
    for mode in [
        AccessMode::CpuGather,
        AccessMode::UnifiedNaive,
        AccessMode::UnifiedAligned,
        AccessMode::Tiered,
        AccessMode::Sharded,
        AccessMode::Nvme,
    ] {
        let r_on = Trainer::new(cfg(mode, true)).unwrap().run_epoch().unwrap();
        let r_off = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        assert!(r_on.pushdown.enabled, "{mode:?}");
        assert_eq!(
            r_on.pushdown.raw_bytes_on_link, r_off.bytes_on_link,
            "{mode:?}: raw side of the report must be the off-run's bytes"
        );
        assert_eq!(
            r_on.bytes_on_link, r_on.pushdown.pushed_bytes_on_link,
            "{mode:?}: epoch accounting must price the pushed stream"
        );
        assert!(
            r_on.bytes_on_link < r_off.bytes_on_link,
            "{mode:?}: pushed {} !< raw {}",
            r_on.bytes_on_link,
            r_off.bytes_on_link
        );
        assert!(r_on.pushdown.reduction() > 1.0, "{mode:?}");
        assert!(r_on.pushdown.near_mem_flops > 0, "{mode:?}: no near-memory work recorded");
        assert!(r_on.pushdown.near_mem_s > 0.0, "{mode:?}");
    }
    // GpuResident: nothing crosses a link either way, and every neighbor
    // is local — no near-memory work at all.
    let r = Trainer::new(cfg(AccessMode::GpuResident, true)).unwrap().run_epoch().unwrap();
    assert_eq!(r.bytes_on_link, 0);
    assert_eq!(r.pushdown.pushed_bytes_on_link, 0);
    assert_eq!(r.pushdown.near_mem_flops, 0);
    // Uvm: priced (report populated) but not byte-gated (DESIGN.md §14).
    let r = Trainer::new(cfg(AccessMode::Uvm, true)).unwrap().run_epoch().unwrap();
    assert!(r.pushdown.enabled);
    assert!(r.pushdown.pushed_bytes_on_link > 0);
}

#[test]
fn pushdown_composes_with_dedup() {
    // dedup off vs on, push-down on in both: dedup may only shrink the
    // (self-stream) bytes further, and both stay under their raw
    // counterparts — the two optimizations multiply, never fight.
    for mode in [AccessMode::UnifiedAligned, AccessMode::Tiered, AccessMode::Nvme] {
        let mut c_nd = cfg(mode, true);
        c_nd.dedup = false;
        let r_push_nodedup = Trainer::new(c_nd).unwrap().run_epoch().unwrap();
        let r_push_dedup = Trainer::new(cfg(mode, true)).unwrap().run_epoch().unwrap();
        let mut c_raw_nd = cfg(mode, false);
        c_raw_nd.dedup = false;
        let r_raw_nodedup = Trainer::new(c_raw_nd).unwrap().run_epoch().unwrap();
        assert!(
            r_push_dedup.bytes_on_link <= r_push_nodedup.bytes_on_link,
            "{mode:?}: dedup worsened the pushed stream"
        );
        assert!(
            r_push_nodedup.bytes_on_link < r_raw_nodedup.bytes_on_link,
            "{mode:?}: push-down alone must beat raw"
        );
        assert!(
            r_push_dedup.bytes_on_link < r_raw_nodedup.bytes_on_link,
            "{mode:?}: composed must beat raw"
        );
        // The aggregate stream itself is per-destination and therefore
        // untouched by self-stream dedup.
        assert_eq!(
            r_push_dedup.pushdown.agg_bytes_on_link,
            r_push_nodedup.pushdown.agg_bytes_on_link,
            "{mode:?}"
        );
    }
}

#[test]
fn no_pushdown_anchor_is_bit_reproducible_and_report_free() {
    // The off-path never calls pushdown_cost, so two identical off runs
    // must agree bit for bit and carry an empty report — the pre-PR
    // accounting, untouched.
    for mode in [AccessMode::CpuGather, AccessMode::Tiered, AccessMode::Nvme] {
        let a = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        let b = Trainer::new(cfg(mode, false)).unwrap().run_epoch().unwrap();
        assert_eq!(a.losses, b.losses, "{mode:?}");
        assert_eq!(a.bytes_on_link, b.bytes_on_link, "{mode:?}");
        assert_eq!(a.requests, b.requests, "{mode:?}");
        assert_eq!(a.breakdown_sim.transfer_s, b.breakdown_sim.transfer_s, "{mode:?}");
        assert!(!a.pushdown.enabled, "{mode:?}");
        assert_eq!(a.pushdown.pushed_bytes_on_link, 0, "{mode:?}");
        assert_eq!(a.pushdown.raw_bytes_on_link, 0, "{mode:?}");
        assert_eq!(a.pushdown.near_mem_flops, 0, "{mode:?}");
        assert_eq!(a.power.near_mem_util, 0.0, "{mode:?}: near-mem power without pushdown");
    }
}

#[test]
fn pushed_down_epochs_leave_page_cache_pins_balanced() {
    // pushdown_cost walks residency read-only; the physical gather still
    // pins and unpins pages.  After a pushed-down epoch the books must
    // balance exactly as they do without the knob.
    let r = Trainer::new(cfg(AccessMode::Tiered, true)).unwrap().run_epoch().unwrap();
    let tier = r.tier.expect("tiered run reports tier stats");
    assert_eq!(tier.pins, tier.unpins, "unbalanced pins under pushdown");
    assert_eq!(tier.pin_blocked, 0);
    let r = Trainer::new(cfg(AccessMode::Nvme, true)).unwrap().run_epoch().unwrap();
    let nvme = r.nvme.expect("nvme run reports storage stats");
    assert_eq!(nvme.tier.pins, nvme.tier.unpins, "unbalanced nvme pins under pushdown");
    assert_eq!(nvme.tier.pin_blocked, 0);
}

#[test]
fn serving_prices_per_request_pushdown() {
    // The serving engine prices aggregates per admitted request (no
    // cross-request merging on the aggregate streams) and must still
    // undercut the raw coalesced gather.
    let mut c = cfg(AccessMode::UnifiedAligned, true);
    c.serve_requests = 24;
    c.arrival_rps = 50_000.0;
    c.admit_depth = 4096;
    let r = ServingEngine::new(c).unwrap().run().unwrap();
    assert!(r.pushdown.enabled);
    assert!(r.pushdown.pushed_bytes_on_link > 0);
    assert!(
        r.pushdown.pushed_bytes_on_link < r.pushdown.raw_bytes_on_link,
        "pushed {} !< raw {}",
        r.pushdown.pushed_bytes_on_link,
        r.pushdown.raw_bytes_on_link
    );
    assert!(r.pushdown.reduction() > 1.0);
}

//! Integration over the unified-tensor API: the Listing 1 -> Listing 2
//! migration exercised end to end, plus failure injection.

use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::tensor::{index_select, Device, MemAdvise, Tensor};
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

#[test]
fn listing2_migration_workflow() {
    let sys = SystemProfile::system1();
    let mut rng = Rng::new(1);

    // Listing 1: features on CPU, gather via CPU, copy to GPU.
    let features_cpu = Tensor::rand_f32(&[5000, 64], Device::Cpu, &mut rng, -1.0, 1.0);
    let idx: Vec<u32> = (0..256).map(|_| rng.gen_range(5000) as u32).collect();
    let (out_py, rep_py) = index_select(&features_cpu, &idx, AccessMode::CpuGather, &sys).unwrap();

    // Listing 2: two-line change — to("unified"), direct indexing.
    let features_uni = features_cpu.to(Device::Unified);
    let (out_pyd, rep_pyd) =
        index_select(&features_uni, &idx, AccessMode::UnifiedAligned, &sys).unwrap();

    // identical numerics, cheaper transfer, zero CPU time
    assert_eq!(out_py.f32_data(), out_pyd.f32_data());
    assert!(rep_pyd.cost.time_s < rep_py.cost.time_s);
    assert_eq!(rep_pyd.cost.cpu_time_s, 0.0);
    assert!(rep_py.cost.cpu_time_s > 0.0);
    // both outputs landed on the (simulated) GPU
    assert_eq!(out_py.device(), Device::Cuda);
    assert_eq!(out_pyd.device(), Device::Cuda);
}

#[test]
fn placement_rules_through_arithmetic() {
    // Table 1's "unified_tensor + cpu_tensor" on real tensors, then the
    // advanced hints of Table 2.
    let mut u = Tensor::from_f32(&[1.0, 2.0, 3.0], &[3], Device::Unified).unwrap();
    let c = Tensor::from_f32(&[10.0, 10.0, 10.0], &[3], Device::Cpu).unwrap();
    let out = u.add(&c).unwrap();
    assert!(out.is_unified());
    assert!(!out.propagated_to_cuda()); // Table 3 row 1

    u.set_propagated_to_cuda(false).unwrap();
    let out2 = u.add(&c).unwrap();
    assert!(out2.is_unified());

    u.mem_advise(MemAdvise::ReadMostly).unwrap();
    assert_eq!(u.advise(), MemAdvise::ReadMostly);
}

#[test]
fn non_unified_hint_apis_raise() {
    // §4.2: RuntimeError on non-unified tensors.
    for device in [Device::Cpu, Device::Cuda] {
        let mut t = Tensor::zeros(&[4], ptdirect::tensor::DType::F32, device);
        assert!(t.set_propagated_to_cuda(true).is_err());
        assert!(t.mem_advise(MemAdvise::AccessedBy).is_err());
    }
}

#[test]
fn gather_modes_agree_property() {
    // Property: for random tables/indices, every access mode yields the
    // same rows (cost differs; values never).
    let sys = SystemProfile::system1();
    check(20, |g: &mut Gen| {
        let n = g.usize_in(2, 400);
        let f = g.usize_in(1, 96);
        let b = g.usize_in(1, 128);
        let mut rng = Rng::new(g.seed);
        let cpu = Tensor::rand_f32(&[n, f], Device::Cpu, &mut rng, -1.0, 1.0);
        let uni = cpu.to(Device::Unified);
        let idx: Vec<u32> = g.vec_u32(b, 0, (n - 1) as u32);
        let (a, _) = index_select(&cpu, &idx, AccessMode::CpuGather, &sys).unwrap();
        let (c, _) = index_select(&uni, &idx, AccessMode::UnifiedNaive, &sys).unwrap();
        let (d, _) = index_select(&uni, &idx, AccessMode::UnifiedAligned, &sys).unwrap();
        prop_assert(
            a.f32_data() == c.f32_data() && a.f32_data() == d.f32_data(),
            "mode outputs diverged",
        )
    });
}

#[test]
fn allocator_recycles_across_step_like_churn() {
    let before = ptdirect::tensor::tensor::unified_alloc_stats();
    for _ in 0..50 {
        let t = Tensor::zeros(&[2048], ptdirect::tensor::DType::F32, Device::Unified);
        let u = t.to(Device::Unified); // clone-ish path
        drop(u);
        drop(t);
    }
    let after = ptdirect::tensor::tensor::unified_alloc_stats();
    let backing = after.backing_allocs - before.backing_allocs;
    assert!(
        backing <= 2,
        "steady-state churn performed {backing} backing allocations"
    );
}

//! Cross-layer properties of the online serving engine
//! (`coordinator::serving`, DESIGN.md §11):
//!
//! * **coalescing numerics** — merging queued requests into one minibatch
//!   (cross-request gather dedup) leaves every request's scattered feature
//!   block bitwise identical to serving that request alone, in all eight
//!   access modes (rows are copied from one gathered table, never
//!   recomputed — tier placement can shift, values cannot);
//! * **degeneracy** — a single closed-loop client reproduces the batch
//!   inference runner's simulated breakdown bit-exactly (same sampler
//!   stream, same gather plans, same cost accounting), coalescing on or
//!   off;
//! * **load** — mean end-to-end latency is monotone non-decreasing in the
//!   open-loop arrival rate (Lindley: compressing arrivals can only grow
//!   waiting), and `admitted + rejected == offered` always balances;
//! * **coverage** — `serve` completes in every access mode;
//! * **shared residency** — concurrent closed-loop clients stream over
//!   one paged cache (DESIGN.md §12): blocks stay bitwise identical to a
//!   solo run and the combined hit rate never drops under static
//!   placement.

use ptdirect::config::{AccessMode, Backend, RunConfig, ShardPolicy};
use ptdirect::coordinator::{InferenceRunner, ServingEngine};

const REQUESTS: u64 = 24;

/// Hermetic config mirroring `dedup_properties.rs`: native backend, no
/// artifacts, sharded runs get real partitioning.
fn cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        serve_requests: REQUESTS,
        // open loop, fast enough that requests pile up and coalesce
        arrival_rps: 50_000.0,
        admit_depth: 4096, // >= requests: no rejections, so the
        // coalesced and uncoalesced runs serve the identical request set
        ..RunConfig::default()
    }
}

#[test]
fn coalesced_blocks_bitwise_identical_to_uncoalesced_in_all_modes() {
    for mode in AccessMode::all() {
        let mut on = ServingEngine::new(cfg(mode)).unwrap();
        let (r_on, blocks_on) = on.run_with_blocks().unwrap();

        let mut c = cfg(mode);
        c.coalesce = false;
        let mut off = ServingEngine::new(c).unwrap();
        let (r_off, blocks_off) = off.run_with_blocks().unwrap();

        assert_eq!(r_on.completed, REQUESTS, "{mode:?}: coalesced run dropped requests");
        assert_eq!(r_off.completed, REQUESTS, "{mode:?}: uncoalesced run dropped requests");
        assert!(
            r_on.batches < r_on.completed,
            "{mode:?}: arrival burst never coalesced (batches {} of {})",
            r_on.batches,
            r_on.completed
        );
        assert_eq!(r_off.batches, r_off.completed, "{mode:?}: --no-coalesce must not merge");
        for (r, (a, b)) in blocks_on.iter().zip(&blocks_off).enumerate() {
            assert!(!a.is_empty(), "{mode:?}: request {r} served no block");
            assert_eq!(a, b, "{mode:?}: request {r}: coalescing changed the feature block");
        }
    }
}

#[test]
fn coalescing_dedups_across_requests() {
    // The windowed seed rule makes consecutive requests overlap heavily;
    // the coalesced gather must fetch strictly fewer rows than requested.
    let mut e = ServingEngine::new(cfg(AccessMode::UnifiedAligned)).unwrap();
    let r = e.run().unwrap();
    assert!(
        r.unique_rows < r.requested_rows,
        "no cross-request dedup: {} unique of {} requested",
        r.unique_rows,
        r.requested_rows
    );
    assert!(r.dedup_ratio() > 1.0);
}

#[test]
fn single_closed_loop_client_degenerates_to_batch_inference() {
    for mode in AccessMode::all() {
        for coalesce in [true, false] {
            let mut c = cfg(mode);
            c.arrival_rps = 0.0; // closed loop
            c.clients = 1;
            c.coalesce = coalesce;
            let mut engine = ServingEngine::new(c.clone()).unwrap();
            let serve = engine.run().unwrap();

            let mut runner = InferenceRunner::new(c).unwrap();
            let infer = runner.run(REQUESTS).unwrap();

            assert_eq!(serve.completed, REQUESTS);
            assert_eq!(serve.batches, REQUESTS, "{mode:?}: one client must never coalesce");
            let (a, b) = (&serve.breakdown_sim, &infer.breakdown_sim);
            assert_eq!(
                a.sample_s, b.sample_s,
                "{mode:?} coalesce={coalesce}: sampling time diverged from the batch runner"
            );
            assert_eq!(
                a.transfer_s, b.transfer_s,
                "{mode:?} coalesce={coalesce}: transfer time diverged from the batch runner"
            );
            assert_eq!(
                a.train_s, b.train_s,
                "{mode:?} coalesce={coalesce}: execute time diverged from the batch runner"
            );
        }
    }
}

#[test]
fn latency_is_monotone_in_arrival_rate() {
    // Fixed request set and service order (coalescing off), arrivals
    // compressed by rising rps: Lindley's recursion says waiting — hence
    // end-to-end latency — can only grow.
    let mut last = f64::NEG_INFINITY;
    for rps in [200.0, 2_000.0, 20_000.0, 200_000.0] {
        let mut c = cfg(AccessMode::UnifiedAligned);
        c.coalesce = false;
        c.arrival_rps = rps;
        let r = ServingEngine::new(c).unwrap().run().unwrap();
        assert_eq!(r.completed, REQUESTS);
        let mean = r.latency.mean();
        assert!(
            mean >= last - 1e-12,
            "mean latency fell from {last} to {mean} at {rps} rps"
        );
        last = mean;
    }
}

#[test]
fn admission_balances_and_sheds_load() {
    // A queue of 2 under a hard burst must reject, and the books must
    // balance: every offered request is either admitted or rejected, and
    // every admitted request completes.
    let mut c = cfg(AccessMode::CpuGather);
    c.admit_depth = 2;
    c.arrival_rps = 1_000_000.0;
    c.serve_requests = 64;
    let r = ServingEngine::new(c).unwrap().run().unwrap();
    assert_eq!(r.offered, 64);
    assert_eq!(r.admitted + r.rejected, r.offered, "admission books do not balance");
    assert_eq!(r.completed, r.admitted, "admitted requests must all complete");
    assert!(r.rejected > 0, "burst over a depth-2 queue must shed load");
    assert!(r.rejection_rate() > 0.0);
    assert_eq!(r.latency.count(), r.completed);
}

#[test]
fn serve_reports_are_sane_in_all_modes() {
    for mode in AccessMode::all() {
        let mut c = cfg(mode);
        c.serve_requests = 8;
        let r = ServingEngine::new(c).unwrap().run().unwrap();
        assert_eq!(r.completed, 8, "{mode:?}");
        assert_eq!(r.offered, 8, "{mode:?}");
        assert_eq!(r.rejected, 0, "{mode:?}");
        assert!(r.makespan_s > 0.0, "{mode:?}: zero makespan");
        assert!(r.goodput_rps() > 0.0, "{mode:?}");
        assert_eq!(r.latency.count(), 8, "{mode:?}");
        assert!(r.latency.min() >= 0.0, "{mode:?}: negative latency");
        assert!(
            r.latency.percentile(0.999) >= r.latency.percentile(0.50),
            "{mode:?}: tail below median"
        );
        assert!(r.busy.total() > 0.0, "{mode:?}: no resource was ever busy");
    }
}

#[test]
fn concurrent_streams_share_one_cache_without_changing_results() {
    // Two closed-loop clients interleave their requests over the *same*
    // paged cache (one `FeatureStore`, hence one `PageCache`).  Under
    // static placement the residency set never moves, so sharing must be
    // observationally free:
    //  * every request's scattered block is bitwise identical to the
    //    solo run's (values come from one source-of-truth table);
    //  * the combined stream's hit rate is no worse than either solo
    //    client's — with coalescing off and a frozen hot set it is
    //    exactly equal, since hits are a per-row property of placement.
    let base = || {
        let mut c = cfg(AccessMode::Tiered);
        c.arrival_rps = 0.0; // closed loop
        c.coalesce = false; // identical per-request gathers in both runs
        c.tier_promote = false; // static placement: residency never moves
        c
    };

    let mut solo_cfg = base();
    solo_cfg.clients = 1;
    let mut solo = ServingEngine::new(solo_cfg).unwrap();
    let (r_solo, blocks_solo) = solo.run_with_blocks().unwrap();

    let mut shared_cfg = base();
    shared_cfg.clients = 2;
    let mut shared = ServingEngine::new(shared_cfg).unwrap();
    let (r_shared, blocks_shared) = shared.run_with_blocks().unwrap();

    assert_eq!(r_solo.completed, REQUESTS);
    assert_eq!(r_shared.completed, REQUESTS);
    assert_eq!(blocks_solo.len(), blocks_shared.len());
    for (r, (a, b)) in blocks_solo.iter().zip(&blocks_shared).enumerate() {
        assert!(!a.is_empty(), "request {r} served no block");
        assert_eq!(a, b, "request {r}: sharing the cache changed the feature block");
    }

    let t_solo = r_solo.tier.expect("tiered serving must report tier stats");
    let t_shared = r_shared.tier.expect("tiered serving must report tier stats");
    assert_eq!(
        t_solo.hits + t_solo.misses,
        t_shared.hits + t_shared.misses,
        "both runs must look up the same number of rows"
    );
    assert!(
        t_shared.hit_rate() >= t_solo.hit_rate() - 1e-12,
        "sharing the cache hurt the hit rate: {} < {}",
        t_shared.hit_rate(),
        t_solo.hit_rate()
    );
    assert_eq!(
        (t_shared.hits, t_shared.misses, t_shared.evictions),
        (t_solo.hits, t_solo.misses, t_solo.evictions),
        "static placement makes the shared and solo streams hit identically"
    );
    assert_eq!(t_shared.pins, t_shared.unpins, "in-flight pins must all release");
    assert_eq!(t_shared.pin_blocked, 0, "static placement never blocks on pins");
}

#[test]
fn closed_loop_clients_stay_bounded_by_depth() {
    // N closed-loop clients: at most N requests are ever in the system,
    // so a depth >= N queue never rejects and the max depth never
    // exceeds the client count.
    let mut c = cfg(AccessMode::UnifiedAligned);
    c.arrival_rps = 0.0;
    c.clients = 4;
    c.admit_depth = 8;
    c.serve_requests = 32;
    let r = ServingEngine::new(c).unwrap().run().unwrap();
    assert_eq!(r.completed, 32);
    assert_eq!(r.rejected, 0);
    assert!(
        r.max_queue_depth <= 4,
        "queue depth {} exceeds the 4 in-flight clients",
        r.max_queue_depth
    );
}

//! Cross-language fixture: the rust warp/coalescing model must agree with
//! the python specification (`python/compile/coalesce.py`) on pinned
//! numbers, including the paper's Fig. 5 toy example.  The same constants
//! are asserted in `python/tests/test_coalesce.py` — if either side
//! drifts, one of the two suites goes red.

use ptdirect::device::warp::{count_requests, per_row_requests, WarpModel};

/// Paper Fig. 4/5 scaling: warp 4 threads, cacheline 4 elements (16 B),
/// 11 features per node, gather rows [0, 2, 4].
fn fig5_model() -> WarpModel {
    WarpModel {
        warp: 4,
        cl_elems: 4,
        elem_bytes: 4,
    }
}

#[test]
fn fig5_pinned_totals() {
    let idx = [0u32, 2, 4];
    let naive = count_requests(&idx, 11, fig5_model(), false);
    let opt = count_requests(&idx, 11, fig5_model(), true);
    // pinned in python/tests/test_coalesce.py::test_fig5_totals
    assert_eq!(naive.requests, 16);
    assert_eq!(opt.requests, 13);
    assert_eq!(naive.cachelines, 10);
    assert_eq!(opt.cachelines, 10);
    assert_eq!(naive.useful_bytes, 3 * 11 * 4);
}

#[test]
fn fig5_pinned_row2_attribution() {
    let idx = [0u32, 2, 4];
    let naive = per_row_requests(&idx, 11, fig5_model(), false);
    let opt = per_row_requests(&idx, 11, fig5_model(), true);
    // The paper's narration: "Alignment reduces the total number of PCIe
    // requests from 7 to 5 in this case" (the row-2 accesses of Fig. 4/5).
    assert_eq!(naive[1], 7);
    assert_eq!(opt[1], 5);
}

#[test]
fn realistic_2052b_pinned_window() {
    // 513-element (2052 B) rows at real constants; a deterministic index
    // set pinned against the python model.
    let idx: Vec<u32> = (0..64u32).map(|i| i * 7919 % 100_000).collect();
    let model = WarpModel::default();
    let naive = count_requests(&idx, 513, model, false);
    let opt = count_requests(&idx, 513, model, true);
    let ratio = naive.requests as f64 / opt.requests as f64;
    assert!(
        (1.6..2.0).contains(&ratio),
        "naive/opt request ratio {ratio}"
    );
    // amplification bounds: naive near 2x, opt near 1x
    assert!(naive.amplification() > 1.7);
    assert!(opt.amplification() < 1.25);
}

#[test]
fn shift_gate_matches_scan() {
    // The applicability gate (f >= 2*cl, misaligned) — the python scan in
    // test_coalesce.py demonstrates violations below it.
    let m = WarpModel::default();
    assert!(!m.shift_applies(16)); // sub-cacheline
    assert!(!m.shift_applies(33)); // between cl and 2cl
    assert!(!m.shift_applies(64)); // aligned multiple
    assert!(m.shift_applies(65)); // >= 2cl, misaligned
    assert!(m.shift_applies(513)); // the Fig. 7 regime
}

//! Property-based tests (via the in-tree `util::proptest` harness) for the
//! sharded feature store's invariants:
//!
//!  * every placement policy routes every row to exactly one owner GPU,
//!    and the shards cover the full node range;
//!  * local + peer + host rows equal the rows requested, whatever the
//!    placement, policy, or promotion history;
//!  * per-GPU hot-set bytes never exceed the configured budget (GPU
//!    memory minus reserve, capped by the per-shard `hot_frac`);
//!  * gathered values always match `SyntheticFeatures::fill_row` — shard
//!    and tier structures are placement metadata, never a second copy;
//!  * `num_gpus = 1` reproduces the single-GPU tiered cost bit-exactly.

use ptdirect::config::{ShardPolicy, SystemProfile};
use ptdirect::featurestore::{
    assign_owners, FeatureStore, ShardConfig, SyntheticFeatures, TierConfig,
};
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

fn random_policy(g: &mut Gen) -> ShardPolicy {
    *g.choose(&ShardPolicy::all())
}

fn random_shard_cfg(g: &mut Gen, rows: usize) -> ShardConfig {
    let ranking = if g.bool() {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(g.seed ^ 0xC0FFEE).shuffle(&mut order);
        Some(order)
    } else {
        None
    };
    ShardConfig {
        num_gpus: g.usize_in(1, 8),
        policy: random_policy(g),
        tier: TierConfig {
            hot_frac: g.f64_in(0.0, 1.0),
            reserve_bytes: 0,
            promote: g.bool(),
            ranking,
            ..TierConfig::default()
        },
        ..ShardConfig::default()
    }
}

fn random_gathers(g: &mut Gen, rows: usize) -> Vec<Vec<u32>> {
    let n_gathers = g.usize_in(1, 6);
    (0..n_gathers)
        .map(|_| {
            let len = g.usize_in(1, 200);
            g.vec_u32(len, 0, (rows - 1) as u32)
        })
        .collect()
}

#[test]
fn every_policy_routes_every_row_to_exactly_one_owner() {
    check(40, |g: &mut Gen| {
        let rows = g.usize_in(1, 2000);
        let n = g.usize_in(1, 16);
        let ranking: Vec<u32> = (0..rows as u32).rev().collect();
        for policy in ShardPolicy::all() {
            let owner = assign_owners(rows, n, policy, Some(&ranking));
            prop_assert(
                owner.len() == rows,
                format!("{policy:?}: {} owners for {rows} rows", owner.len()),
            )?;
            if let Some(&bad) = owner.iter().find(|&&o| o as usize >= n) {
                return prop_assert(false, format!("{policy:?}: owner {bad} >= {n} GPUs"));
            }
            // Coverage: shard sizes sum back to the full node range.
            let mut sizes = vec![0usize; n];
            for &o in &owner {
                sizes[o as usize] += 1;
            }
            prop_assert(
                sizes.iter().sum::<usize>() == rows,
                format!("{policy:?}: shards do not partition the table"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn local_peer_host_rows_equal_rows_requested() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 400);
        let dim = g.usize_in(1, 64);
        let cfg = random_shard_cfg(g, rows);
        let store =
            FeatureStore::build_sharded(rows, dim, 8, &SystemProfile::system1(), g.seed, cfg)
                .map_err(|e| e.to_string())?;
        let mut requested = 0u64;
        for idx in random_gathers(g, rows) {
            store.gather(&idx).map_err(|e| e.to_string())?;
            requested += idx.len() as u64;
        }
        let totals = store.shard_stats().expect("sharded store has stats").totals();
        prop_assert(
            totals.rows_served() == requested,
            format!(
                "local {} + peer {} + host {} != requested {requested}",
                totals.local_rows, totals.peer_rows, totals.host_rows
            ),
        )
    });
}

#[test]
fn per_gpu_hot_bytes_never_exceed_budget() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let row_bytes = dim as u64 * 4;
        // Shrink the GPU so the budget actually binds, and reserve a slice.
        let mut sys = SystemProfile::system1();
        sys.gpu_mem_bytes = g.u64_in(0, 64) * row_bytes;
        let mut cfg = random_shard_cfg(g, rows);
        cfg.tier.reserve_bytes = g.u64_in(0, 16) * row_bytes;
        cfg.tier.promote = true; // promotion churn must respect budgets too
        let budget = sys.gpu_mem_bytes.saturating_sub(cfg.tier.reserve_bytes);
        let store = FeatureStore::build_sharded(rows, dim, 8, &sys, g.seed, cfg)
            .map_err(|e| e.to_string())?;
        for idx in random_gathers(g, rows) {
            store.gather(&idx).map_err(|e| e.to_string())?;
            for (gpu, s) in store.shard_stats().unwrap().per_gpu.iter().enumerate() {
                prop_assert(
                    s.hot_bytes <= budget && s.hot_bytes <= s.capacity_bytes,
                    format!(
                        "gpu {gpu}: hot {} bytes > budget {budget} (capacity {})",
                        s.hot_bytes, s.capacity_bytes
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn gathered_values_match_fill_row_regardless_of_placement() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 200);
        let dim = g.usize_in(1, 48);
        let classes = 8u32;
        let seed = g.seed ^ 0xFEA7;
        let cfg = random_shard_cfg(g, rows);
        let store = FeatureStore::build_sharded(
            rows,
            dim,
            classes,
            &SystemProfile::system1(),
            seed,
            cfg,
        )
        .map_err(|e| e.to_string())?;
        let synth = SyntheticFeatures::new(dim, classes, seed);
        let mut want_row = vec![0f32; dim];
        for idx in random_gathers(g, rows) {
            let (vals, _) = store.gather(&idx).map_err(|e| e.to_string())?;
            for (chunk, &r) in vals.chunks_exact(dim).zip(&idx) {
                synth.fill_row(r, &mut want_row);
                prop_assert(
                    chunk == want_row.as_slice(),
                    format!("row {r} diverged from SyntheticFeatures::fill_row"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn one_gpu_reproduces_the_tiered_cost_bit_exactly() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let sys = SystemProfile::system1();
        let seed = g.seed;
        let hot_frac = g.f64_in(0.0, 1.0);
        let promote = g.bool();
        let policy = random_policy(g);
        let idx = g.vec_u32(g.usize_in(1, 150), 0, (rows - 1) as u32);
        let ranking: Vec<u32> = (0..rows as u32).collect();

        let tier_cfg = TierConfig {
            hot_frac,
            reserve_bytes: 0,
            promote,
            ranking: Some(ranking.clone()),
            ..TierConfig::default()
        };
        let tiered = FeatureStore::build_tiered(rows, dim, 8, &sys, seed, tier_cfg.clone())
            .map_err(|e| e.to_string())?;
        let (_, c_ti) = tiered.gather(&idx).map_err(|e| e.to_string())?;

        let sharded = FeatureStore::build_sharded(
            rows,
            dim,
            8,
            &sys,
            seed,
            ShardConfig {
                num_gpus: 1,
                policy,
                tier: tier_cfg,
                ..ShardConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let (_, c_sh) = sharded.gather(&idx).map_err(|e| e.to_string())?;
        prop_assert(
            c_sh.time_s == c_ti.time_s
                && c_sh.bytes_on_link == c_ti.bytes_on_link
                && c_sh.requests == c_ti.requests
                && c_sh.split.peer_bytes == 0,
            format!("N=1 {policy:?} diverged from tiered: {c_sh:?} vs {c_ti:?}"),
        )
    });
}

//! Multi-threaded planned gather (`--sampler-workers`, DESIGN.md §13):
//!
//! * The worker count is a pure wall-clock knob — gather and scatter
//!   outputs are **bitwise identical** at 1/2/7/16 workers in all eight
//!   access modes, on both `GatherPlan` id paths (the dense slot table
//!   and the sparse hash map).
//! * A panic inside a gather worker surfaces as `Error::Pipeline`
//!   carrying the payload — never a hang, never a lost thread (a hang
//!   here shows up as a test-harness timeout, like `pipeline_stress`).
//! * Page pins taken by concurrent gather streams balance back to zero
//!   once every stream releases — no refcount leaks under contention.

use ptdirect::config::{AccessMode, Backend, Precision, RunConfig, SystemProfile};
use ptdirect::coordinator::Trainer;
use ptdirect::error::Error;
use ptdirect::featurestore::FeatureStore;
use ptdirect::sampler::GatherPlan;
use ptdirect::tensor::indexing::gather_rows_into_parallel;

const WORKERS: [usize; 4] = [1, 2, 7, 16];

fn store(mode: AccessMode, rows: usize, dim: usize, workers: usize) -> FeatureStore {
    let sys = SystemProfile::system1();
    let mut s = FeatureStore::build_quantized(
        rows,
        dim,
        8,
        mode,
        &sys,
        42,
        Precision::Fp32,
        None,
        None,
        None,
    )
    .unwrap();
    s.set_gather_workers(workers);
    s
}

/// A duplicated, skewed request stream over `rows` ids, `len` long.
fn requests(rows: usize, len: usize) -> Vec<u32> {
    (0..len)
        .map(|i| (((i * 31 + 7) % rows) as u32).min(rows as u32 - 1))
        .collect()
}

#[test]
fn gather_and_scatter_are_bitwise_invariant_in_worker_count() {
    // Dense plan path: small id space, duplicated stream (the slot-table
    // branch of GatherPlan::build).
    for mode in AccessMode::all() {
        let idx = requests(500, 331);
        let plan = GatherPlan::build(&idx);
        let mut reference: Option<(Vec<f32>, Vec<f32>)> = None;
        for &w in &WORKERS {
            // Fresh store per worker count: stateful tiers must see the
            // same access history at every count.
            let s = store(mode, 500, 24, w);
            let (direct, _) = s.gather(&idx).unwrap();
            let mut planned = vec![0f32; plan.requested_rows() * s.dim()];
            s.gather_planned(&plan, &mut planned).unwrap();
            assert_eq!(direct, planned, "{mode:?} planned != direct at {w} workers");
            match &reference {
                None => reference = Some((direct, planned)),
                Some((d1, p1)) => {
                    assert_eq!(&direct, d1, "{mode:?} gather changed at {w} workers");
                    assert_eq!(&planned, p1, "{mode:?} scatter changed at {w} workers");
                }
            }
        }
    }
}

#[test]
fn sparse_id_path_is_also_invariant_in_worker_count() {
    // Sparse plan path: a big id space with few, scattered requests
    // drives GatherPlan::build onto its hash-map branch.
    let rows = 40_000;
    let idx: Vec<u32> = (0..97u32)
        .map(|i| (i as u64 * 2_654_435_761 % rows as u64) as u32)
        .flat_map(|v| [v, v]) // duplicates exercise the scatter map
        .collect();
    let plan = GatherPlan::build(&idx);
    assert!(plan.unique_rows() < idx.len());
    let mut reference: Option<Vec<f32>> = None;
    for &w in &WORKERS {
        let s = store(AccessMode::UnifiedAligned, rows, 16, w);
        let mut planned = vec![0f32; plan.requested_rows() * s.dim()];
        s.gather_planned(&plan, &mut planned).unwrap();
        match &reference {
            None => reference = Some(planned),
            Some(p1) => assert_eq!(&planned, p1, "sparse path changed at {w} workers"),
        }
    }
}

#[test]
fn epoch_reports_are_invariant_in_sampler_workers() {
    // Through the trainer, the knob must change nothing observable:
    // losses, link bytes, requests — all pinned to the 1-worker run.
    for mode in [AccessMode::CpuGather, AccessMode::Tiered] {
        let cfg = |workers: usize| RunConfig {
            dataset: "product".into(),
            arch: "sage".into(),
            mode,
            sampler_workers: workers,
            steps_per_epoch: 4,
            scale: 2048,
            feature_budget: 8 << 20,
            seed: 42,
            backend: Backend::Native,
            artifacts_dir: "this-directory-does-not-exist".into(),
            ..RunConfig::default()
        };
        let reference = Trainer::new(cfg(1)).unwrap().run_epoch().unwrap();
        for workers in [2, 7, 16] {
            let r = Trainer::new(cfg(workers)).unwrap().run_epoch().unwrap();
            assert_eq!(r.losses, reference.losses, "{mode:?} @ {workers} workers");
            assert_eq!(r.accs, reference.accs, "{mode:?} @ {workers} workers");
            assert_eq!(
                r.bytes_on_link, reference.bytes_on_link,
                "{mode:?} @ {workers} workers"
            );
            assert_eq!(r.requests, reference.requests, "{mode:?} @ {workers} workers");
        }
    }
}

#[test]
fn worker_panic_surfaces_as_pipeline_error_not_a_hang() {
    // An out-of-range row makes one worker's slice index panic; the
    // parent must join every worker and return Error::Pipeline with the
    // payload, not hang or propagate the panic.
    let src = vec![1.0f32; 10 * 4];
    let idx = vec![0u32, 1, 2, 99, 3, 4, 5, 6];
    let mut dst = vec![0f32; idx.len() * 4];
    match gather_rows_into_parallel(&src, 4, &idx, &mut dst, 4) {
        Err(Error::Pipeline(msg)) => {
            assert!(msg.contains("gather worker panicked"), "payload lost: {msg}")
        }
        Err(e) => panic!("unexpected error kind: {e}"),
        Ok(()) => panic!("out-of-range gather succeeded"),
    }
}

#[test]
fn concurrent_pins_return_to_zero() {
    // Eight streams pin / gather / unpin the same tiered store
    // concurrently; afterwards every pin must be matched by an unpin
    // (the serving engine's in-flight protection must not leak under
    // contention).
    for mode in [AccessMode::Tiered, AccessMode::Nvme] {
        let s = store(mode, 2_000, 24, 4);
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let s = &s;
                scope.spawn(move || {
                    for round in 0..5usize {
                        let idx = requests(2_000, 64 + t * 13 + round);
                        s.pin_rows(&idx);
                        let _ = s.gather(&idx).unwrap();
                        s.unpin_rows(&idx);
                    }
                });
            }
        });
        let stats = match mode {
            AccessMode::Nvme => s.nvme_stats().expect("nvme store reports stats").tier,
            _ => s.tier_stats().expect("tiered store reports stats"),
        };
        assert!(stats.pins > 0, "{mode:?}: pins were never exercised");
        assert_eq!(
            stats.pins, stats.unpins,
            "{mode:?}: pin refcounts leaked under concurrency"
        );
    }
}

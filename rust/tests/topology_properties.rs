//! Integration tests for the link-topology registry and the multi-host
//! network tier (DESIGN.md §15):
//!
//!  * the `--num-hosts`/`--fetch-strategy` knobs are inert at one host —
//!    every access mode replays its single-host epoch bit-exactly through
//!    the topology-driven engines;
//!  * the network resource lane exists in every schedule but stays idle
//!    (exactly 0.0 busy seconds) on a single host, and per-link busy
//!    shares stay within the serial envelope;
//!  * remote fetching and partition-local replication agree bitwise on
//!    numerics (placement and pricing never touch values);
//!  * partition-local replication reproduces the single-host cost
//!    bit-exactly, reporting the mirrored halo instead of paying bytes;
//!  * remote bytes grow monotonically with the host count under every
//!    placement policy (host 0's shard only shrinks as hosts double).

use ptdirect::config::{AccessMode, Backend, FetchStrategy, RunConfig, ShardPolicy};
use ptdirect::coordinator::simclock::ResourceKind;
use ptdirect::coordinator::Trainer;
use ptdirect::interconnect::NUM_RESOURCE_KINDS;

const STEPS: u32 = 8;

/// Hermetic config: native backend, no artifacts needed.
fn cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: STEPS,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: 42,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        ..RunConfig::default()
    }
}

fn multi_host_cfg(num_hosts: u32, strategy: FetchStrategy) -> RunConfig {
    RunConfig {
        num_gpus: 2,
        num_hosts,
        fetch_strategy: strategy,
        ..cfg(AccessMode::Sharded)
    }
}

#[test]
fn single_host_knobs_are_inert_in_every_mode() {
    // `--num-hosts 1` is the degeneracy anchor: with either fetch
    // strategy, every access mode must replay the default epoch report
    // bit-exactly — same numerics, same costs, same power.
    for mode in AccessMode::all() {
        let base = Trainer::new(cfg(mode)).unwrap().run_epoch().unwrap();
        for strategy in FetchStrategy::all() {
            let mut c = cfg(mode);
            c.num_hosts = 1;
            c.fetch_strategy = strategy;
            let r = Trainer::new(c).unwrap().run_epoch().unwrap();
            assert_eq!(r.losses, base.losses, "{mode:?} {strategy:?}");
            assert_eq!(r.accs, base.accs, "{mode:?} {strategy:?}");
            for (got, want, what) in [
                (r.breakdown_sim.sample_s, base.breakdown_sim.sample_s, "sample"),
                (r.breakdown_sim.transfer_s, base.breakdown_sim.transfer_s, "transfer"),
                (r.breakdown_sim.train_s, base.breakdown_sim.train_s, "train"),
                (r.breakdown_sim.other_s, base.breakdown_sim.other_s, "other"),
                (r.overlap.overlapped_s, base.overlap.overlapped_s, "overlapped"),
                (r.power.watts, base.power.watts, "watts"),
            ] {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{mode:?} {strategy:?}: {what} diverged at one host"
                );
            }
            assert_eq!(r.bytes_on_link, base.bytes_on_link, "{mode:?} {strategy:?}");
            assert_eq!(r.requests, base.requests, "{mode:?} {strategy:?}");
        }
    }
}

#[test]
fn the_net_lane_exists_everywhere_but_idles_on_a_single_host() {
    assert_eq!(ResourceKind::all().len(), NUM_RESOURCE_KINDS);
    assert!(ResourceKind::all().contains(&ResourceKind::NetLink));
    for mode in AccessMode::all() {
        let mut c = cfg(mode);
        c.prefetch_depth = 4;
        let sampler_lanes = c.sampler_workers.max(1) as f64;
        let r = Trainer::new(c).unwrap().run_epoch().unwrap();
        let o = &r.overlap;
        assert_eq!(
            o.busy.get(ResourceKind::NetLink),
            0.0,
            "{mode:?}: network lane busy on a single host"
        );
        // Per-link busy conservation: every lane stays inside the serial
        // envelope, and no single-lane resource outlasts the epoch.
        for kind in ResourceKind::all() {
            let busy = o.busy.get(kind);
            assert!(busy >= 0.0, "{mode:?}: negative {kind:?} busy");
            assert!(
                busy <= o.serial_s * (1.0 + 1e-9),
                "{mode:?}: {kind:?} busy {busy} exceeds serial {}",
                o.serial_s
            );
            let lanes = if kind == ResourceKind::Sampler {
                sampler_lanes
            } else {
                1.0
            };
            assert!(
                o.overlapped_s >= busy / lanes - 1e-9 * o.serial_s,
                "{mode:?}: {kind:?} busy {busy} exceeds the epoch {}",
                o.overlapped_s
            );
        }
    }
}

#[test]
fn remote_fetch_prices_the_network_in_a_multi_host_epoch() {
    let r = Trainer::new(multi_host_cfg(4, FetchStrategy::RemoteFetch))
        .unwrap()
        .run_epoch()
        .unwrap();
    let totals = r.shard.as_ref().expect("sharded epoch reports shard stats").totals();
    assert!(totals.remote_rows > 0, "4-host hash split must home rows remotely");
    assert!(totals.remote_bytes > 0);
    assert!(totals.net_time_s > 0.0);
    assert_eq!(totals.halo_rows, 0, "remote fetching replicates nothing");
    // Row conservation still holds with the fourth class in the split.
    assert_eq!(totals.rows_served(), r.dedup.unique_rows);
    // The overlap engine scheduled the fetches on the network lane.
    assert!(
        r.overlap.busy.get(ResourceKind::NetLink) > 0.0,
        "remote fetches never occupied the net lane"
    );
}

#[test]
fn fetch_strategies_agree_bitwise_on_numerics() {
    // Placement and pricing never touch values: the two remote-row
    // strategies disagree on cost, never on the loss trajectory.
    let remote = Trainer::new(multi_host_cfg(4, FetchStrategy::RemoteFetch))
        .unwrap()
        .run_epoch()
        .unwrap();
    let local = Trainer::new(multi_host_cfg(4, FetchStrategy::PartitionLocal))
        .unwrap()
        .run_epoch()
        .unwrap();
    assert_eq!(remote.losses, local.losses, "fetch strategy changed numerics");
    assert_eq!(remote.accs, local.accs);
    let lt = local.shard.as_ref().unwrap().totals();
    assert!(lt.halo_rows > 0, "partition-local must report the mirrored halo");
    assert_eq!(lt.remote_rows, 0);
    assert_eq!(lt.remote_bytes, 0);
    assert_eq!(lt.net_time_s, 0.0);
}

#[test]
fn partition_local_reproduces_the_single_host_epoch_bit_exactly() {
    // The replication strategy's steady state *is* the single-host run:
    // identical cost, bytes, schedule, and power — only the halo counter
    // records that a real deployment would spend memory for it.
    let one = Trainer::new(multi_host_cfg(1, FetchStrategy::PartitionLocal))
        .unwrap()
        .run_epoch()
        .unwrap();
    let four = Trainer::new(multi_host_cfg(4, FetchStrategy::PartitionLocal))
        .unwrap()
        .run_epoch()
        .unwrap();
    assert_eq!(four.losses, one.losses);
    for (got, want, what) in [
        (four.breakdown_sim.sample_s, one.breakdown_sim.sample_s, "sample"),
        (four.breakdown_sim.transfer_s, one.breakdown_sim.transfer_s, "transfer"),
        (four.breakdown_sim.train_s, one.breakdown_sim.train_s, "train"),
        (four.breakdown_sim.other_s, one.breakdown_sim.other_s, "other"),
        (four.overlap.overlapped_s, one.overlap.overlapped_s, "overlapped"),
        (four.power.watts, one.power.watts, "watts"),
    ] {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "partition-local 4 hosts diverged from 1 host on {what}"
        );
    }
    assert_eq!(four.bytes_on_link, one.bytes_on_link);
    assert_eq!(four.requests, one.requests);
    assert_eq!(one.shard.as_ref().unwrap().totals().halo_rows, 0);
    assert!(four.shard.as_ref().unwrap().totals().halo_rows > 0);
}

#[test]
fn remote_bytes_grow_monotonically_with_the_host_count() {
    // Host 0's shard only shrinks as the host count doubles (hash keeps
    // multiples, degree round-robin keeps every 2k-th rank, contig halves
    // the range), so the remote byte volume can only grow.
    for policy in ShardPolicy::all() {
        let mut last = 0u64;
        for hosts in [1u32, 2, 4, 8] {
            let mut c = multi_host_cfg(hosts, FetchStrategy::RemoteFetch);
            c.shard_policy = policy;
            let r = Trainer::new(c).unwrap().run_epoch().unwrap();
            let t = r.shard.as_ref().unwrap().totals();
            assert!(
                t.remote_bytes >= last,
                "{policy:?}: remote bytes shrank from {last} at {hosts} hosts"
            );
            last = t.remote_bytes;
            if hosts == 1 {
                assert_eq!(t.remote_bytes, 0, "{policy:?}: one host has no remote rows");
            }
        }
        assert!(last > 0, "{policy:?}: eight hosts never paid the network");
    }
}

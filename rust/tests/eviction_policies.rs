//! Model-based property tests for the page cache's eviction policies
//! (DESIGN.md §12, `featurestore::pagecache`):
//!
//! * **LRU** and **LFU** replayed against naive reference models (an
//!   O(pages) argmin scan per eviction — no lazy heaps, no stale-entry
//!   repair) over random traces: hit/miss/promotion/eviction counters
//!   and the resident page set must match after every gather;
//! * **CLOCK** replayed against a straightforward second-chance model
//!   that additionally *proves* the second-chance contract on every
//!   eviction: the victim's reference bit is clear, and any reference
//!   it ever received was consumed by a later hand visit;
//! * **monotonicity** — the hit count never decreases with cache size:
//!   for every policy on cyclic sequential traces (where the behavior
//!   is provable), for Static/LRU/LFU on random traces (nested static
//!   prefixes, the LRU stack property, LFU inclusion from full nested
//!   preseeds), and the full-capacity endpoint for everything;
//! * **tie-breaking** — stamp/frequency ties evict the lowest page id,
//!   pinned by explicit scenarios, and whole-trace replays are
//!   deterministic for every policy.

use ptdirect::config::EvictionPolicy;
use ptdirect::featurestore::PageCache;
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

// ---------------------------------------------------------------------------
// Reference models (page-granular, no pins — the serving pins are covered
// by tests/pagecache_properties.rs)
// ---------------------------------------------------------------------------

struct ModelState {
    resident: Vec<bool>,
    cap: usize,
    hits: u64,
    misses: u64,
    promotions: u64,
    evictions: u64,
}

impl ModelState {
    fn new(num_pages: usize, cap: usize) -> ModelState {
        ModelState {
            resident: vec![false; num_pages],
            cap,
            hits: 0,
            misses: 0,
            promotions: 0,
            evictions: 0,
        }
    }

    fn resident_ids(&self) -> Vec<u32> {
        (0..self.resident.len() as u32)
            .filter(|&p| self.resident[p as usize])
            .collect()
    }

    fn resident_count(&self) -> usize {
        self.resident.iter().filter(|&&r| r).count()
    }

    /// Split one gather into hits/misses against the *current* residency
    /// (no admissions mid-split, matching `PageCache::record`) and return
    /// the missed pages, sorted and deduplicated.
    fn split(&mut self, idx: &[u32], page_rows: usize) -> Vec<usize> {
        let mut cold = Vec::new();
        for &r in idx {
            let p = r as usize / page_rows;
            if self.resident[p] {
                self.hits += 1;
            } else {
                self.misses += 1;
                cold.push(p);
            }
        }
        cold.sort_unstable();
        cold.dedup();
        cold
    }
}

/// Naive LRU: per-page last-access stamps, victim = argmin (stamp, page).
struct LruModel {
    s: ModelState,
    stamp: Vec<u64>,
    tick: u64,
}

impl LruModel {
    fn new(num_pages: usize, cap: usize, preseed: &[u32]) -> LruModel {
        let mut m = LruModel {
            s: ModelState::new(num_pages, cap),
            stamp: vec![0; num_pages],
            tick: 0,
        };
        for &p in preseed {
            m.s.resident[p as usize] = true;
        }
        m
    }

    fn record(&mut self, idx: &[u32], page_rows: usize) {
        self.tick += 1;
        for &r in idx {
            self.stamp[r as usize / page_rows] = self.tick;
        }
        let cold = self.s.split(idx, page_rows);
        if self.s.cap == 0 {
            return;
        }
        for p in cold {
            if self.s.resident[p] {
                continue;
            }
            if self.s.resident_count() < self.s.cap {
                self.s.resident[p] = true;
                self.s.promotions += 1;
                continue;
            }
            let victim = (0..self.s.resident.len())
                .filter(|&q| self.s.resident[q])
                .min_by_key(|&q| (self.stamp[q], q))
                .unwrap();
            self.s.resident[victim] = false;
            self.s.evictions += 1;
            self.s.resident[p] = true;
            self.s.promotions += 1;
        }
    }
}

/// Naive LFU: victim = argmin (freq, page); admit only on strictly
/// greater candidate frequency.
struct LfuModel {
    s: ModelState,
    freq: Vec<u64>,
}

impl LfuModel {
    fn new(num_pages: usize, cap: usize, preseed: &[u32]) -> LfuModel {
        let mut m = LfuModel {
            s: ModelState::new(num_pages, cap),
            freq: vec![0; num_pages],
        };
        for &p in preseed {
            m.s.resident[p as usize] = true;
        }
        m
    }

    fn record(&mut self, idx: &[u32], page_rows: usize) {
        for &r in idx {
            self.freq[r as usize / page_rows] += 1;
        }
        let cold = self.s.split(idx, page_rows);
        if self.s.cap == 0 {
            return;
        }
        for p in cold {
            if self.s.resident[p] {
                continue;
            }
            if self.s.resident_count() < self.s.cap {
                self.s.resident[p] = true;
                self.s.promotions += 1;
                continue;
            }
            let victim = (0..self.s.resident.len())
                .filter(|&q| self.s.resident[q])
                .min_by_key(|&q| (self.freq[q], q))
                .unwrap();
            if self.freq[p] > self.freq[victim] {
                self.s.resident[victim] = false;
                self.s.evictions += 1;
                self.s.resident[p] = true;
                self.s.promotions += 1;
            }
        }
    }
}

/// Straightforward second-chance CLOCK over a circular frame buffer,
/// instrumented to prove the contract on every eviction: the victim was
/// not referenced since the hand's last clearing visit.
struct ClockModel {
    s: ModelState,
    slots: Vec<u32>,
    referenced: Vec<bool>,
    hand: usize,
    /// Global event counter; bumped on every reference and hand visit.
    seq: u64,
    /// Event of each page's last reference-bit set.
    ref_seq: Vec<u64>,
    /// Event of each page's last bit-consuming hand visit (or admission,
    /// which starts the page unreferenced).
    cleared_seq: Vec<u64>,
}

impl ClockModel {
    fn new(num_pages: usize, cap: usize, preseed: &[u32]) -> ClockModel {
        let mut m = ClockModel {
            s: ModelState::new(num_pages, cap),
            slots: Vec::new(),
            referenced: vec![false; num_pages],
            hand: 0,
            seq: 0,
            ref_seq: vec![0; num_pages],
            cleared_seq: vec![0; num_pages],
        };
        for &p in preseed {
            m.s.resident[p as usize] = true;
            m.slots.push(p);
        }
        m
    }

    fn record(&mut self, idx: &[u32], page_rows: usize) -> Result<(), String> {
        for &r in idx {
            let p = r as usize / page_rows;
            if self.s.resident[p] {
                self.seq += 1;
                self.referenced[p] = true;
                self.ref_seq[p] = self.seq;
            }
        }
        let cold = self.s.split(idx, page_rows);
        if self.s.cap == 0 {
            return Ok(());
        }
        for p in cold {
            if self.s.resident[p] {
                continue;
            }
            if self.s.resident_count() < self.s.cap {
                self.s.resident[p] = true;
                self.s.promotions += 1;
                self.slots.push(p as u32);
                self.referenced[p] = false;
                self.seq += 1;
                self.cleared_seq[p] = self.seq;
                continue;
            }
            // Sweep: spend reference bits until an unreferenced frame.
            loop {
                let v = self.slots[self.hand] as usize;
                self.seq += 1;
                if self.referenced[v] {
                    self.referenced[v] = false;
                    self.cleared_seq[v] = self.seq;
                    self.hand = (self.hand + 1) % self.slots.len();
                    continue;
                }
                // The second-chance contract, proved at the victim:
                if self.ref_seq[v] > self.cleared_seq[v] {
                    return Err(format!(
                        "clock evicted page {v} referenced at event {} after its \
                         last clearing visit at event {}",
                        self.ref_seq[v], self.cleared_seq[v]
                    ));
                }
                self.slots[self.hand] = p as u32;
                self.s.resident[v] = false;
                self.s.evictions += 1;
                self.s.resident[p] = true;
                self.s.promotions += 1;
                self.referenced[p] = false;
                self.cleared_seq[p] = self.seq;
                self.hand = (self.hand + 1) % self.slots.len();
                break;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared generators
// ---------------------------------------------------------------------------

/// A built cache plus the page-level preseed the models should mirror.
fn build_with_preseed(
    g: &mut Gen,
    rows: usize,
    page_rows: usize,
    policy: EvictionPolicy,
    cap_rows: usize,
) -> (PageCache, Vec<u32>) {
    let ranking = if g.bool() {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(g.seed ^ 0xC0FFEE).shuffle(&mut order);
        Some(order)
    } else {
        None
    };
    let cache = PageCache::build(rows, 64, page_rows, policy, cap_rows, ranking.as_deref());
    // Replay the preseed walk the cache performed, page-wise.
    let mut preseed = Vec::new();
    let mut seen = vec![false; rows.div_ceil(page_rows)];
    if let Some(rk) = &ranking {
        for &r in rk {
            if preseed.len() >= cache.capacity_pages() {
                break;
            }
            if (r as usize) < rows {
                let p = r as usize / page_rows;
                if !seen[p] {
                    seen[p] = true;
                    preseed.push(p as u32);
                }
            }
        }
    }
    (cache, preseed)
}

fn random_trace(g: &mut Gen, rows: usize) -> Vec<Vec<u32>> {
    let n_gathers = g.usize_in(1, 10);
    (0..n_gathers)
        .map(|_| {
            let len = g.usize_in(1, 120);
            g.vec_u32(len, 0, (rows - 1) as u32)
        })
        .collect()
}

fn assert_cache_matches_model(
    cache: &PageCache,
    m: &ModelState,
    what: &str,
) -> Result<(), String> {
    let s = cache.stats();
    prop_assert(
        s.hits == m.hits && s.misses == m.misses,
        format!(
            "{what}: hit/miss diverged: cache {}/{} vs model {}/{}",
            s.hits, s.misses, m.hits, m.misses
        ),
    )?;
    prop_assert(
        s.promotions == m.promotions && s.evictions == m.evictions,
        format!(
            "{what}: promote/evict diverged: cache {}/{} vs model {}/{}",
            s.promotions, s.evictions, m.promotions, m.evictions
        ),
    )?;
    prop_assert(
        cache.resident_page_ids() == m.resident_ids(),
        format!("{what}: resident page sets diverged"),
    )
}

// ---------------------------------------------------------------------------
// 1. Model equivalence
// ---------------------------------------------------------------------------

#[test]
fn lru_matches_the_naive_reference_model() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 250);
        let page_rows = g.usize_in(1, 4);
        let cap_rows = g.usize_in(0, rows);
        let (mut cache, preseed) =
            build_with_preseed(g, rows, page_rows, EvictionPolicy::Lru, cap_rows);
        let mut model = LruModel::new(
            rows.div_ceil(page_rows),
            cache.capacity_pages(),
            &preseed,
        );
        for (i, idx) in random_trace(g, rows).into_iter().enumerate() {
            cache.record(&idx);
            model.record(&idx, page_rows);
            assert_cache_matches_model(&cache, &model.s, &format!("lru gather {i}"))?;
        }
        Ok(())
    });
}

#[test]
fn lfu_matches_the_naive_reference_model() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 250);
        let page_rows = g.usize_in(1, 4);
        let cap_rows = g.usize_in(0, rows);
        let (mut cache, preseed) =
            build_with_preseed(g, rows, page_rows, EvictionPolicy::Lfu, cap_rows);
        let mut model = LfuModel::new(
            rows.div_ceil(page_rows),
            cache.capacity_pages(),
            &preseed,
        );
        for (i, idx) in random_trace(g, rows).into_iter().enumerate() {
            cache.record(&idx);
            model.record(&idx, page_rows);
            assert_cache_matches_model(&cache, &model.s, &format!("lfu gather {i}"))?;
        }
        Ok(())
    });
}

#[test]
fn clock_matches_the_second_chance_model_and_honors_references() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 250);
        let page_rows = g.usize_in(1, 4);
        let cap_rows = g.usize_in(0, rows);
        let (mut cache, preseed) =
            build_with_preseed(g, rows, page_rows, EvictionPolicy::Clock, cap_rows);
        let mut model = ClockModel::new(
            rows.div_ceil(page_rows),
            cache.capacity_pages(),
            &preseed,
        );
        for (i, idx) in random_trace(g, rows).into_iter().enumerate() {
            cache.record(&idx);
            // The model itself fails if an eviction ever breaks the
            // second-chance contract.
            model.record(&idx, page_rows)?;
            assert_cache_matches_model(&cache, &model.s, &format!("clock gather {i}"))?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Hit-count monotonicity in cache size
// ---------------------------------------------------------------------------

/// Hits of one full replay of `trace` through a fresh cache of
/// `cap_rows` budget, preseeded from the identity ranking.
fn replay_hits(
    policy: EvictionPolicy,
    rows: usize,
    cap_rows: usize,
    preseed: bool,
    trace: &[Vec<u32>],
) -> u64 {
    let ranking: Vec<u32> = (0..rows as u32).collect();
    let mut cache = PageCache::build(
        rows,
        64,
        1,
        policy,
        cap_rows,
        if preseed { Some(&ranking) } else { None },
    );
    for idx in trace {
        cache.record(idx);
    }
    cache.stats().hits
}

#[test]
fn every_policy_is_monotone_on_cyclic_sequential_traces() {
    // Round-robin over D distinct rows, one row per gather, preseeded
    // full from the identity ranking — the canonical trace where all
    // four policies' behavior is provable (LRU/CLOCK thrash past the
    // capacity, LFU and static freeze the prefix), so the hit count
    // must be non-decreasing in the capacity for each of them.
    check(15, |g: &mut Gen| {
        let d = g.usize_in(2, 40);
        let cycles = g.usize_in(2, 5);
        let trace: Vec<Vec<u32>> = (0..cycles)
            .flat_map(|_| (0..d as u32).map(|r| vec![r]))
            .collect();
        for policy in EvictionPolicy::all() {
            let mut prev = 0u64;
            for cap in 0..=d {
                let hits = replay_hits(policy, d, cap, true, &trace);
                prop_assert(
                    hits >= prev,
                    format!("{policy:?}: hits dropped {prev} -> {hits} at capacity {cap}/{d}"),
                )?;
                prev = hits;
            }
            // Full capacity: everything preseeded, every access hits.
            prop_assert(
                prev == (cycles * d) as u64,
                format!("{policy:?}: full cache missed on a cyclic trace"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn static_and_lru_are_monotone_on_random_traces() {
    // Static: nested ranked prefixes — a bigger cache's resident set
    // contains the smaller one's, forever.  LRU: the classic stack
    // property (single-row gathers, cold start).  Both make hit counts
    // monotone on *any* trace.
    check(20, |g: &mut Gen| {
        let rows = g.usize_in(2, 120);
        let n = g.usize_in(1, 400);
        let trace: Vec<Vec<u32>> = g
            .vec_u32(n, 0, (rows - 1) as u32)
            .into_iter()
            .map(|r| vec![r])
            .collect();
        let caps: Vec<usize> = {
            let mut c: Vec<usize> = (0..4).map(|_| g.usize_in(0, rows)).collect();
            c.sort_unstable();
            c
        };
        for (policy, preseed) in [(EvictionPolicy::Static, true), (EvictionPolicy::Lru, false)] {
            let mut prev = 0u64;
            for &cap in &caps {
                let hits = replay_hits(policy, rows, cap, preseed, &trace);
                prop_assert(
                    hits >= prev,
                    format!("{policy:?}: hits dropped {prev} -> {hits} at capacity {cap}"),
                )?;
                prev = hits;
            }
        }
        Ok(())
    });
}

#[test]
fn lfu_is_monotone_on_random_traces_from_full_preseeds() {
    // LFU inclusion: two caches preseeded full from nested prefixes of
    // the same ranking stay nested under strict-greater admission (the
    // smaller cache's minimum frequency is at least the bigger one's),
    // so hits are monotone — batch gathers included.
    check(20, |g: &mut Gen| {
        let rows = g.usize_in(2, 120);
        let trace = random_trace(g, rows);
        let caps: Vec<usize> = {
            let mut c: Vec<usize> = (0..4).map(|_| g.usize_in(0, rows)).collect();
            c.sort_unstable();
            c
        };
        let mut prev = 0u64;
        for &cap in &caps {
            let hits = replay_hits(EvictionPolicy::Lfu, rows, cap, true, &trace);
            prop_assert(
                hits >= prev,
                format!("lfu: hits dropped {prev} -> {hits} at capacity {cap}"),
            )?;
            prev = hits;
        }
        Ok(())
    });
}

#[test]
fn full_capacity_is_the_hit_count_ceiling_for_every_policy() {
    // With the whole table preseeded resident nothing is ever cold, so
    // the full-capacity cache's hit count bounds every smaller cache's
    // on the same trace — the endpoint every policy must respect
    // (including CLOCK, whose interior points admit Belady anomalies on
    // adversarial traces and are deliberately only pinned on the cyclic
    // trace above).
    check(20, |g: &mut Gen| {
        let rows = g.usize_in(2, 120);
        let trace = random_trace(g, rows);
        let total: u64 = trace.iter().map(|t| t.len() as u64).sum();
        for policy in EvictionPolicy::all() {
            let full = replay_hits(policy, rows, rows, true, &trace);
            prop_assert(
                full == total,
                format!("{policy:?}: full cache missed ({full} of {total})"),
            )?;
            let cap = g.usize_in(0, rows);
            let partial = replay_hits(policy, rows, cap, true, &trace);
            prop_assert(
                partial <= full,
                format!("{policy:?}: partial cache out-hit the full cache"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. Deterministic tie-breaking and replay determinism
// ---------------------------------------------------------------------------

#[test]
fn lfu_breaks_frequency_ties_toward_the_lowest_page_id() {
    // Pages 0..2 preseeded at frequency zero; the first admission must
    // displace page 0, then page 1 — lowest id first among equals.
    let ranking: Vec<u32> = (0..10).collect();
    let mut cache = PageCache::build(10, 64, 1, EvictionPolicy::Lfu, 3, Some(&ranking));
    cache.record(&[9]);
    assert!(!cache.is_resident(0), "freq tie must evict page 0 first");
    assert!(cache.is_resident(1) && cache.is_resident(2) && cache.is_resident(9));
    cache.record(&[8]);
    assert!(!cache.is_resident(1), "next freq tie must evict page 1");
    assert!(cache.is_resident(2) && cache.is_resident(8) && cache.is_resident(9));
}

#[test]
fn lru_breaks_stamp_ties_toward_the_lowest_page_id() {
    // Preseeded pages all carry stamp 0; evictions walk them in id
    // order until the stamps differentiate.
    let ranking: Vec<u32> = (0..10).collect();
    let mut cache = PageCache::build(10, 64, 1, EvictionPolicy::Lru, 3, Some(&ranking));
    cache.record(&[5]);
    assert!(!cache.is_resident(0), "stamp tie must evict page 0 first");
    cache.record(&[6]);
    assert!(!cache.is_resident(1), "next stamp tie must evict page 1");
    assert!(cache.is_resident(2) && cache.is_resident(5) && cache.is_resident(6));
}

#[test]
fn identical_replays_produce_identical_stats_for_every_policy() {
    check(15, |g: &mut Gen| {
        let rows = g.usize_in(2, 200);
        let page_rows = g.usize_in(1, 8);
        let cap = g.usize_in(0, rows);
        let trace = random_trace(g, rows);
        let ranking: Vec<u32> = (0..rows as u32).collect();
        for policy in EvictionPolicy::all() {
            let mut a = PageCache::build(rows, 64, page_rows, policy, cap, Some(&ranking));
            let mut b = PageCache::build(rows, 64, page_rows, policy, cap, Some(&ranking));
            for idx in &trace {
                let cold_a = a.record(idx);
                let cold_b = b.record(idx);
                prop_assert(cold_a == cold_b, format!("{policy:?}: cold streams diverged"))?;
            }
            prop_assert(a.stats() == b.stats(), format!("{policy:?}: stats diverged"))?;
            prop_assert(
                a.resident_page_ids() == b.resident_page_ids(),
                format!("{policy:?}: resident sets diverged"),
            )?;
        }
        Ok(())
    });
}

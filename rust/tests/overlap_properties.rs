//! Property tests for the discrete-event overlap engine (DESIGN.md §9):
//!
//! * the overlapped epoch time is monotone non-increasing in
//!   `--prefetch-depth`,
//! * it is bounded by `[max over resources of busy/lanes, serial sum]`
//!   (links and GPU are single-lane; the sampler divides across its
//!   lanes),
//! * depth 0 is bit-exact with the pre-engine serial breakdown in every
//!   access mode, and
//! * the critical-path attribution is conservative (its durations sum to
//!   the makespan).

use ptdirect::config::{AccessMode, RunConfig};
use ptdirect::coordinator::schedule::{schedule_epoch, OverlapParams};
use ptdirect::coordinator::simclock::ResourceKind;
use ptdirect::coordinator::Trainer;
use ptdirect::interconnect::ResourceDemand;
use ptdirect::util::proptest::{check, prop_assert, Gen};

/// Relative slack for comparisons between totals that are summed in
/// different orders (the serial formula multiplies per-step constants;
/// the event engine accumulates them step by step).
const REL_EPS: f64 = 1e-9;

fn random_demands(g: &mut Gen, n: usize) -> Vec<ResourceDemand> {
    (0..n)
        .map(|_| {
            // Pick a link mix: host-only, peer+host, storage+host,
            // net+host (a multi-host remote fetch), or launch-only — the
            // shapes the access modes emit.
            let shape = g.usize_in(0, 4);
            let link_s = g.f64_in(0.0, 3e-3);
            let cpu_s = if g.bool() { g.f64_in(0.0, 1e-3) } else { 0.0 };
            let (host_s, peer_s, storage_s, net_s) = match shape {
                0 => (link_s, 0.0, 0.0, 0.0),
                1 => (link_s * 0.6, link_s * 0.4, 0.0, 0.0),
                2 => (link_s * 0.3, 0.0, link_s * 0.7, 0.0),
                3 => (link_s * 0.5, 0.0, 0.0, link_s * 0.5),
                _ => (0.0, 0.0, 0.0, 0.0),
            };
            ResourceDemand {
                total_s: cpu_s + link_s,
                cpu_s,
                host_s,
                peer_s,
                storage_s,
                net_s,
            }
        })
        .collect()
}

fn serial_of(demands: &[ResourceDemand], p: &OverlapParams) -> f64 {
    let n = demands.len() as f64;
    let stages = p.sample_step_s * n
        + demands.iter().map(|d| d.total_s).sum::<f64>()
        + p.train_step_s * n;
    stages + 0.02 * stages
}

#[test]
fn overlapped_time_is_monotone_and_bounded_for_random_epochs() {
    check(96, |g| {
        let n = g.usize_in(1, 32);
        let demands = random_demands(g, n);
        let mut p = OverlapParams {
            sample_step_s: g.f64_in(0.0, 2e-3),
            train_step_s: g.f64_in(0.0, 2e-3),
            other_s: 0.0,
            serial_s: 0.0,
            prefetch_depth: 0,
            sampler_lanes: g.usize_in(1, 3),
        };
        let stages = serial_of(&demands, &p);
        p.other_s = stages - stages / 1.02; // ~the 2% bookkeeping share
        p.serial_s = stages;

        let mut last = f64::INFINITY;
        for depth in 0..=8u32 {
            p.prefetch_depth = depth;
            let r = schedule_epoch(&demands, &p);
            prop_assert(
                r.overlapped_s <= last * (1.0 + REL_EPS),
                format!("depth {depth}: {} rose above {last}", r.overlapped_s),
            )?;
            prop_assert(
                r.overlapped_s <= p.serial_s * (1.0 + REL_EPS),
                format!("depth {depth}: overlapped {} > serial {}", r.overlapped_s, p.serial_s),
            )?;
            // Lower bound: no single-lane resource can be busier than the
            // epoch is long (the sampler has `lanes` servers, so its busy
            // time divides by the lane count).
            for kind in ResourceKind::all() {
                let lanes = if kind == ResourceKind::Sampler {
                    p.sampler_lanes as f64
                } else {
                    1.0
                };
                let busy = r.busy.get(kind);
                prop_assert(
                    r.overlapped_s >= busy / lanes - REL_EPS * p.serial_s.max(1e-12),
                    format!("depth {depth}: {kind:?} busy {busy} > epoch {}", r.overlapped_s),
                )?;
            }
            last = r.overlapped_s;
        }
        Ok(())
    });
}

#[test]
fn critical_path_durations_sum_to_the_makespan() {
    check(64, |g| {
        let n = g.usize_in(1, 24);
        let demands = random_demands(g, n);
        let mut p = OverlapParams {
            sample_step_s: g.f64_in(0.0, 2e-3),
            train_step_s: g.f64_in(0.0, 2e-3),
            other_s: g.f64_in(0.0, 1e-3),
            serial_s: 0.0,
            prefetch_depth: g.u64_in(1, 8) as u32,
            sampler_lanes: g.usize_in(1, 3),
        };
        p.serial_s = serial_of(&demands, &p) + p.other_s;
        let r = schedule_epoch(&demands, &p);
        let makespan = r.overlapped_s - p.other_s;
        prop_assert(
            (r.critical.total() - makespan).abs() <= REL_EPS * makespan.max(1e-12),
            format!("critical {} != makespan {makespan}", r.critical.total()),
        )
    });
}

fn small_cfg(mode: AccessMode) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        mode,
        scale: 2048,
        feature_budget: 8 << 20,
        steps_per_epoch: 4,
        skip_train: true,
        ..RunConfig::default()
    }
}

#[test]
fn depth_zero_is_bit_exact_with_the_serial_breakdown_in_every_mode() {
    for mode in AccessMode::all() {
        let mut cfg = small_cfg(mode);
        cfg.prefetch_depth = 0;
        let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
        let b = &r.breakdown_sim;
        assert_eq!(
            r.overlap.overlapped_s,
            b.sample_s + b.transfer_s + b.train_s + b.other_s,
            "{mode:?}: depth 0 must reproduce the additive serial sum bit-exactly"
        );
        assert_eq!(r.overlap.serial_s, r.overlap.overlapped_s, "{mode:?}");
    }
}

#[test]
fn every_mode_overlaps_within_bounds_at_depth_four() {
    for mode in AccessMode::all() {
        let mut cfg = small_cfg(mode);
        cfg.prefetch_depth = 4;
        let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
        let o = &r.overlap;
        assert!(
            o.overlapped_s <= o.serial_s * (1.0 + REL_EPS),
            "{mode:?}: overlapped {} > serial {}",
            o.overlapped_s,
            o.serial_s
        );
        for kind in ResourceKind::all() {
            assert!(
                o.overlapped_s >= o.busy.get(kind) - REL_EPS * o.serial_s,
                "{mode:?}: {kind:?} busy {} exceeds the epoch {}",
                o.busy.get(kind),
                o.overlapped_s
            );
        }
        assert!(o.critical.total() > 0.0, "{mode:?}: empty critical path");
    }
}

#[test]
fn trainer_epochs_are_monotone_in_prefetch_depth() {
    // Through the full trainer stack (promotion off so the tier state is
    // identical across runs): deeper windows never slow the epoch.
    for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned, AccessMode::Nvme] {
        let mut last = f64::INFINITY;
        for depth in [0u32, 1, 2, 4, 8] {
            let mut cfg = small_cfg(mode);
            cfg.prefetch_depth = depth;
            cfg.tier_promote = false;
            let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
            assert!(
                r.overlap.overlapped_s <= last * (1.0 + REL_EPS),
                "{mode:?} depth {depth}: {} rose above {last}",
                r.overlap.overlapped_s
            );
            last = r.overlap.overlapped_s;
        }
    }
}

#[test]
fn unified_aligned_overlaps_strictly_below_serial_at_depth_two() {
    // The acceptance contract: depth >= 2 hides sampling under the
    // zero-copy transfer, so the pipelined epoch lands strictly below the
    // serial sum while the serial breakdown itself is untouched.
    let mut cfg = small_cfg(AccessMode::UnifiedAligned);
    cfg.prefetch_depth = 2;
    let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
    assert!(
        r.overlap.overlapped_s < r.overlap.serial_s,
        "depth 2 must overlap: {} !< {}",
        r.overlap.overlapped_s,
        r.overlap.serial_s
    );
    assert_eq!(r.overlap.serial_s, r.breakdown_sim.total_s());
}

//! Stress tests for the staged pipeline executor: randomized per-stage
//! latencies across 100 seeds must neither deadlock nor lose/duplicate
//! items, and injected failures in any stage must abort promptly through
//! the queue close-on-error protocol of `pipeline/executor.rs`.
//!
//! Deadlocks surface as a test-harness hang/timeout, which is exactly the
//! regression signal these guards exist for.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use ptdirect::error::Error;
use ptdirect::pipeline::executor::run_pipeline;
use ptdirect::util::rng::Rng;

/// Deterministic per-item jitter so every seed exercises a different
/// interleaving of fast and slow items in each stage.
fn jitter_sleep(base_us: u64, item: u64) {
    let us = base_us * (item % 7 + 1) / 7;
    if us > 0 {
        std::thread::sleep(Duration::from_micros(us));
    }
}

#[test]
fn randomized_latencies_100_seeds_exact_item_counts() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed);
        let depth = 1 + rng.gen_range_usize(4); // 1..=4
        let n_items = 16 + rng.gen_range(48); // 16..=63
        let sample_us = rng.gen_range(80);
        let gather_us = rng.gen_range(80);
        let train_us = rng.gen_range(80);

        let trained = AtomicU64::new(0);
        let checksum = AtomicU64::new(0);
        let report = run_pipeline(
            n_items,
            depth,
            |i| {
                jitter_sleep(sample_us, i);
                Ok(i)
            },
            |b| {
                jitter_sleep(gather_us, b);
                Ok(b)
            },
            |f| {
                jitter_sleep(train_us, f);
                trained.fetch_add(1, Ordering::Relaxed);
                checksum.fetch_add(f, Ordering::Relaxed);
                Ok(())
            },
        )
        .unwrap_or_else(|e| panic!("seed {seed}: pipeline failed: {e}"));

        assert_eq!(report.items, n_items, "seed {seed}: report undercounts");
        assert_eq!(
            trained.load(Ordering::Relaxed),
            n_items,
            "seed {seed}: trainer saw a different item count"
        );
        // sum 0..n-1 — catches duplicated or substituted items, not just
        // miscounts
        assert_eq!(
            checksum.load(Ordering::Relaxed),
            n_items * (n_items - 1) / 2,
            "seed {seed}: item payloads lost or duplicated"
        );
    }
}

#[test]
fn injected_failures_abort_cleanly_across_100_seeds() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed ^ 0xE44);
        let depth = 1 + rng.gen_range_usize(3);
        let fail_stage = rng.gen_range(3);
        let fail_at = rng.gen_range(48);

        let result = run_pipeline(
            64,
            depth,
            move |i| {
                if fail_stage == 0 && i == fail_at {
                    Err(Error::Pipeline(format!("sampler down at {i}")))
                } else {
                    Ok(i)
                }
            },
            move |b| {
                if fail_stage == 1 && b == fail_at {
                    Err(Error::Pipeline(format!("gatherer down at {b}")))
                } else {
                    Ok(b)
                }
            },
            move |f| {
                if fail_stage == 2 && f == fail_at {
                    Err(Error::Pipeline(format!("trainer down at {f}")))
                } else {
                    Ok(())
                }
            },
        );
        match result {
            Err(Error::Pipeline(_)) => {}
            Err(e) => panic!("seed {seed}: unexpected error kind {e}"),
            Ok(r) => panic!("seed {seed}: injected failure vanished ({} items)", r.items),
        }
    }
}

#[test]
fn injected_panics_abort_cleanly_in_every_stage() {
    // The poisoned-lock satellite: a panic in any stage thread must
    // surface as a pipeline `Err` carrying the payload — never a hang on
    // a dead queue, never a `.lock().unwrap()` cascade in the neighbor
    // stages.  A hang here shows up as a test-harness timeout.
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xBAD);
        let depth = 1 + rng.gen_range_usize(3);
        let fail_stage = rng.gen_range(3);
        let fail_at = rng.gen_range(48);

        let result = run_pipeline(
            64,
            depth,
            move |i| {
                if fail_stage == 0 && i == fail_at {
                    panic!("sampler panic at {i}");
                }
                Ok(i)
            },
            move |b| {
                if fail_stage == 1 && b == fail_at {
                    panic!("gatherer panic at {b}");
                }
                Ok(b)
            },
            move |f| {
                if fail_stage == 2 && f == fail_at {
                    panic!("trainer panic at {f}");
                }
                Ok(())
            },
        );
        match result {
            Err(Error::Pipeline(msg)) => assert!(
                msg.contains("panicked") && msg.contains("panic at"),
                "seed {seed}: payload lost: {msg}"
            ),
            Err(e) => panic!("seed {seed}: unexpected error kind {e}"),
            Ok(r) => panic!("seed {seed}: injected panic vanished ({} items)", r.items),
        }
    }
}

#[test]
fn gather_worker_panic_inside_a_stage_aborts_the_pipeline_cleanly() {
    // The parallel-gather seam under the pipeline: one item's gather runs
    // `gather_rows_into_parallel` with an out-of-range row, so a *worker
    // thread two levels down* panics.  The worker join converts it to
    // `Error::Pipeline`, the stage returns Err, and the executor aborts
    // through the same close-on-error protocol as a direct stage failure
    // — never a hang on the dead gather stage.
    use ptdirect::tensor::indexing::gather_rows_into_parallel;

    let src = vec![1.0f32; 10 * 4];
    let result = run_pipeline(
        64,
        4,
        Ok,
        move |b| {
            let idx = if b == 23 {
                vec![0u32, 1, 99, 2] // row 99 of a 10-row table
            } else {
                vec![0u32, 1, 2, 3]
            };
            let mut dst = vec![0f32; idx.len() * 4];
            gather_rows_into_parallel(&src, 4, &idx, &mut dst, 4)?;
            Ok(b)
        },
        |_f| Ok(()),
    );
    match result {
        Err(Error::Pipeline(msg)) => assert!(
            msg.contains("gather worker panicked"),
            "worker panic payload lost: {msg}"
        ),
        Err(e) => panic!("unexpected error kind {e}"),
        Ok(r) => panic!("injected worker panic vanished ({} items)", r.items),
    }
}

#[test]
fn unbalanced_stage_mix_keeps_exact_counts() {
    // One stage much slower than the others, all queue depths, both
    // directions — the backpressure and starvation corners.
    for &(slow_stage, depth) in &[(0usize, 1usize), (1, 1), (2, 1), (0, 8), (2, 8)] {
        let delay = |stage: usize| {
            if stage == slow_stage {
                Duration::from_micros(200)
            } else {
                Duration::from_micros(5)
            }
        };
        let trained = AtomicU64::new(0);
        let r = run_pipeline(
            32,
            depth,
            |i| {
                std::thread::sleep(delay(0));
                Ok(i)
            },
            |b| {
                std::thread::sleep(delay(1));
                Ok(b)
            },
            |_f| {
                std::thread::sleep(delay(2));
                trained.fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(r.items, 32);
        assert_eq!(trained.load(Ordering::Relaxed), 32);
    }
}

//! Property-based tests (via the in-tree `util::proptest` harness) for the
//! tiered feature store's invariants:
//!
//!  * hit + miss counts equal the rows requested, whatever the placement
//!    or promotion history;
//!  * hot-set bytes never exceed the configured budget (GPU memory minus
//!    reserve, capped by `hot_frac`);
//!  * gathered values always match `SyntheticFeatures::fill_row` — the
//!    cache is placement metadata, never a second copy of the data;
//!  * the hot-frac endpoints reproduce `UnifiedAligned` (0) and
//!    `GpuResident` (1) costs exactly.

use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::featurestore::{FeatureStore, SyntheticFeatures, TierConfig};
use ptdirect::util::proptest::{check, prop_assert, Gen};
use ptdirect::util::rng::Rng;

fn random_tier_cfg(g: &mut Gen, rows: usize) -> TierConfig {
    let ranking = if g.bool() {
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(g.seed ^ 0xC0FFEE).shuffle(&mut order);
        Some(order)
    } else {
        None
    };
    TierConfig {
        hot_frac: g.f64_in(0.0, 1.0),
        reserve_bytes: 0,
        promote: g.bool(),
        ranking,
        ..TierConfig::default()
    }
}

fn random_gathers(g: &mut Gen, rows: usize) -> Vec<Vec<u32>> {
    let n_gathers = g.usize_in(1, 6);
    (0..n_gathers)
        .map(|_| {
            let len = g.usize_in(1, 200);
            g.vec_u32(len, 0, (rows - 1) as u32)
        })
        .collect()
}

#[test]
fn hits_plus_misses_equal_rows_requested() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 400);
        let dim = g.usize_in(1, 64);
        let cfg = random_tier_cfg(g, rows);
        let store =
            FeatureStore::build_tiered(rows, dim, 8, &SystemProfile::system1(), g.seed, cfg)
                .map_err(|e| e.to_string())?;
        let mut requested = 0u64;
        for idx in random_gathers(g, rows) {
            store.gather(&idx).map_err(|e| e.to_string())?;
            requested += idx.len() as u64;
        }
        let stats = store.tier_stats().expect("tiered store has stats");
        prop_assert(
            stats.hits + stats.misses == requested,
            format!(
                "hits {} + misses {} != requested {requested}",
                stats.hits, stats.misses
            ),
        )
    });
}

#[test]
fn hot_bytes_never_exceed_budget() {
    check(30, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let row_bytes = dim as u64 * 4;
        // Shrink the GPU so the budget actually binds, and reserve a slice.
        let mut sys = SystemProfile::system1();
        sys.gpu_mem_bytes = g.u64_in(0, 64) * row_bytes;
        let mut cfg = random_tier_cfg(g, rows);
        cfg.reserve_bytes = g.u64_in(0, 16) * row_bytes;
        cfg.promote = true; // promotion churn must respect the budget too
        let budget = sys.gpu_mem_bytes.saturating_sub(cfg.reserve_bytes);
        let store = FeatureStore::build_tiered(rows, dim, 8, &sys, g.seed, cfg)
            .map_err(|e| e.to_string())?;
        for idx in random_gathers(g, rows) {
            store.gather(&idx).map_err(|e| e.to_string())?;
            let stats = store.tier_stats().unwrap();
            prop_assert(
                stats.hot_bytes <= budget && stats.hot_bytes <= stats.capacity_bytes,
                format!(
                    "hot {} bytes > budget {budget} (capacity {})",
                    stats.hot_bytes, stats.capacity_bytes
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn gathered_values_match_fill_row_regardless_of_promotion_history() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 200);
        let dim = g.usize_in(1, 48);
        let classes = 8u32;
        let seed = g.seed ^ 0xFEA7;
        let cfg = random_tier_cfg(g, rows);
        let store = FeatureStore::build_tiered(
            rows,
            dim,
            classes,
            &SystemProfile::system1(),
            seed,
            cfg,
        )
        .map_err(|e| e.to_string())?;
        let synth = SyntheticFeatures::new(dim, classes, seed);
        let mut want_row = vec![0f32; dim];
        for idx in random_gathers(g, rows) {
            let (vals, _) = store.gather(&idx).map_err(|e| e.to_string())?;
            for (chunk, &r) in vals.chunks_exact(dim).zip(&idx) {
                synth.fill_row(r, &mut want_row);
                prop_assert(
                    chunk == want_row.as_slice(),
                    format!("row {r} diverged from SyntheticFeatures::fill_row"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn hot_frac_endpoints_reproduce_the_reference_modes() {
    check(25, |g: &mut Gen| {
        let rows = g.usize_in(2, 300);
        let dim = g.usize_in(1, 64);
        let sys = SystemProfile::system1();
        let seed = g.seed;
        let idx = g.vec_u32(g.usize_in(1, 150), 0, (rows - 1) as u32);

        let ua = FeatureStore::build(rows, dim, 8, AccessMode::UnifiedAligned, &sys, seed)
            .map_err(|e| e.to_string())?;
        let (_, c_ua) = ua.gather(&idx).map_err(|e| e.to_string())?;
        let cold = FeatureStore::build_tiered(
            rows,
            dim,
            8,
            &sys,
            seed,
            TierConfig {
                hot_frac: 0.0,
                reserve_bytes: 0,
                promote: g.bool(),
                ranking: Some((0..rows as u32).collect()),
                ..TierConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let (_, c_cold) = cold.gather(&idx).map_err(|e| e.to_string())?;
        prop_assert(
            c_cold.time_s == c_ua.time_s
                && c_cold.requests == c_ua.requests
                && c_cold.bytes_on_link == c_ua.bytes_on_link,
            format!("hot-frac 0 diverged from UnifiedAligned: {c_cold:?} vs {c_ua:?}"),
        )?;

        let hot = FeatureStore::build_tiered(
            rows,
            dim,
            8,
            &sys,
            seed,
            TierConfig {
                hot_frac: 1.0,
                reserve_bytes: 0,
                promote: false,
                ranking: Some((0..rows as u32).collect()),
                ..TierConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let (_, c_hot) = hot.gather(&idx).map_err(|e| e.to_string())?;
        prop_assert(
            c_hot.time_s == sys.kernel_launch_s
                && c_hot.requests == 0
                && c_hot.bytes_on_link == 0,
            format!("hot-frac 1 is not kernel-launch-only: {c_hot:?}"),
        )
    });
}

//! Staged pipeline executor: sample ∥ gather ∥ train over bounded queues.
//!
//! Generic over the three stage functions so tests can run it with stub
//! stages and the trainer with real ones.  Per-stage busy time and queue
//! wait statistics come back in a [`PipelineReport`]; the coordinator folds
//! the *simulated* transfer durations in separately (DESIGN.md §5 — the
//! executor measures the real work, the interconnect models the missing
//! hardware).

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::error::{Error, Result};
use crate::pipeline::queue::BoundedQueue;
use crate::util::timer::Timer;

/// Close a queue when dropped — including during a panic unwind.  Every
/// stage closes its queues on *all* exit paths; without this, a panicking
/// stage would strand its neighbors blocked forever on a queue nobody
/// will ever close again (the executor's join would then deadlock).
struct CloseOnDrop<'q, T>(&'q BoundedQueue<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Render a caught panic payload for the pipeline error message.
fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a stage function, converting a panic into a pipeline [`Error`] so
/// the failed batch surfaces as an `Err` and shutdown stays clean (the
/// shared queues would otherwise see poisoned locks and hung peers).
fn run_stage<R>(stage_name: &str, f: impl FnOnce() -> Result<R>) -> Result<R> {
    catch_unwind(AssertUnwindSafe(f)).unwrap_or_else(|payload| {
        Err(Error::Pipeline(format!(
            "{stage_name} stage panicked: {}",
            panic_msg(payload)
        )))
    })
}

/// Per-stage busy seconds (real wall-clock inside each stage function).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub sample_s: f64,
    pub gather_s: f64,
    pub train_s: f64,
}

/// Pipeline execution summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineReport {
    pub items: u64,
    /// End-to-end wall time of the pipelined run.
    pub wall_s: f64,
    pub stages: StageTimes,
    /// Producer-blocked seconds per queue (backpressure pressure gauge).
    pub q1_push_wait_s: f64,
    pub q2_push_wait_s: f64,
    /// Consumer-blocked seconds per queue (starvation gauge).
    pub q1_pop_wait_s: f64,
    pub q2_pop_wait_s: f64,
}

/// Run `n_items` through sample -> gather -> train with `queue_depth`
/// backpressure windows between stages.
///
/// * `sample(i)` produces a batch;
/// * `gather(batch)` attaches features;
/// * `train(fed)` consumes it.
///
/// Any stage error aborts the pipeline and is returned.  A stage *panic*
/// is contained the same way: caught, converted into [`Error::Pipeline`]
/// with the panic payload, and propagated after both queues close — one
/// bad batch reads as a failed epoch, never as a poisoned-lock cascade or
/// a hung join (`tests/pipeline_stress.rs` injects panics per stage).
pub fn run_pipeline<B, F, S, G, T>(
    n_items: u64,
    queue_depth: usize,
    sample: S,
    gather: G,
    mut train: T,
) -> Result<PipelineReport>
where
    B: Send,
    F: Send,
    S: Fn(u64) -> Result<B> + Send + Sync,
    G: Fn(B) -> Result<F> + Send + Sync,
    T: FnMut(F) -> Result<()> + Send,
{
    let q1: BoundedQueue<B> = BoundedQueue::new(queue_depth);
    let q2: BoundedQueue<F> = BoundedQueue::new(queue_depth);
    let wall = Timer::start();

    let mut report = PipelineReport::default();
    let result: Result<StageTimes> = std::thread::scope(|scope| {
        let q1 = &q1;
        let q2 = &q2;
        let sample = &sample;
        let gather = &gather;

        // Every stage must close its queues on *all* exit paths —
        // including panics, hence the drop guards — or the neighbors
        // block forever on a dead queue.  Stage functions additionally
        // run under `run_stage`, which converts a panic into a pipeline
        // `Err` carrying the payload, so one failed batch aborts the
        // epoch cleanly instead of cascading poisoned-lock panics.
        let sampler = scope.spawn(move || -> Result<f64> {
            let _close_q1 = CloseOnDrop(q1);
            let mut busy = 0.0;
            for i in 0..n_items {
                let t = Timer::start();
                let b = run_stage("sample", || sample(i))?;
                busy += t.elapsed_s();
                if q1.push(b).is_err() {
                    break; // downstream aborted
                }
            }
            Ok(busy)
        });

        let gatherer = scope.spawn(move || -> Result<f64> {
            // Closing q1 too stops a sampler blocked on a full queue.
            let _close_q1 = CloseOnDrop(q1);
            let _close_q2 = CloseOnDrop(q2);
            let mut busy = 0.0;
            while let Some(b) = q1.pop() {
                let t = Timer::start();
                let f = run_stage("gather", || gather(b))?;
                busy += t.elapsed_s();
                if q2.push(f).is_err() {
                    break;
                }
            }
            Ok(busy)
        });

        // Trainer runs on the calling thread.
        let mut train_busy = 0.0;
        let mut train_err: Option<Error> = None;
        let mut items = 0u64;
        while let Some(f) = q2.pop() {
            let t = Timer::start();
            match run_stage("train", || train(f)) {
                Ok(()) => {
                    train_busy += t.elapsed_s();
                    items += 1;
                }
                Err(e) => {
                    train_err = Some(e);
                    q1.close();
                    q2.close();
                    break;
                }
            }
        }

        let sample_busy = sampler
            .join()
            .map_err(|_| Error::Pipeline("sampler thread panicked".into()))??;
        let gather_busy = gatherer
            .join()
            .map_err(|_| Error::Pipeline("gatherer thread panicked".into()))??;
        if let Some(e) = train_err {
            return Err(e);
        }
        report.items = items;
        Ok(StageTimes {
            sample_s: sample_busy,
            gather_s: gather_busy,
            train_s: train_busy,
        })
    });

    report.stages = result?;
    report.wall_s = wall.elapsed_s();
    (report.q1_push_wait_s, report.q1_pop_wait_s) = q1.wait_stats();
    (report.q2_push_wait_s, report.q2_pop_wait_s) = q2.wait_stats();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn processes_all_items_in_order_effects() {
        let mut seen = Vec::new();
        let r = run_pipeline(
            50,
            4,
            |i| Ok(i),
            |b| Ok(b * 2),
            |f| {
                seen.push(f);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(r.items, 50);
        assert_eq!(seen, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn stage_error_aborts_cleanly() {
        let r = run_pipeline(
            100,
            2,
            |i| Ok(i),
            |b| {
                if b == 10 {
                    Err(Error::Pipeline("boom".into()))
                } else {
                    Ok(b)
                }
            },
            |_f| Ok(()),
        );
        assert!(r.is_err());
    }

    #[test]
    fn train_error_aborts_cleanly() {
        let r = run_pipeline(
            100,
            2,
            |i| Ok(i),
            |b| Ok(b),
            |f| {
                if f == 5 {
                    Err(Error::Pipeline("trainer".into()))
                } else {
                    Ok(())
                }
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn gather_panic_becomes_a_pipeline_error_not_a_hang() {
        let r = run_pipeline(
            100,
            2,
            |i| Ok(i),
            |b| {
                if b == 10 {
                    panic!("injected gather panic at {b}");
                }
                Ok(b)
            },
            |_f| Ok(()),
        );
        match r {
            Err(Error::Pipeline(msg)) => {
                assert!(msg.contains("panicked"), "message lost the cause: {msg}");
                assert!(msg.contains("injected gather panic"), "payload dropped: {msg}");
            }
            other => panic!("expected Pipeline error, got {other:?}"),
        }
    }

    #[test]
    fn sample_panic_becomes_a_pipeline_error_not_a_hang() {
        let r = run_pipeline(
            100,
            2,
            |i| {
                if i == 3 {
                    panic!("sampler died");
                }
                Ok(i)
            },
            |b| Ok(b),
            |_f| Ok(()),
        );
        assert!(matches!(r, Err(Error::Pipeline(m)) if m.contains("sample stage panicked")));
    }

    #[test]
    fn train_panic_becomes_a_pipeline_error_not_a_hang() {
        let r = run_pipeline(
            100,
            2,
            |i| Ok(i),
            |b| Ok(b),
            |f| {
                if f == 5 {
                    panic!("trainer died");
                }
                Ok(())
            },
        );
        assert!(matches!(r, Err(Error::Pipeline(m)) if m.contains("train stage panicked")));
    }

    #[test]
    fn executor_is_reusable_after_a_stage_panic() {
        // The shared queues must come back clean: a panicked run followed
        // by a healthy one on fresh queues processes everything.
        let _ = run_pipeline(
            20,
            1,
            |i| Ok(i),
            |b: u64| if b == 0 { panic!("boom") } else { Ok(b) },
            |_f| Ok(()),
        );
        let mut seen = 0u64;
        let r = run_pipeline(
            20,
            1,
            |i| Ok(i),
            |b| Ok(b),
            |_f| {
                seen += 1;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(r.items, 20);
        assert_eq!(seen, 20);
    }

    #[test]
    fn slow_trainer_builds_backpressure() {
        let r = run_pipeline(
            20,
            1,
            |i| Ok(i),
            |b| Ok(b),
            |_f| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(())
            },
        )
        .unwrap();
        // fast producer behind depth-1 queues must have blocked
        assert!(r.q1_push_wait_s + r.q2_push_wait_s > 0.0);
    }

    #[test]
    fn overlap_beats_serial_for_balanced_stages() {
        // 3 stages x 2ms, 16 items: serial = 96ms, pipelined ~ 36ms.
        let stage = || std::thread::sleep(std::time::Duration::from_millis(2));
        let r = run_pipeline(
            16,
            4,
            |i| {
                stage();
                Ok(i)
            },
            |b| {
                stage();
                Ok(b)
            },
            |_f| {
                stage();
                Ok(())
            },
        )
        .unwrap();
        let serial = r.stages.sample_s + r.stages.gather_s + r.stages.train_s;
        assert!(
            r.wall_s < 0.8 * serial,
            "wall {} vs serial {serial}",
            r.wall_s
        );
    }
}

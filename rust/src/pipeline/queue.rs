//! Bounded MPMC queue with blocking push (backpressure) and pop.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Bounded multi-producer multi-consumer FIFO.
///
/// `push` blocks while the queue is full — this is the backpressure window
/// between pipeline stages (a slow trainer stalls the sampler instead of
/// buffering unboundedly).  `close` wakes all waiters; subsequent `pop`s
/// drain the remaining items then return `None`.
///
/// Two robustness properties the executor leans on:
///
/// * the `push_wait_s`/`pop_wait_s` gauges count **only condvar-blocked
///   seconds** — lock-acquisition latency and the instant closed/non-full
///   paths contribute nothing, so the backpressure metric the overlap
///   report prints is actual stall time, not bookkeeping noise;
/// * every lock acquisition recovers from poisoning
///   ([`PoisonError::into_inner`]): a panicked peer thread must degrade
///   into a clean close-and-drain shutdown, not cascade `.unwrap()`
///   panics (or a deadlock) through every other stage.  The queue state
///   is a plain `VecDeque` + counters, valid at every await point, so
///   resuming past a poison is sound.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    /// cumulative seconds producers spent blocked (backpressure metric)
    push_wait_s: f64,
    /// cumulative seconds consumers spent blocked (starvation metric)
    pop_wait_s: f64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State {
                items: VecDeque::with_capacity(capacity),
                closed: false,
                push_wait_s: 0.0,
                pop_wait_s: 0.0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Poison-recovering lock (see the type docs): the queue must keep
    /// functioning after a peer stage thread panicked.
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Blocking push. Returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.lock();
        if st.items.len() >= self.capacity && !st.closed {
            // Time only the condvar-blocked window: the uncontended path
            // (and the instant closed-path rejection) must not inflate
            // the backpressure gauge.
            let t0 = std::time::Instant::now();
            while st.items.len() >= self.capacity && !st.closed {
                st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.push_wait_s += t0.elapsed().as_secs_f64();
        }
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.lock();
        if st.items.is_empty() && !st.closed {
            // Same blocked-only accounting as `push`: draining a
            // non-empty queue (or returning `None` on a closed one) is
            // not starvation and must cost the gauge nothing.
            let t0 = std::time::Instant::now();
            while st.items.is_empty() && !st.closed {
                st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.pop_wait_s += t0.elapsed().as_secs_f64();
        }
        let item = st.items.pop_front();
        drop(st);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let item = self.lock().items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (producer blocked seconds, consumer blocked seconds) — condvar
    /// stall time only, not lock or bookkeeping overhead.
    pub fn wait_stats(&self) -> (f64, f64) {
        let st = self.lock();
        (st.push_wait_s, st.pop_wait_s)
    }

    /// Poison the state mutex on purpose (panic while holding the guard)
    /// so tests can pin the recover-from-poison behavior.
    #[cfg(test)]
    fn poison_for_test(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.state.lock().unwrap();
            panic!("deliberate poison");
        }));
        assert!(self.state.is_poisoned(), "test setup failed to poison");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert!(q.push(9).is_err());
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            // blocks until the main thread pops
            q2.push(1).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.len(), 1); // still full, producer blocked
        assert_eq!(q.pop(), Some(0));
        producer.join().unwrap();
        assert_eq!(q.pop(), Some(1));
        let (push_wait, _) = q.wait_stats();
        assert!(push_wait > 0.0);
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 2000u32;
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..n_items / 2 {
                        q.push(p * (n_items / 2) + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn pop_on_closed_empty_is_none_not_deadlock() {
        let q: BoundedQueue<i32> = BoundedQueue::new(2);
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn unblocked_operations_accumulate_zero_wait() {
        // The satellite bugfix: the gauges must count condvar-blocked
        // seconds only.  A never-full, never-empty-while-popping workload
        // (and the closed fast paths) must leave both at exactly 0.0.
        let q = BoundedQueue::new(8);
        for i in 0..200 {
            q.push(i).unwrap();
            assert_eq!(q.pop(), Some(i));
        }
        q.close();
        assert_eq!(q.pop(), None); // closed-and-drained fast path
        assert!(q.push(0).is_err()); // closed-producer fast path
        let (push_wait, pop_wait) = q.wait_stats();
        assert_eq!(push_wait, 0.0, "uncontended pushes inflated the gauge");
        assert_eq!(pop_wait, 0.0, "uncontended pops inflated the gauge");
    }

    #[test]
    fn starved_pop_counts_blocked_time() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(30));
        q.push(7).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(7));
        let (_, pop_wait) = q.wait_stats();
        assert!(pop_wait > 0.0, "a genuinely starved pop must register");
    }

    #[test]
    fn poisoned_lock_recovers_into_clean_shutdown() {
        // A panicked stage thread must not cascade: push/pop/close on a
        // poisoned queue keep working (close-and-drain), no unwrap panic.
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.poison_for_test();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(q.push(3).is_err());
        let _ = q.wait_stats();
    }

    #[test]
    fn poisoned_lock_does_not_wedge_blocked_waiters() {
        // A waiter blocked on a poisoned-then-closed queue must wake and
        // exit instead of panicking inside the condvar loop.
        let q: Arc<BoundedQueue<i32>> = Arc::new(BoundedQueue::new(1));
        q.poison_for_test();
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().expect("consumer must not panic"), None);
    }
}

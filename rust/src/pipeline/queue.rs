//! Bounded MPMC queue with blocking push (backpressure) and pop.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Bounded multi-producer multi-consumer FIFO.
///
/// `push` blocks while the queue is full — this is the backpressure window
/// between pipeline stages (a slow trainer stalls the sampler instead of
/// buffering unboundedly).  `close` wakes all waiters; subsequent `pop`s
/// drain the remaining items then return `None`.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    /// cumulative seconds producers spent blocked (backpressure metric)
    push_wait_s: f64,
    /// cumulative seconds consumers spent blocked (starvation metric)
    pop_wait_s: f64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State {
                items: VecDeque::with_capacity(capacity),
                closed: false,
                push_wait_s: 0.0,
                pop_wait_s: 0.0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push. Returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let t0 = std::time::Instant::now();
        let mut st = self.state.lock().unwrap();
        while st.items.len() >= self.capacity && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        st.push_wait_s += t0.elapsed().as_secs_f64();
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let t0 = std::time::Instant::now();
        let mut st = self.state.lock().unwrap();
        while st.items.is_empty() && !st.closed {
            st = self.not_empty.wait(st).unwrap();
        }
        st.pop_wait_s += t0.elapsed().as_secs_f64();
        let item = st.items.pop_front();
        drop(st);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let item = self.state.lock().unwrap().items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (producer blocked seconds, consumer blocked seconds).
    pub fn wait_stats(&self) -> (f64, f64) {
        let st = self.state.lock().unwrap();
        (st.push_wait_s, st.pop_wait_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert!(q.push(9).is_err());
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            // blocks until the main thread pops
            q2.push(1).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.len(), 1); // still full, producer blocked
        assert_eq!(q.pop(), Some(0));
        producer.join().unwrap();
        assert_eq!(q.pop(), Some(1));
        let (push_wait, _) = q.wait_stats();
        assert!(push_wait > 0.0);
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 2000u32;
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..n_items / 2 {
                        q.push(p * (n_items / 2) + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn pop_on_closed_empty_is_none_not_deadlock() {
        let q: BoundedQueue<i32> = BoundedQueue::new(2);
        q.close();
        assert_eq!(q.pop(), None);
    }
}

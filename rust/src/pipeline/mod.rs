//! Streaming mini-batch pipeline: bounded queues with blocking backpressure
//! and a staged executor that overlaps sampling, gathering, and training —
//! the data-loader machinery whose CPU-side cost Fig. 3 profiles.
//!
//! Structure: [`BoundedQueue`] is a condvar-based MPMC channel with a
//! fixed depth (the backpressure window — `RunConfig::queue_depth`, set
//! via the `run.queue_depth` TOML key); the
//! [`executor`] wires sampler workers → gather → train stages through two
//! such queues and reports per-stage busy/blocked times
//! ([`StageTimes`]).  Real threads move real batches; the *simulated*
//! transfer durations ride along in each batch's metadata rather than
//! being slept (DESIGN.md §5 — the pipeline overlaps measured work while
//! the epoch model stays analytic).  Error injection and randomized
//! latencies are exercised by `tests/pipeline_stress.rs`.

pub mod executor;
pub mod queue;

pub use executor::{PipelineReport, StageTimes};
pub use queue::BoundedQueue;

//! Streaming mini-batch pipeline: bounded queues with blocking backpressure
//! and a staged executor that overlaps sampling, gathering, and training —
//! the data-loader machinery whose CPU-side cost Fig. 3 profiles.

pub mod executor;
pub mod queue;

pub use executor::{PipelineReport, StageTimes};
pub use queue::BoundedQueue;

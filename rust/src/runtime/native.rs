//! Built-in deterministic trainer — the execution backend that works
//! everywhere, with no AOT artifacts and no PJRT.
//!
//! A multinomial logistic regression over the gathered *root* features
//! (the sampler's destination-prefix convention puts the batch roots in
//! the first `batch` rows of every gathered block).  The synthetic
//! features carry a noisy one-hot of the label (see
//! [`crate::featurestore::SyntheticFeatures`]), so the loss curve shows
//! real learning — which is exactly what the end-to-end tests need to
//! assert the paper's core correctness property: the access mode may only
//! change *cost*, never *numerics*.  Every operation here is plain `f32`
//! arithmetic in a fixed order, so identically-seeded runs produce
//! bitwise-identical loss sequences across all access modes — including
//! `Tiered` and `Sharded` at any GPU count, since both are placement
//! metadata over the same table (DESIGN.md §5/§6).
//!
//! Selection: `--backend native` forces this trainer; `--backend auto`
//! falls back to it whenever the run's AOT artifact is absent, so every
//! CLI path (and CI) trains end-to-end in a container with no XLA build.
//! It is intentionally *not* a GNN — the cost model supplies the
//! simulated GNN step time (DESIGN.md §5); this backend only has to make
//! the numerics real, deterministic, and learnable.

use crate::error::{Error, Result};
use crate::runtime::state::StepMetrics;
use crate::tensor::indexing::gather_rows_into_parallel;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Default SGD learning rate for the native trainer.
pub const DEFAULT_LR: f32 = 0.3;

/// Mutable model state: one dense softmax layer, plain SGD.
pub struct NativeTrainState {
    dim: usize,
    classes: usize,
    lr: f32,
    /// Worker count for the chunked root-row `index_select`
    /// (`--sampler-workers`); chunking only partitions the copy, so the
    /// numerics are bitwise identical at every value.
    workers: usize,
    /// Weights `[dim, classes]`, row-major.
    w: Vec<f32>,
    /// Bias `[classes]`.
    b: Vec<f32>,
    pub steps: u64,
}

impl NativeTrainState {
    /// Glorot-uniform weight init (zeros for the bias), seeded like
    /// [`crate::runtime::TrainState::init`].
    pub fn init(dim: usize, classes: u32, lr: f32, seed: u64) -> NativeTrainState {
        let classes = classes as usize;
        let mut rng = Rng::new(seed);
        let limit = (6.0 / (dim + classes) as f64).sqrt() as f32;
        let w = (0..dim * classes)
            .map(|_| rng.gen_f32_range(-limit, limit))
            .collect();
        NativeTrainState {
            dim,
            classes,
            lr,
            workers: 1,
            w,
            b: vec![0.0; classes],
            steps: 0,
        }
    }

    /// Fan the root-row extraction across `n` workers (clamped to at
    /// least 1).  Purely a throughput knob: see [`NativeTrainState::step`].
    pub fn set_workers(&mut self, n: usize) {
        self.workers = n.max(1);
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Forward pass for one root row: `out = W^T x + b` (`out` has length
    /// `classes`).  The same loop order as [`NativeTrainState::step`], so
    /// inference over a freshly-initialised state is bitwise identical to
    /// the logits the first training step would compute.
    pub fn logits_into(&self, xi: &[f32], out: &mut [f32]) {
        debug_assert_eq!(xi.len(), self.dim);
        debug_assert_eq!(out.len(), self.classes);
        let k = self.classes;
        out.copy_from_slice(&self.b);
        for (d, &xv) in xi.iter().enumerate() {
            let wrow = &self.w[d * k..(d + 1) * k];
            for (l, &wv) in out.iter_mut().zip(wrow) {
                *l += xv * wv;
            }
        }
    }

    /// One SGD step.  `x` is the gathered feature block `[rows, dim]` whose
    /// first `labels.len()` rows are the batch roots; the rest of the block
    /// (sampled neighbors) is ignored by this model.
    pub fn step(&mut self, x: &[f32], labels: &[i32]) -> Result<StepMetrics> {
        let n = labels.len();
        let k = self.classes;
        if n == 0 {
            return Err(Error::Runtime("native step: empty batch".into()));
        }
        if x.len() < n * self.dim {
            return Err(Error::Runtime(format!(
                "native step: {} feature values < {} roots x dim {}",
                x.len(),
                n,
                self.dim
            )));
        }
        let t = Timer::start();

        // Chunked `index_select` of the root block: the roots are the
        // destination prefix of the gathered features, and extracting them
        // goes through the same parallel-gather seam as every other row
        // copy (`--sampler-workers` fans the memcpy).  The chunking only
        // partitions the copy, never reorders it, so the extracted block —
        // and therefore every loss — is bitwise identical at any worker
        // count (pinned by `root_extraction_is_worker_count_invariant`).
        let root_idx: Vec<u32> = (0..n as u32).collect();
        let mut roots = vec![0f32; n * self.dim];
        gather_rows_into_parallel(x, self.dim, &root_idx, &mut roots, self.workers)?;

        let mut grad_w = vec![0f32; self.dim * k];
        let mut grad_b = vec![0f32; k];
        let mut logits = vec![0f32; k];
        let mut loss_sum = 0f32;
        let mut correct = 0usize;

        for i in 0..n {
            let y = labels[i];
            if y < 0 || y as usize >= k {
                return Err(Error::Runtime(format!(
                    "native step: label {y} outside [0, {k})"
                )));
            }
            let y = y as usize;
            let xi = &roots[i * self.dim..(i + 1) * self.dim];

            self.logits_into(xi, &mut logits);

            // numerically-stable softmax cross-entropy
            let max_l = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &l in logits.iter() {
                denom += (l - max_l).exp();
            }
            loss_sum += denom.ln() - (logits[y] - max_l);

            // total_cmp: NaN logits (divergent lr) order last instead of
            // panicking, so the step surfaces the non-finite loss error
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap();
            if argmax == y {
                correct += 1;
            }

            // dL/dlogit = softmax - onehot(y)
            for c in 0..k {
                let g = (logits[c] - max_l).exp() / denom - if c == y { 1.0 } else { 0.0 };
                grad_b[c] += g;
                for (d, &xv) in xi.iter().enumerate() {
                    grad_w[d * k + c] += g * xv;
                }
            }
        }

        let scale = self.lr / n as f32;
        for (w, g) in self.w.iter_mut().zip(&grad_w) {
            *w -= scale * g;
        }
        for (b, g) in self.b.iter_mut().zip(&grad_b) {
            *b -= scale * g;
        }
        self.steps += 1;

        let loss = loss_sum / n as f32;
        if !loss.is_finite() {
            return Err(Error::Runtime(format!(
                "non-finite native loss at step {}: {loss}",
                self.steps
            )));
        }
        Ok(StepMetrics {
            loss,
            acc: correct as f32 / n as f32,
            exec_s: t.elapsed_s(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurestore::SyntheticFeatures;

    fn batch(synth: &SyntheticFeatures, nodes: &[u32]) -> (Vec<f32>, Vec<i32>) {
        let mut x = vec![0f32; nodes.len() * synth.dim];
        for (chunk, &v) in x.chunks_exact_mut(synth.dim).zip(nodes) {
            synth.fill_row(v, chunk);
        }
        let labels = nodes.iter().map(|&v| synth.label(v)).collect();
        (x, labels)
    }

    #[test]
    fn learns_the_synthetic_signal() {
        let synth = SyntheticFeatures::new(32, 8, 7);
        let mut state = NativeTrainState::init(32, 8, DEFAULT_LR, 3);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..30u32 {
            let nodes: Vec<u32> = (0..16u32).map(|i| step * 16 + i).collect();
            let (x, labels) = batch(&synth, &nodes);
            let m = state.step(&x, &labels).unwrap();
            if first.is_none() {
                first = Some(m.loss);
            }
            last = m.loss;
        }
        let first = first.unwrap();
        assert!(
            last < 0.8 * first,
            "no learning: loss {first} -> {last}"
        );
        assert_eq!(state.steps, 30);
    }

    #[test]
    fn deterministic_across_instances() {
        let synth = SyntheticFeatures::new(16, 4, 1);
        let run = || {
            let mut s = NativeTrainState::init(16, 4, DEFAULT_LR, 11);
            let mut losses = Vec::new();
            for step in 0..5u32 {
                let nodes: Vec<u32> = (0..8u32).map(|i| step * 8 + i).collect();
                let (x, labels) = batch(&synth, &nodes);
                losses.push(s.step(&x, &labels).unwrap().loss);
            }
            losses
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn root_extraction_is_worker_count_invariant() {
        // The chunked index_select over the root block must be bitwise
        // neutral: any `--sampler-workers` value produces the exact same
        // loss sequence AND the exact same final parameters as workers=1.
        let synth = SyntheticFeatures::new(24, 6, 9);
        let run = |workers: usize| {
            let mut s = NativeTrainState::init(24, 6, DEFAULT_LR, 13);
            s.set_workers(workers);
            let mut losses = Vec::new();
            for step in 0..6u32 {
                let nodes: Vec<u32> = (0..11u32).map(|i| (step * 11 + i) % 64).collect();
                let (x, labels) = batch(&synth, &nodes);
                losses.push(s.step(&x, &labels).unwrap().loss.to_bits());
            }
            let w_bits: Vec<u32> = s.w.iter().map(|v| v.to_bits()).collect();
            let b_bits: Vec<u32> = s.b.iter().map(|v| v.to_bits()).collect();
            (losses, w_bits, b_bits)
        };
        let reference = run(1);
        for workers in [2usize, 3, 8, 64] {
            assert_eq!(run(workers), reference, "workers={workers}");
        }
    }

    #[test]
    fn ignores_non_root_rows() {
        // Extra (neighbor) rows after the roots must not change the step.
        let synth = SyntheticFeatures::new(16, 4, 2);
        let nodes: Vec<u32> = (0..8).collect();
        let (x, labels) = batch(&synth, &nodes);
        let mut padded = x.clone();
        padded.extend(vec![99.0f32; 4 * 16]); // junk neighbor rows
        let mut a = NativeTrainState::init(16, 4, DEFAULT_LR, 5);
        let mut b = NativeTrainState::init(16, 4, DEFAULT_LR, 5);
        let la = a.step(&x, &labels).unwrap().loss;
        let lb = b.step(&padded, &labels).unwrap().loss;
        assert_eq!(la, lb);
    }

    #[test]
    fn nan_features_error_instead_of_panic() {
        // NaN propagates into every logit; argmax must stay total-ordered
        // (no partial_cmp panic) and the step must surface the non-finite
        // loss as a runtime error.
        let mut s = NativeTrainState::init(8, 4, DEFAULT_LR, 1);
        let x = vec![f32::NAN; 8];
        let err = s.step(&x, &[0]).unwrap_err();
        assert!(format!("{err}").contains("non-finite"), "{err}");
    }

    #[test]
    fn logits_match_step_order() {
        let synth = SyntheticFeatures::new(16, 4, 2);
        let nodes: Vec<u32> = (0..4).collect();
        let (x, _) = batch(&synth, &nodes);
        let s = NativeTrainState::init(16, 4, DEFAULT_LR, 5);
        let mut out = vec![0f32; 4];
        s.logits_into(&x[..16], &mut out);
        // bias starts at zero, weights are Glorot: logits must be finite
        // and not all identical
        assert!(out.iter().all(|l| l.is_finite()));
        assert!(out.iter().any(|&l| l != out[0]));
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut s = NativeTrainState::init(8, 4, DEFAULT_LR, 1);
        assert!(s.step(&[0.0; 8], &[]).is_err()); // empty batch
        assert!(s.step(&[0.0; 8], &[0, 1]).is_err()); // too few rows
        assert!(s.step(&[0.0; 8], &[9]).is_err()); // label out of range
    }
}

//! Training state: parameter/momentum literals + the fused step call.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactSpec, IoRole};
use crate::runtime::client::{literal_f32, literal_i32, literal_scalar_f32, LoadedArtifact};
use crate::tensor::DType;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Batch tensors for one training step, shaped per the artifact manifest.
#[derive(Clone, Debug)]
pub struct StepBatch {
    /// Gathered input features `[layer_sizes[0], in_dim]` (row-major).
    pub x0: Vec<f32>,
    /// Per-layer local neighbor indices `[n_dst, fanout]`.
    pub nbrs: Vec<Vec<i32>>,
    /// Per-layer masks.
    pub masks: Vec<Vec<f32>>,
    /// Root labels `[batch]`.
    pub labels: Vec<i32>,
}

/// Step metrics.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub acc: f32,
    /// Measured PJRT execution seconds.
    pub exec_s: f64,
}

/// Owns the model's mutable state across steps.
pub struct TrainState {
    param_names: Vec<String>,
    params: Vec<xla::Literal>,
    momenta: Vec<xla::Literal>,
    pub steps: u64,
}

impl TrainState {
    /// Glorot-uniform init from the artifact's parameter shapes (matrices),
    /// zeros for vectors and momenta — matching `model.init_params`.
    pub fn init(spec: &ArtifactSpec, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut param_names = Vec::new();
        let mut params = Vec::new();
        let mut momenta = Vec::new();
        for io in spec.inputs.iter().filter(|i| i.role == IoRole::Param) {
            if io.dtype != DType::F32 {
                return Err(Error::Runtime(format!("param {} not f32", io.name)));
            }
            let n = io.numel();
            let data: Vec<f32> = if io.dims.len() == 2 {
                let limit = (6.0 / (io.dims[0] + io.dims[1]) as f64).sqrt() as f32;
                (0..n).map(|_| rng.gen_f32_range(-limit, limit)).collect()
            } else {
                vec![0f32; n]
            };
            param_names.push(io.name.clone());
            params.push(literal_f32(&data, &io.dims)?);
            momenta.push(literal_f32(&vec![0f32; n], &io.dims)?);
        }
        Ok(TrainState {
            param_names,
            params,
            momenta,
            steps: 0,
        })
    }

    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    /// Read one parameter back as f32 values (tests / checkpoints).
    pub fn param_values(&self, name: &str) -> Result<Vec<f32>> {
        let i = self
            .param_names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| Error::Runtime(format!("no param {name}")))?;
        Ok(self.params[i].to_vec::<f32>()?)
    }

    /// Run one fused train step; updates params/momenta in place.
    pub fn step(
        &mut self,
        artifact: &LoadedArtifact,
        batch: &StepBatch,
    ) -> Result<StepMetrics> {
        let spec = &artifact.spec;
        let nl = spec.fanouts.len();
        if batch.nbrs.len() != nl || batch.masks.len() != nl {
            return Err(Error::Runtime(format!(
                "batch has {} layers, artifact {}",
                batch.nbrs.len(),
                nl
            )));
        }

        // data literals in manifest order: x0, nbr0.., mask0.., labels
        let x0_dims = [spec.layer_sizes[0], spec.in_dim];
        if batch.x0.len() != x0_dims[0] * x0_dims[1] {
            return Err(Error::Runtime(format!(
                "x0 len {} != {}x{}",
                batch.x0.len(),
                x0_dims[0],
                x0_dims[1]
            )));
        }
        let x0 = literal_f32(&batch.x0, &x0_dims)?;
        let mut nbr_lits = Vec::with_capacity(nl);
        let mut mask_lits = Vec::with_capacity(nl);
        for l in 0..nl {
            let dims = [spec.layer_sizes[l + 1], spec.fanouts[l]];
            nbr_lits.push(literal_i32(&batch.nbrs[l], &dims)?);
            mask_lits.push(literal_f32(&batch.masks[l], &dims)?);
        }
        let labels = literal_i32(&batch.labels, &[spec.batch])?;

        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(2 * self.params.len() + 2 * nl + 2);
        inputs.extend(self.params.iter());
        inputs.extend(self.momenta.iter());
        inputs.push(&x0);
        inputs.extend(nbr_lits.iter());
        inputs.extend(mask_lits.iter());
        inputs.push(&labels);

        let t = Timer::start();
        let mut outs = artifact.execute(&inputs)?;
        let exec_s = t.elapsed_s();

        // outputs: loss, acc, new params, new momenta
        let np = self.params.len();
        if outs.len() != 2 + 2 * np {
            return Err(Error::Runtime(format!(
                "expected {} outputs, got {}",
                2 + 2 * np,
                outs.len()
            )));
        }
        let loss = literal_scalar_f32(&outs[0])?;
        let acc = literal_scalar_f32(&outs[1])?;
        // replace state in-place (drain from the back to avoid clones)
        let momenta_new: Vec<xla::Literal> = outs.split_off(2 + np);
        let params_new: Vec<xla::Literal> = outs.split_off(2);
        self.params = params_new;
        self.momenta = momenta_new;
        self.steps += 1;

        if !loss.is_finite() {
            return Err(Error::Runtime(format!(
                "non-finite loss at step {}: {loss}",
                self.steps
            )));
        }
        Ok(StepMetrics { loss, acc, exec_s })
    }
}

//! PJRT runtime: load the AOT artifacts (`make artifacts`) and execute them
//! on the request path with zero Python.
//!
//! * [`artifact`] — parser for `artifacts/manifest.txt` (the calling
//!   convention `python/compile/aot.py` records).
//! * [`client`] — `xla` crate wrapper: HLO text → compile → execute.
//! * [`state`] — training state (params/momenta literals) + the step call.
//! * [`native`] — built-in deterministic trainer (no artifacts, no PJRT):
//!   the fallback backend every environment can execute.

pub mod artifact;
pub mod client;
pub mod native;
pub mod state;

pub use artifact::{ArtifactKind, ArtifactSpec, IoRole, IoSpec, Manifest};
pub use client::{LoadedArtifact, Runtime};
pub use native::NativeTrainState;
pub use state::TrainState;

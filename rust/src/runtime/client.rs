//! PJRT client wrapper: HLO text -> compile -> execute.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot.py).

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactSpec, IoRole};
use crate::tensor::DType;
use crate::util::timer::Timer;

/// Process-wide PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client (the simulated GPU's executor).
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, dir: &Path, spec: &ArtifactSpec) -> Result<LoadedArtifact> {
        let path = spec.hlo_path(dir);
        if !path.exists() {
            return Err(Error::ArtifactMissing(path.display().to_string()));
        }
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!(
            "compiled artifact `{}` in {:.2}s ({} inputs, {} outputs)",
            spec.name,
            t.elapsed_s(),
            spec.inputs.len(),
            spec.outputs.len()
        );
        Ok(LoadedArtifact {
            spec: spec.clone(),
            exe,
            compile_s: t.elapsed_s(),
        })
    }
}

/// A compiled executable plus its calling convention.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    pub compile_s: f64,
}

impl LoadedArtifact {
    /// Execute with literal inputs; returns the flattened output tuple.
    ///
    /// The AOT programs are lowered with `return_tuple=True`, so PJRT hands
    /// back a single tuple literal which we decompose in manifest order.
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "artifact `{}` expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "artifact `{}` returned {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            )));
        }
        Ok(outs)
    }
}

/// Build an f32 literal of `dims` from a slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Build an i32 literal of `dims` from a slice.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// Build a zeroed literal for an IO spec.
pub fn literal_zeros(dtype: DType, dims: &[usize]) -> Result<xla::Literal> {
    let numel: usize = dims.iter().product();
    match dtype {
        DType::F32 => literal_f32(&vec![0f32; numel], dims),
        DType::I32 => literal_i32(&vec![0i32; numel], dims),
        other => Err(Error::Runtime(format!("unsupported literal dtype {other}"))),
    }
}

/// Read back an f32 literal (any shape) as a Vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Scalar f32 readout.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Role-aware input assembly check (used by TrainState; exposed for tests).
pub fn check_roles(spec: &ArtifactSpec) -> (usize, usize, usize) {
    let n_param = spec.inputs.iter().filter(|i| i.role == IoRole::Param).count();
    let n_mom = spec
        .inputs
        .iter()
        .filter(|i| i.role == IoRole::Momentum)
        .count();
    let n_data = spec.inputs.iter().filter(|i| i.role == IoRole::Data).count();
    (n_param, n_mom, n_data)
}

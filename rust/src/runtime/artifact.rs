//! Manifest parser for the AOT artifact calling convention.
//!
//! The manifest is a line-oriented text format written by
//! `python/compile/aot.py` (kept deliberately trivial — serde is not
//! available offline, and the format must stay greppable):
//!
//! ```text
//! artifact sage_product
//! file sage_product.hlo.txt
//! kind train
//! arch sage
//! batch 64
//! ...
//! input param l0_b f32 64
//! input data x0 f32 2304x100
//! output metric loss f32 scalar
//! end
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::DType;

/// What an input/output slot carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoRole {
    Param,
    Momentum,
    Data,
    Metric,
}

impl IoRole {
    fn parse(s: &str) -> Option<IoRole> {
        match s {
            "param" => Some(IoRole::Param),
            "momentum" => Some(IoRole::Momentum),
            "data" => Some(IoRole::Data),
            "metric" => Some(IoRole::Metric),
            _ => None,
        }
    }
}

/// One input or output slot.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub role: IoRole,
    pub name: String,
    pub dtype: DType,
    /// Empty for scalars.
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Artifact kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Train,
    Infer,
    Gather,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "train" => Some(ArtifactKind::Train),
            "infer" => Some(ArtifactKind::Infer),
            "gather" => Some(ArtifactKind::Gather),
            _ => None,
        }
    }
}

/// One artifact's full calling convention + model hyperparameters.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub arch: Option<String>,
    pub batch: usize,
    pub hidden: usize,
    pub in_dim: usize,
    pub classes: usize,
    pub fanouts: Vec<usize>,
    pub layer_sizes: Vec<usize>,
    pub lr: f64,
    pub momentum: f64,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    fn empty(name: String) -> Self {
        ArtifactSpec {
            name,
            file: String::new(),
            kind: ArtifactKind::Train,
            arch: None,
            batch: 0,
            hidden: 0,
            in_dim: 0,
            classes: 0,
            fanouts: Vec::new(),
            layer_sizes: Vec::new(),
            lr: 0.0,
            momentum: 0.0,
            inputs: Vec::new(),
            outputs: Vec::new(),
        }
    }

    pub fn params(&self) -> impl Iterator<Item = &IoSpec> {
        self.inputs.iter().filter(|i| i.role == IoRole::Param)
    }

    pub fn data_inputs(&self) -> impl Iterator<Item = &IoSpec> {
        self.inputs.iter().filter(|i| i.role == IoRole::Data)
    }

    pub fn num_params(&self) -> usize {
        self.params().count()
    }

    /// Total trainable parameter element count.
    pub fn param_elems(&self) -> usize {
        self.params().map(|p| p.numel()).sum()
    }

    /// Rows the feature gather must deliver per step (= layer_sizes[0]).
    pub fn gather_rows(&self) -> usize {
        self.layer_sizes.first().copied().unwrap_or(0)
    }

    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

/// All artifacts in a directory.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| Error::Manifest(format!("bad dim `{d}`")))
        })
        .collect()
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| Error::Manifest(format!("bad int `{d}`")))
        })
        .collect()
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        if !path.exists() {
            return Err(Error::ArtifactMissing(path.display().to_string()));
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut man = Manifest {
            artifacts: BTreeMap::new(),
            dir: dir.to_path_buf(),
        };
        let mut cur: Option<ArtifactSpec> = None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            let err = |msg: &str| Error::Manifest(format!("line {}: {msg}", ln + 1));
            match key {
                "artifact" => {
                    if cur.is_some() {
                        return Err(err("artifact before previous `end`"));
                    }
                    cur = Some(ArtifactSpec::empty(
                        rest.first().ok_or_else(|| err("missing name"))?.to_string(),
                    ));
                }
                "end" => {
                    let spec = cur.take().ok_or_else(|| err("end without artifact"))?;
                    if spec.file.is_empty() {
                        return Err(err("artifact missing `file`"));
                    }
                    man.artifacts.insert(spec.name.clone(), spec);
                }
                _ => {
                    let spec = cur.as_mut().ok_or_else(|| err("field outside artifact"))?;
                    match key {
                        "file" => spec.file = rest.concat(),
                        "kind" => {
                            spec.kind = ArtifactKind::parse(rest.first().copied().unwrap_or(""))
                                .ok_or_else(|| err("bad kind"))?
                        }
                        "arch" => spec.arch = rest.first().map(|s| s.to_string()),
                        "batch" => spec.batch = rest[0].parse().map_err(|_| err("bad batch"))?,
                        "hidden" => spec.hidden = rest[0].parse().map_err(|_| err("bad hidden"))?,
                        "in_dim" => spec.in_dim = rest[0].parse().map_err(|_| err("bad in_dim"))?,
                        "classes" => {
                            spec.classes = rest[0].parse().map_err(|_| err("bad classes"))?
                        }
                        "fanouts" => spec.fanouts = parse_usize_list(rest[0])?,
                        "layer_sizes" => spec.layer_sizes = parse_usize_list(rest[0])?,
                        "lr" => spec.lr = rest[0].parse().map_err(|_| err("bad lr"))?,
                        "momentum" => {
                            spec.momentum = rest[0].parse().map_err(|_| err("bad momentum"))?
                        }
                        "input" | "output" => {
                            if rest.len() != 4 {
                                return Err(err("io line needs: role name dtype dims"));
                            }
                            let io = IoSpec {
                                role: IoRole::parse(rest[0]).ok_or_else(|| err("bad role"))?,
                                name: rest[1].to_string(),
                                dtype: DType::parse(rest[2]).ok_or_else(|| err("bad dtype"))?,
                                dims: parse_dims(rest[3])?,
                            };
                            if key == "input" {
                                spec.inputs.push(io);
                            } else {
                                spec.outputs.push(io);
                            }
                        }
                        _ => return Err(err(&format!("unknown key `{key}`"))),
                    }
                }
            }
        }
        if cur.is_some() {
            return Err(Error::Manifest("unterminated artifact".into()));
        }
        Ok(man)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::ArtifactMissing(name.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact sage_tiny
file sage_tiny.hlo.txt
kind train
arch sage
batch 4
hidden 8
in_dim 12
classes 5
fanouts 2,2
layer_sizes 36,12,4
lr 0.003
momentum 0.9
input param l0_b f32 8
input param l0_w_nbr f32 12x8
input momentum l0_b f32 8
input data x0 f32 36x12
input data nbr0 i32 12x2
input data labels i32 4
output metric loss f32 scalar
output param l0_b f32 8
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let a = m.get("sage_tiny").unwrap();
        assert_eq!(a.kind, ArtifactKind::Train);
        assert_eq!(a.batch, 4);
        assert_eq!(a.fanouts, vec![2, 2]);
        assert_eq!(a.layer_sizes, vec![36, 12, 4]);
        assert_eq!(a.gather_rows(), 36);
        assert_eq!(a.num_params(), 2);
        assert_eq!(a.param_elems(), 8 + 96);
        let loss = &a.outputs[0];
        assert_eq!(loss.dims, Vec::<usize>::new());
        assert_eq!(loss.numel(), 1);
        assert!((a.lr - 0.003).abs() < 1e-12);
    }

    #[test]
    fn missing_artifact_lookup_errors() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(matches!(m.get("nope"), Err(Error::ArtifactMissing(_))));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("input param x f32 4\n", Path::new("/")).is_err());
        assert!(Manifest::parse("artifact a\nfile f\nbogus 1\nend\n", Path::new("/")).is_err());
        assert!(Manifest::parse("artifact a\nfile f\n", Path::new("/")).is_err());
        assert!(Manifest::parse("artifact a\nkind train\nend\n", Path::new("/")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration hook: when `make artifacts` has run, the real manifest
        // must parse and contain the 12 Fig. 8 variants.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for arch in ["sage", "gat"] {
            for ds in ["reddit", "product", "twit", "sk", "paper", "wiki"] {
                let a = m.get(&format!("{arch}_{ds}")).unwrap();
                assert_eq!(a.kind, ArtifactKind::Train);
                assert!(a.hlo_path(&m.dir).exists(), "{} hlo missing", a.name);
            }
        }
        assert!(m.get("gather_aligned").is_ok());
    }
}

//! Simulated GPU device model.
//!
//! We have no GPU in this environment; what the paper's contribution needs
//! from one is (a) the *memory request stream* its gather kernels generate —
//! modeled bit-exactly in [`warp`] — and (b) per-launch overheads — constants
//! in [`crate::config::SystemProfile`].  Actual numerics run on the PJRT CPU
//! client (see [`crate::runtime`]).

pub mod warp;

pub use warp::{count_requests, count_requests_naive_ref, per_row_requests, GatherTraffic};

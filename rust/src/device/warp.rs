//! Warp-level PCIe request coalescing model — paper §4.5 / Fig. 4 & 5.
//!
//! This mirrors the Python specification in `python/compile/coalesce.py`
//! (which in turn mirrors the circular-shift arithmetic in the Pallas
//! gather kernel); the cross-language fixture test pins both to the same
//! numbers, including the paper's Fig. 5 toy example (row 2 drops from 7 to
//! 5 requests).
//!
//! Model: the indexing kernel assigns one thread per (row, feature) element,
//! contiguously over the flattened access sequence.  Each warp issues one
//! PCIe read request per *distinct cacheline* touched by its threads (Min et
//! al. 2020).  The circular-shift optimization rotates each row's in-row
//! access order by `s_r = (t_begin_r - row_start_r) mod cl` so interior
//! warps see exactly one aligned cacheline window.
//!
//! [`count_requests`] is the O(#warps) production implementation used in the
//! hot simulation path; [`count_requests_naive_ref`] is the obviously
//! correct O(#elements) oracle the property tests compare against.

/// Parameters of the access-generation model.
#[derive(Clone, Copy, Debug)]
pub struct WarpModel {
    /// Threads per warp (32 on real hardware).
    pub warp: u64,
    /// Cacheline size in *elements* (128 B / 4 B = 32 on real hardware).
    pub cl_elems: u64,
    /// Element size in bytes (4 for f32 features).
    pub elem_bytes: u64,
}

impl Default for WarpModel {
    fn default() -> Self {
        WarpModel {
            warp: 32,
            cl_elems: 32,
            elem_bytes: 4,
        }
    }
}

impl WarpModel {
    /// Model for a given element width, keeping the 128-byte hardware
    /// cacheline: narrower elements pack more per line (`cl_elems` =
    /// 128 / `elem_bytes`), so fp16/int8 storage (DESIGN.md §13) halves
    /// or quarters `bytes_moved` without touching the warp geometry.
    /// `for_elem_bytes(4)` equals `WarpModel::default()` — the fp32
    /// bit-exact anchor.  Widths that don't divide 128 round `cl_elems`
    /// down to the nearest power of two (the counter requires one).
    pub fn for_elem_bytes(elem_bytes: u64) -> WarpModel {
        let eb = elem_bytes.clamp(1, 128);
        let raw = (128 / eb).max(1);
        let cl = 1u64 << (63 - raw.leading_zeros());
        WarpModel { warp: 32, cl_elems: cl, elem_bytes: eb }
    }

    /// Recover the precision a store's constructor encoded in its row
    /// width: `elem_bytes = row_bytes / feat_elems`.  The sharded/NVMe
    /// cost models call this instead of `WarpModel::default()` so that
    /// a table built with `--precision fp16|int8` prices the narrowed
    /// row on every link.  Falls back to the f32 default when the
    /// division is not exact (defensive: no existing caller hits it).
    pub fn for_row_layout(row_bytes: u64, feat_elems: u64) -> WarpModel {
        if feat_elems > 0 && row_bytes >= feat_elems && row_bytes % feat_elems == 0 {
            WarpModel::for_elem_bytes(row_bytes / feat_elems)
        } else {
            WarpModel::default()
        }
    }

    /// Whether the circular-shift optimization applies to a feature width.
    ///
    /// The paper's kernel "appl[ies] this optimization only when ... the
    /// feature widths are not naturally aligned to 128-byte granularity";
    /// we additionally require the row to span at least two cachelines —
    /// for shorter rows the rotation's wrap segment can *fragment* accesses
    /// (no interior warp exists to pay for the extra wrap line), which the
    /// property tests demonstrate; an exhaustive scan (see
    /// python/tests/test_coalesce.py) shows f >= 2*cl is violation-free.
    pub fn shift_applies(&self, feat_elems: u64) -> bool {
        feat_elems >= 2 * self.cl_elems && feat_elems % self.cl_elems != 0
    }
}

/// Request statistics for one gather operation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GatherTraffic {
    /// Total PCIe read requests issued.
    pub requests: u64,
    /// Distinct cachelines touched (a lower bound on `requests`).
    pub cachelines: u64,
    /// Bytes actually moved over the link: `requests * cacheline_bytes`
    /// (includes I/O amplification from fragmentation).
    pub bytes_moved: u64,
    /// Bytes the application consumes: `rows * feat_elems * elem_bytes`.
    pub useful_bytes: u64,
}

impl GatherTraffic {
    /// I/O amplification factor (>= 1 in practice).
    pub fn amplification(&self) -> f64 {
        if self.useful_bytes == 0 {
            1.0
        } else {
            self.bytes_moved as f64 / self.useful_bytes as f64
        }
    }
}

#[inline]
fn shift_for(t_begin: u64, row_start: u64, cl: u64, shifted: bool) -> u64 {
    if shifted {
        // (t_begin - row_start) mod cl, computed without going negative.
        // cl is a power of two (asserted by count_requests), so mod = mask.
        let mask = cl - 1;
        ((t_begin & mask) + cl - (row_start & mask)) & mask
    } else {
        0
    }
}

/// Count the distinct cachelines hit by threads `[t_lo, t_hi)` of a row whose
/// access function is `addr(t) = start + ((t - t_row + s) mod f)`.
///
/// The rotated in-row sequence consists of at most two contiguous address
/// runs: positions `[0, f-s)` map to `[start+s, start+f)` and positions
/// `[f-s, f)` wrap to `[start, start+s)`.  A warp covers a contiguous span
/// of positions, so it intersects at most both runs; each intersection is an
/// address interval whose cacheline span is closed-form.
#[inline]
fn row_warp_lines(
    start: u64,
    f: u64,
    s: u64,
    pos_lo: u64,
    pos_hi: u64,
    cl_shift: u32,
    lines: &mut [(u64, u64); 2],
) -> usize {
    debug_assert!(s < f.max(1));
    let mut n = 0;
    // run A: positions [0, f-s) -> addresses [start+s, start+f)
    let a_lo = pos_lo.min(f - s);
    let a_hi = pos_hi.min(f - s);
    if a_lo < a_hi {
        let addr_lo = start + s + a_lo;
        let addr_hi = start + s + a_hi; // exclusive
        lines[n] = (addr_lo >> cl_shift, (addr_hi - 1) >> cl_shift);
        n += 1;
    }
    // run B: positions [f-s, f) -> addresses [start, start+s)
    let b_lo = pos_lo.max(f - s);
    let b_hi = pos_hi;
    if b_lo < b_hi {
        let addr_lo = start + (b_lo - (f - s));
        let addr_hi = start + (b_hi - (f - s));
        lines[n] = (addr_lo >> cl_shift, (addr_hi - 1) >> cl_shift);
        n += 1;
    }
    n
}

/// Production request counter: O(#warps) regardless of feature width.
pub fn count_requests(
    idx: &[u32],
    feat_elems: u64,
    model: WarpModel,
    shifted: bool,
) -> GatherTraffic {
    let WarpModel { warp, cl_elems: cl, elem_bytes } = model;
    if feat_elems == 0 || idx.is_empty() {
        return GatherTraffic::default();
    }
    assert!(
        cl.is_power_of_two(),
        "cacheline size must be a power of two"
    );
    let cl_shift = cl.trailing_zeros();
    let f = feat_elems;
    let mut requests: u64 = 0;

    // Distinct cachelines across the whole gather (dedup identical rows and
    // overlapping rows by sorting line intervals).
    let mut row_line_ranges: Vec<(u64, u64)> = idx
        .iter()
        .map(|&r| {
            let start = r as u64 * f;
            (start >> cl_shift, (start + f - 1) >> cl_shift)
        })
        .collect();
    row_line_ranges.sort_unstable();
    let mut cachelines: u64 = 0;
    let mut last_line: Option<u64> = None;
    for (lo, hi) in row_line_ranges {
        let lo_eff = match last_line {
            Some(l) if l >= lo => {
                if l >= hi {
                    continue;
                }
                l + 1
            }
            _ => lo,
        };
        cachelines += hi - lo_eff + 1;
        last_line = Some(match last_line {
            Some(l) => l.max(hi),
            None => hi,
        });
    }

    // Per-warp distinct lines. Warps are windows of `warp` consecutive
    // threads over the concatenated per-row position ranges; the row serving
    // global thread `t` is simply `t / f`.
    let total_threads = idx.len() as u64 * f;
    let mut w_lo: u64 = 0;
    let mut lines_buf: Vec<(u64, u64)> = Vec::with_capacity(8);
    while w_lo < total_threads {
        let w_hi = (w_lo + warp).min(total_threads);
        lines_buf.clear();
        let first_row = (w_lo / f) as usize;
        let last_row = ((w_hi - 1) / f) as usize;
        if first_row == last_row {
            // Fast path (dominant when f >= warp): the warp touches one
            // row, at most two address runs — count their line union
            // without the buffer + sort machinery. ~3x on the fig6 grid.
            let rft = first_row as u64 * f;
            let start = idx[first_row] as u64 * f;
            let s = shift_for(rft, start, cl, shifted) % f;
            let mut two = [(0u64, 0u64); 2];
            let n = row_warp_lines(start, f, s, w_lo - rft, w_hi - rft, cl_shift, &mut two);
            requests += match n {
                0 => 0,
                1 => two[0].1 - two[0].0 + 1,
                _ => {
                    let (a, b) = if two[0].0 <= two[1].0 {
                        (two[0], two[1])
                    } else {
                        (two[1], two[0])
                    };
                    if b.0 <= a.1 {
                        a.1.max(b.1) - a.0 + 1 // overlapping/adjacent union
                    } else {
                        (a.1 - a.0 + 1) + (b.1 - b.0 + 1)
                    }
                }
            };
            w_lo = w_hi;
            continue;
        }
        for rpos in first_row..=last_row {
            let rft = rpos as u64 * f; // row's first global thread id
            let start = idx[rpos] as u64 * f;
            // (c + s) mod f only depends on s mod f, so reduce here; the
            // naive reference applies the same reduction implicitly.
            let s = shift_for(rft, start, cl, shifted) % f;
            let pos_lo = w_lo.max(rft) - rft;
            let pos_hi = w_hi.min(rft + f) - rft;
            let mut two = [(0u64, 0u64); 2];
            let n = row_warp_lines(start, f, s, pos_lo, pos_hi, cl_shift, &mut two);
            for &(lo, hi) in &two[..n] {
                lines_buf.push((lo, hi));
            }
        }
        // count distinct lines across collected [lo, hi] ranges
        lines_buf.sort_unstable();
        let mut cnt: u64 = 0;
        let mut last: Option<u64> = None;
        for &(lo, hi) in &lines_buf {
            let lo_eff = match last {
                Some(l) if l >= lo => {
                    if l >= hi {
                        continue;
                    }
                    l + 1
                }
                _ => lo,
            };
            cnt += hi - lo_eff + 1;
            last = Some(match last {
                Some(l) => l.max(hi),
                None => hi,
            });
        }
        requests += cnt;
        w_lo = w_hi;
    }

    GatherTraffic {
        requests,
        cachelines,
        bytes_moved: requests * cl * elem_bytes,
        useful_bytes: idx.len() as u64 * f * elem_bytes,
    }
}

/// Obviously-correct O(#elements) reference (kept for the property tests and
/// small fixtures; do not use in the simulation hot path).
pub fn count_requests_naive_ref(
    idx: &[u32],
    feat_elems: u64,
    model: WarpModel,
    shifted: bool,
) -> GatherTraffic {
    use std::collections::HashSet;
    let WarpModel { warp, cl_elems: cl, elem_bytes } = model;
    if feat_elems == 0 || idx.is_empty() {
        return GatherTraffic::default();
    }
    let f = feat_elems;
    let mut requests = 0u64;
    let mut all: HashSet<u64> = HashSet::new();
    let mut warp_lines: HashSet<u64> = HashSet::new();
    let mut n_in_warp = 0u64;
    let mut t_begin = 0u64;
    for &r in idx {
        let start = r as u64 * f;
        let s = shift_for(t_begin, start, cl, shifted);
        for c in 0..f {
            let addr = start + ((c + s) % f);
            warp_lines.insert(addr / cl);
            all.insert(addr / cl);
            n_in_warp += 1;
            if n_in_warp == warp {
                requests += warp_lines.len() as u64;
                warp_lines.clear();
                n_in_warp = 0;
            }
        }
        t_begin += f;
    }
    if n_in_warp > 0 {
        requests += warp_lines.len() as u64;
    }
    GatherTraffic {
        requests,
        cachelines: all.len() as u64,
        bytes_moved: requests * cl * elem_bytes,
        useful_bytes: idx.len() as u64 * f * elem_bytes,
    }
}

/// Per-row request attribution (paper Fig. 5 counts the requests servicing
/// one row).  O(#elements); fixture-sized inputs only.
pub fn per_row_requests(idx: &[u32], feat_elems: u64, model: WarpModel, shifted: bool) -> Vec<u64> {
    use std::collections::HashMap;
    use std::collections::HashSet;
    let WarpModel { warp, cl_elems: cl, .. } = model;
    let f = feat_elems;
    let mut counts = vec![0u64; idx.len()];
    if f == 0 || idx.is_empty() {
        return counts;
    }
    // (addr, row position) pairs in thread order
    let mut pairs: Vec<(u64, usize)> = Vec::with_capacity(idx.len() * f as usize);
    let mut t_begin = 0u64;
    for (rpos, &r) in idx.iter().enumerate() {
        let start = r as u64 * f;
        let s = shift_for(t_begin, start, cl, shifted);
        for c in 0..f {
            pairs.push((start + ((c + s) % f), rpos));
        }
        t_begin += f;
    }
    for chunk in pairs.chunks(warp as usize) {
        let mut by_row: HashMap<usize, HashSet<u64>> = HashMap::new();
        for &(addr, rpos) in chunk {
            by_row.entry(rpos).or_default().insert(addr / cl);
        }
        for (rpos, lines) in by_row {
            counts[rpos] += lines.len() as u64;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert, Gen};

    /// Paper Fig. 4/5 toy scaling: warp 4, cacheline 4 elements, 11 features.
    fn fig5_model() -> WarpModel {
        WarpModel {
            warp: 4,
            cl_elems: 4,
            elem_bytes: 4,
        }
    }

    #[test]
    fn fig5_row2_seven_to_five() {
        let idx = [0u32, 2, 4];
        let naive = per_row_requests(&idx, 11, fig5_model(), false);
        let opt = per_row_requests(&idx, 11, fig5_model(), true);
        assert_eq!(naive[1], 7, "paper Fig. 4: row 2 takes 7 requests naive");
        assert_eq!(opt[1], 5, "paper Fig. 5: circular shift reduces to 5");
    }

    #[test]
    fn fig5_totals_match_python_spec() {
        // Pinned in python/tests/test_coalesce.py as well.
        let idx = [0u32, 2, 4];
        let naive = count_requests(&idx, 11, fig5_model(), false);
        let opt = count_requests(&idx, 11, fig5_model(), true);
        assert_eq!(naive.requests, 16);
        assert_eq!(opt.requests, 13);
        assert_eq!(naive.cachelines, opt.cachelines);
    }

    #[test]
    fn fast_matches_naive_reference_on_fixtures() {
        let model = WarpModel::default();
        for f in [1u64, 7, 11, 31, 32, 33, 127, 128, 129, 513] {
            for shifted in [false, true] {
                let idx: Vec<u32> = vec![0, 5, 5, 17, 2, 900, 901, 3];
                let a = count_requests(&idx, f, model, shifted);
                let b = count_requests_naive_ref(&idx, f, model, shifted);
                assert_eq!(a, b, "f={f} shifted={shifted}");
            }
        }
    }

    #[test]
    fn fast_matches_naive_property() {
        check(60, |g: &mut Gen| {
            let f = g.usize_in(1, 200) as u64;
            let n = g.usize_in(1, 50);
            let idx = g.vec_u32(n, 0, 4000);
            let model = WarpModel {
                warp: *g.choose(&[4u64, 8, 16, 32]),
                cl_elems: *g.choose(&[4u64, 8, 16, 32]),
                elem_bytes: 4,
            };
            let shifted = g.bool();
            let a = count_requests(&idx, f, model, shifted);
            let b = count_requests_naive_ref(&idx, f, model, shifted);
            prop_assert(
                a == b,
                format!("mismatch: {a:?} vs {b:?} (f={f}, idx={idx:?}, shifted={shifted})"),
            )
        });
    }

    #[test]
    fn shift_never_increases_requests_property() {
        // Holds whenever the kernel's applicability gate passes (f >= cl);
        // for sub-cacheline rows the gate keeps the naive schedule.
        check(60, |g: &mut Gen| {
            let n = g.usize_in(1, 40);
            let idx = g.vec_u32(n, 0, 3000);
            let cl = *g.choose(&[4u64, 8, 16, 32]);
            let f = g.usize_in(2 * cl as usize, 150.max(2 * cl as usize)) as u64;
            let model = WarpModel {
                warp: cl,
                cl_elems: cl,
                elem_bytes: 4,
            };
            let naive = count_requests(&idx, f, model, false);
            let opt = count_requests(&idx, f, model, model.shift_applies(f));
            prop_assert(
                opt.requests <= naive.requests && opt.cachelines == naive.cachelines,
                format!("opt={opt:?} naive={naive:?} f={f} idx={idx:?}"),
            )
        });
    }

    #[test]
    fn aligned_width_is_invariant_under_shift() {
        let model = WarpModel::default();
        let idx = [5u32, 1, 9, 3, 1000];
        let a = count_requests(&idx, 128, model, false);
        let b = count_requests(&idx, 128, model, true);
        assert_eq!(a, b);
        assert_eq!(a.amplification(), 1.0);
    }

    #[test]
    fn misaligned_2052b_reduction_matches_fig7_shape() {
        // 513 f32 = 2052 B rows: naive ~2 lines/warp, shifted ~1.
        let model = WarpModel::default();
        let mut rng = crate::util::Rng::new(0);
        let idx: Vec<u32> = (0..64).map(|_| rng.gen_range(4_000_000) as u32).collect();
        let naive = count_requests(&idx, 513, model, false);
        let opt = count_requests(&idx, 513, model, true);
        let ratio = naive.requests as f64 / opt.requests as f64;
        assert!(ratio > 1.6 && ratio <= 2.0, "ratio={ratio}");
    }

    #[test]
    fn empty_inputs() {
        let model = WarpModel::default();
        assert_eq!(count_requests(&[], 10, model, false).requests, 0);
        assert_eq!(count_requests(&[1], 0, model, true).requests, 0);
    }

    #[test]
    fn precision_constructors() {
        // fp32 layout reproduces the default model field-for-field —
        // the degeneracy anchor for every pre-precision report.
        let d = WarpModel::default();
        let fp32 = WarpModel::for_elem_bytes(4);
        assert_eq!((fp32.warp, fp32.cl_elems, fp32.elem_bytes), (d.warp, d.cl_elems, d.elem_bytes));
        // Narrower elements pack more per 128 B line.
        let fp16 = WarpModel::for_elem_bytes(2);
        assert_eq!((fp16.cl_elems, fp16.elem_bytes), (64, 2));
        let int8 = WarpModel::for_elem_bytes(1);
        assert_eq!((int8.cl_elems, int8.elem_bytes), (128, 1));
        // Row-layout recovery: row_bytes / feat_elems.
        let m = WarpModel::for_row_layout(129 * 2, 129);
        assert_eq!(m.elem_bytes, 2);
        let m = WarpModel::for_row_layout(516, 129); // fp32 rows
        assert_eq!((m.cl_elems, m.elem_bytes), (32, 4));
        // Non-exact division falls back to the default.
        let m = WarpModel::for_row_layout(100, 33);
        assert_eq!(m.elem_bytes, 4);
    }

    #[test]
    fn narrower_elements_strictly_reduce_bytes_moved() {
        // Same index stream, same feature count: fp16 and int8 layouts
        // move strictly fewer link bytes than fp32 (tentpole invariant;
        // the integration version lives in tests/quant_properties.rs).
        let mut rng = crate::util::Rng::new(3);
        let idx: Vec<u32> = (0..128).map(|_| rng.gen_range(100_000) as u32).collect();
        let f = 256u64;
        let by_width: Vec<u64> = [4u64, 2, 1]
            .iter()
            .map(|&eb| {
                let m = WarpModel::for_elem_bytes(eb);
                count_requests(&idx, f, m, m.shift_applies(f)).bytes_moved
            })
            .collect();
        assert!(by_width[0] > by_width[1] && by_width[1] > by_width[2], "{by_width:?}");
    }

    #[test]
    fn traffic_accounting() {
        let model = fig5_model();
        let t = count_requests(&[0, 2], 11, model, false);
        assert_eq!(t.useful_bytes, 2 * 11 * 4);
        assert_eq!(t.bytes_moved, t.requests * 16);
        assert!(t.bytes_moved >= t.useful_bytes);
        assert!(t.amplification() >= 1.0);
    }
}

//! Evaluation-platform models — paper Table 5.
//!
//! A [`SystemProfile`] bundles every hardware constant the simulated half of
//! the time model needs (DESIGN.md §5): PCIe link parameters, host gather
//! throughput, per-call overheads, GPU memory capacity, and the affine power
//! model used for Fig. 9.  The three presets correspond to the paper's
//! System1/2/3; constants are calibrated so the *ratios* the paper reports
//! (Py 1.85–5.01x slower than ideal, PyD 1.03–1.20x) fall out of the model,
//! not hard-coded.

/// PCIe interconnect constants.
#[derive(Clone, Debug)]
pub struct PcieConfig {
    /// Theoretical peak bandwidth (the "ideal" of paper Fig. 6), bytes/s.
    pub peak_bw: f64,
    /// Efficiency of large contiguous DMA transfers from pinned memory.
    pub dma_efficiency: f64,
    /// Efficiency of GPU zero-copy reads at full coalescing (PyD aligned).
    pub direct_efficiency: f64,
    /// Read-request round-trip issue cost when the link is latency-bound
    /// (seconds per request, fully pipelined requests overlap; this is the
    /// *per-request* residual cost).
    pub request_issue_s: f64,
    /// Cacheline granularity of zero-copy reads (bytes).
    pub cacheline_bytes: u64,
    /// Fraction of *duplicate* line traffic absorbed by the GPU L2 when
    /// adjacent warps straddle the same cacheline (misaligned streams).
    /// EMOGI (Min et al. 2020) measures ~44% throughput loss for misaligned
    /// access — between the naive 2.0x line-amplification bound and the
    /// 1.25x sector bound — which a 0.4 merge fraction reproduces.
    pub l2_merge_fraction: f64,
}

/// NVLink peer-interconnect constants (the `Sharded` mode's GPU↔GPU path;
/// DESIGN.md §6).
///
/// Shaped exactly like [`PcieConfig`] so the peer link model
/// ([`crate::interconnect::NvlinkLink`]) can mirror the zero-copy PCIe
/// costing: a bandwidth bound over the (L2-merged) line traffic against
/// `peak_bw * direct_efficiency`, raced against a per-request issue bound.
/// Peer reads still coalesce at cacheline granularity — the requester's
/// warp stream is the same; only the link underneath changes.
#[derive(Clone, Debug)]
pub struct NvlinkConfig {
    /// Aggregate per-direction NVLink bandwidth available to one GPU (its
    /// peer-ingress budget, shared across however many peers it reads
    /// from in a step), bytes/s.
    pub peak_bw: f64,
    /// Efficiency of zero-copy peer reads at full coalescing.
    pub direct_efficiency: f64,
    /// Residual per-request issue cost, seconds (NVLink's shorter, on-board
    /// round trip beats PCIe's).
    pub request_issue_s: f64,
    /// Cacheline granularity of peer reads (bytes).
    pub cacheline_bytes: u64,
    /// Fraction of duplicate line traffic absorbed by the requester's L2
    /// (same mechanism as [`PcieConfig::l2_merge_fraction`]).
    pub l2_merge_fraction: f64,
}

/// NVMe storage-link constants (the `Nvme` mode's GPU↔SSD path;
/// DESIGN.md §8).
///
/// GIDS (arXiv:2306.16384) extends the zero-copy paradigm past host
/// memory: GPU threads submit NVMe read commands directly (BaM-style),
/// so cold feature rows stream from storage without CPU involvement.
/// The link is block-granular — every command reads a whole
/// [`NvmeConfig::block_bytes`] block — and its throughput is the lesser
/// of a bandwidth bound and a command-rate bound, where the achievable
/// command rate is capped both by the device's IOPS ceiling and by how
/// many commands the submission queues keep in flight
/// (`queue_depth / read_latency_s`, Little's law).
#[derive(Clone, Debug)]
pub struct NvmeConfig {
    /// Peak sequential-read bandwidth, bytes/s.
    pub peak_bw: f64,
    /// Device random-read command ceiling, commands/s (4 KiB reads).
    pub iops: f64,
    /// Outstanding-command budget the GPU submission queues sustain.
    /// Effective command rate is `min(iops, queue_depth / read_latency_s)`
    /// — shallow queues leave the device idle between completions.
    pub queue_depth: u32,
    /// Per-command service latency, seconds (submission to completion).
    pub read_latency_s: f64,
    /// Read granularity, bytes (the NVMe block / page size).  Rows smaller
    /// than a block amplify I/O unless adjacent rows coalesce into shared
    /// blocks ([`crate::interconnect::count_block_ios`]).
    pub block_bytes: u64,
}

/// Cross-host network-link constants (the multi-host tier's
/// host↔host path; DESIGN.md §15).
///
/// Sits one level above NVLink in the memory hierarchy: remote feature
/// fetches under `--num-hosts > 1` leave the machine over Ethernet or
/// InfiniBand.  The model is deliberately coarser than the zero-copy
/// links — no warp request stream crosses the NIC; remote reads are
/// batched per-host RPCs, so the cost is the larger of a wire-bandwidth
/// bound and a per-message latency bound (one round trip per distinct
/// remote host in the batch).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Per-host NIC bandwidth, bytes/s (unidirectional).
    pub peak_bw: f64,
    /// One-way message latency, seconds (switch + NIC + software stack);
    /// each distinct remote host in a batch pays one.
    pub latency_s: f64,
}

/// Affine whole-system power model (paper Fig. 9; meter-level).
#[derive(Clone, Debug)]
pub struct PowerProfile {
    /// Idle draw, watts (paper: "system idle power is about 105 W").
    pub idle_w: f64,
    /// CPU package max additional draw at 100% utilization.
    pub cpu_max_w: f64,
    /// GPU board max additional draw at 100% utilization.
    pub gpu_max_w: f64,
    /// Additional draw attributable to PCIe/memory I/O at full tilt.
    pub io_max_w: f64,
    /// NVMe SSD max additional draw over idle at 100% read utilization
    /// (the `Nvme` storage tier's active power; DESIGN.md §8).
    pub ssd_max_w: f64,
    /// Near-memory aggregation engine max additional draw at 100%
    /// utilization (the `--aggregate-pushdown` reduction units on the
    /// host/peer/storage side; GNNear-class DIMM engines draw an order of
    /// magnitude less than the GPU board — DESIGN.md §14).
    pub near_mem_max_w: f64,
}

impl PowerProfile {
    /// System power given utilizations in [0, 1].
    pub fn watts(&self, cpu_util: f64, gpu_util: f64, io_util: f64, storage_util: f64) -> f64 {
        self.idle_w
            + self.cpu_max_w * cpu_util.clamp(0.0, 1.0)
            + self.gpu_max_w * gpu_util.clamp(0.0, 1.0)
            + self.io_max_w * io_util.clamp(0.0, 1.0)
            + self.ssd_max_w * storage_util.clamp(0.0, 1.0)
    }
}

/// One evaluation platform (paper Table 5 row).
#[derive(Clone, Debug)]
pub struct SystemProfile {
    pub name: &'static str,
    pub cpu_name: &'static str,
    pub gpu_name: &'static str,
    pub cores: u32,
    pub threads: u32,
    /// GPU device memory capacity, bytes (gates GpuResident / sizes UVM).
    pub gpu_mem_bytes: u64,
    /// Peak multithreaded host gather throughput for large rows, bytes/s.
    /// (Scattered-row memcpy; NUMA systems are markedly worse than their
    /// STREAM numbers, which is exactly the paper's System2 observation.)
    pub host_gather_peak: f64,
    /// Row size at which gather throughput reaches half of peak, bytes.
    /// Models per-row overhead (pointer chasing, cache misses) that makes
    /// small-feature gathers slow.
    pub host_gather_half_row: f64,
    /// CUDA kernel launch + API call overhead per op, seconds.
    pub kernel_launch_s: f64,
    /// DMA setup cost per cudaMemcpy call, seconds.
    pub dma_setup_s: f64,
    /// UVM page-fault service time per fault group, seconds.
    pub uvm_fault_s: f64,
    /// UVM migration granularity, bytes.
    pub uvm_page_bytes: u64,
    /// GPU peak fp32 throughput, FLOP/s (spec sheet).
    pub gpu_fp32_flops: f64,
    /// Achieved fraction of peak for small-batch GNN kernels (GNN training
    /// is notoriously memory-bound; 10-20% is typical for these models).
    pub gpu_efficiency: f64,
    /// Near-memory reduction throughput, FLOP/s — the aggregate rate of
    /// the memory-side sum units `--aggregate-pushdown` runs on (GNNear's
    /// DIMM-side accelerators; DESIGN.md §14).  Deliberately below
    /// `gpu_fp32_flops`: push-down trades compute rate for link bytes.
    pub near_mem_fp32_flops: f64,
    /// Host-side graph work (sampling, subgraph construction) per examined
    /// edge, seconds — multithreaded DGL dataloader equivalent.
    pub sample_s_per_edge: f64,
    pub pcie: PcieConfig,
    /// Peer-interconnect constants for the simulated multi-GPU variant of
    /// this platform (`--mode sharded`).  The paper's testbeds are
    /// single-GPU; these model the NVLink bridge/switch their multi-GPU
    /// SKUs ship (System2's V100 has real NVLink 2.0).
    pub nvlink: NvlinkConfig,
    /// NVMe storage-link constants for the beyond-host-memory cold tier
    /// (`--mode nvme`, DESIGN.md §8); the SSD class each platform would
    /// plausibly carry.
    pub nvme: NvmeConfig,
    /// Cross-host network constants for the multi-host tier
    /// (`--num-hosts`, DESIGN.md §15); the NIC class each platform would
    /// plausibly carry.
    pub net: NetConfig,
    pub power: PowerProfile,
}

impl SystemProfile {
    /// Effective host gather throughput for a given feature-row size.
    ///
    /// `g(row) = peak * row / (row + half_row)` — saturating in row size,
    /// matching the paper's observation that small features hurt the
    /// CPU-centric baseline the most.
    pub fn host_gather_bw(&self, row_bytes: f64) -> f64 {
        self.host_gather_peak * row_bytes / (row_bytes + self.host_gather_half_row)
    }

    /// The paper's System1: AMD Threadripper 3960X + NVIDIA TITAN Xp 12 GB.
    pub fn system1() -> Self {
        SystemProfile {
            name: "System1",
            cpu_name: "AMD Threadripper 3960X 24C/48T",
            gpu_name: "NVIDIA TITAN Xp 12GB",
            cores: 24,
            threads: 48,
            gpu_mem_bytes: 12 << 30,
            host_gather_peak: 20.0e9,
            host_gather_half_row: 256.0,
            kernel_launch_s: 12e-6,
            dma_setup_s: 14e-6,
            uvm_fault_s: 25e-6,
            uvm_page_bytes: 4096,
            gpu_fp32_flops: 12.1e12,
            gpu_efficiency: 0.12,
            near_mem_fp32_flops: 2.0e12,
            sample_s_per_edge: 28e-9,
            pcie: PcieConfig {
                peak_bw: 15.75e9, // PCIe 3.0 x16
                dma_efficiency: 0.88,
                direct_efficiency: 0.93,
                request_issue_s: 4.0e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // Pascal-generation 2-way bridge (NVLink 1.0-class).
            nvlink: NvlinkConfig {
                peak_bw: 40.0e9,
                direct_efficiency: 0.92,
                request_issue_s: 2.0e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // Workstation PCIe 3.0 x4 NVMe (970 Pro class).
            nvme: NvmeConfig {
                peak_bw: 3.2e9,
                iops: 600_000.0,
                queue_depth: 256,
                read_latency_s: 90e-6,
                block_bytes: 4096,
            },
            // Workstation 100GbE NIC (ConnectX-5 class).
            net: NetConfig {
                peak_bw: 12.5e9,
                latency_s: 10e-6,
            },
            power: PowerProfile {
                idle_w: 105.0,
                cpu_max_w: 280.0,
                gpu_max_w: 250.0,
                io_max_w: 25.0,
                ssd_max_w: 9.0,
                near_mem_max_w: 12.0,
            },
        }
    }

    /// The paper's System2: dual Xeon Gold 6230 + Tesla V100 16 GB.
    /// NUMA cross-socket traffic makes the CPU-centric gather notably worse
    /// (the paper measures 3.31–5.01x slowdowns here).
    pub fn system2() -> Self {
        SystemProfile {
            name: "System2",
            cpu_name: "Dual Intel Xeon Gold 6230 40C/80T",
            gpu_name: "NVIDIA Tesla V100 16GB",
            cores: 40,
            threads: 80,
            gpu_mem_bytes: 16 << 30,
            host_gather_peak: 7.8e9,
            host_gather_half_row: 300.0,
            kernel_launch_s: 12e-6,
            dma_setup_s: 16e-6,
            uvm_fault_s: 22e-6,
            uvm_page_bytes: 4096,
            gpu_fp32_flops: 14.9e12,
            gpu_efficiency: 0.12,
            near_mem_fp32_flops: 2.4e12,
            sample_s_per_edge: 35e-9,
            pcie: PcieConfig {
                peak_bw: 15.75e9,
                dma_efficiency: 0.88,
                direct_efficiency: 0.94,
                request_issue_s: 4.0e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // V100 NVLink 2.0: 6 links x 25 GB/s per direction.
            nvlink: NvlinkConfig {
                peak_bw: 150.0e9,
                direct_efficiency: 0.92,
                request_issue_s: 2.0e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // Datacenter U.2 NVMe (P4510 class): deeper queues, steadier
            // latency, slightly lower peak than the consumer parts.
            nvme: NvmeConfig {
                peak_bw: 3.0e9,
                iops: 750_000.0,
                queue_depth: 512,
                read_latency_s: 80e-6,
                block_bytes: 4096,
            },
            // Datacenter InfiniBand HDR 200Gb (the V100 cluster fabric).
            net: NetConfig {
                peak_bw: 25.0e9,
                latency_s: 2e-6,
            },
            power: PowerProfile {
                idle_w: 130.0,
                cpu_max_w: 2.0 * 125.0,
                gpu_max_w: 300.0,
                io_max_w: 25.0,
                ssd_max_w: 12.0,
                near_mem_max_w: 15.0,
            },
        }
    }

    /// The paper's System3: Intel i7-8700K + GTX 1660 6 GB.
    pub fn system3() -> Self {
        SystemProfile {
            name: "System3",
            cpu_name: "Intel i7-8700K 6C/12T",
            gpu_name: "NVIDIA GTX 1660 6GB",
            cores: 6,
            threads: 12,
            gpu_mem_bytes: 6 << 30,
            host_gather_peak: 11.5e9,
            host_gather_half_row: 256.0,
            kernel_launch_s: 14e-6,
            dma_setup_s: 15e-6,
            uvm_fault_s: 28e-6,
            uvm_page_bytes: 4096,
            gpu_fp32_flops: 5.0e12,
            gpu_efficiency: 0.12,
            near_mem_fp32_flops: 1.6e12,
            sample_s_per_edge: 60e-9,
            pcie: PcieConfig {
                peak_bw: 15.75e9,
                dma_efficiency: 0.86,
                direct_efficiency: 0.92,
                request_issue_s: 4.5e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // Consumer Turing part: modest 2-way bridge.
            nvlink: NvlinkConfig {
                peak_bw: 25.0e9,
                direct_efficiency: 0.90,
                request_issue_s: 2.5e-9,
                cacheline_bytes: 128,
                l2_merge_fraction: 0.4,
            },
            // Budget desktop NVMe (660p class): QLC, shallow queues.
            nvme: NvmeConfig {
                peak_bw: 1.8e9,
                iops: 220_000.0,
                queue_depth: 128,
                read_latency_s: 120e-6,
                block_bytes: 4096,
            },
            // Desktop 25GbE NIC: the budget box scales out over the office
            // switch, with commodity latency.
            net: NetConfig {
                peak_bw: 3.125e9,
                latency_s: 15e-6,
            },
            power: PowerProfile {
                idle_w: 70.0,
                cpu_max_w: 95.0,
                gpu_max_w: 120.0,
                io_max_w: 20.0,
                ssd_max_w: 6.0,
                near_mem_max_w: 10.0,
            },
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "system1" | "1" => Some(Self::system1()),
            "system2" | "2" => Some(Self::system2()),
            "system3" | "3" => Some(Self::system3()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::system1(), Self::system2(), Self::system3()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        assert_eq!(SystemProfile::by_name("system2").unwrap().name, "System2");
        assert_eq!(SystemProfile::by_name("3").unwrap().name, "System3");
        assert!(SystemProfile::by_name("laptop").is_none());
    }

    #[test]
    fn gather_bw_saturates_with_row_size() {
        let s = SystemProfile::system1();
        let small = s.host_gather_bw(64.0);
        let big = s.host_gather_bw(16384.0);
        assert!(small < big);
        assert!(big <= s.host_gather_peak);
        // half-row definition: g(half_row) == peak/2
        let half = s.host_gather_bw(s.host_gather_half_row);
        assert!((half - s.host_gather_peak / 2.0).abs() < 1e-3 * s.host_gather_peak);
    }

    #[test]
    fn numa_system_gathers_slower() {
        // The paper's core System2 observation: despite 40 cores, the
        // CPU-centric gather path is the slowest of the three systems.
        assert!(
            SystemProfile::system2().host_gather_peak
                < SystemProfile::system3().host_gather_peak
        );
    }

    #[test]
    fn nvlink_beats_pcie_on_every_profile() {
        // The sharded mode's premise: peer reads are cheaper than host
        // reads, per byte and per request, on every platform.
        for s in SystemProfile::all() {
            assert!(
                s.nvlink.peak_bw * s.nvlink.direct_efficiency
                    > s.pcie.peak_bw * s.pcie.direct_efficiency,
                "{}: NVLink effective bw must exceed PCIe",
                s.name
            );
            assert!(s.nvlink.request_issue_s < s.pcie.request_issue_s, "{}", s.name);
        }
    }

    #[test]
    fn power_model_monotone_and_clamped() {
        let p = SystemProfile::system1().power;
        assert!((p.watts(0.0, 0.0, 0.0, 0.0) - 105.0).abs() < 1e-9);
        assert!(p.watts(0.5, 0.2, 0.1, 0.0) > p.watts(0.1, 0.2, 0.1, 0.0));
        assert_eq!(p.watts(2.0, 0.0, 0.0, 0.0), p.watts(1.0, 0.0, 0.0, 0.0));
        // SSD active power is its own affine term, clamped like the rest.
        assert!(p.watts(0.0, 0.0, 0.0, 1.0) > p.watts(0.0, 0.0, 0.0, 0.0));
        assert_eq!(p.watts(0.0, 0.0, 0.0, 5.0), p.watts(0.0, 0.0, 0.0, 1.0));
    }

    #[test]
    fn near_memory_engine_is_modest_on_every_profile() {
        // Push-down's premise: the memory-side reduction units are slower
        // and far lower-power than the GPU — the win is link bytes, not
        // compute.  Both constants must stay strictly below their GPU
        // counterparts or the cost model's trade-off inverts.
        for s in SystemProfile::all() {
            assert!(
                s.near_mem_fp32_flops > 0.0 && s.near_mem_fp32_flops < s.gpu_fp32_flops,
                "{}: near-mem FLOPs must sit below the GPU's",
                s.name
            );
            assert!(
                s.power.near_mem_max_w > 0.0 && s.power.near_mem_max_w < s.power.gpu_max_w / 5.0,
                "{}: near-mem power must be a small fraction of the GPU board",
                s.name
            );
        }
    }

    #[test]
    fn net_sits_below_nvlink_on_every_profile() {
        // The multi-host tier's premise: the network is the slowest
        // transfer link above storage latency class — remote fetches must
        // never be cheaper per byte than the intra-host peer link, or the
        // partition-locality trade-off inverts.
        for s in SystemProfile::all() {
            assert!(
                s.net.peak_bw < s.nvlink.peak_bw,
                "{}: net bw must sit below NVLink",
                s.name
            );
            assert!(s.net.peak_bw > 0.0, "{}", s.name);
            assert!(
                s.net.latency_s > s.nvlink.request_issue_s,
                "{}: a network round trip must dwarf an NVLink request",
                s.name
            );
        }
    }

    #[test]
    fn nvme_sits_below_the_host_link_on_every_profile() {
        // The storage tier's premise: NVMe is the slowest, costliest tier —
        // below PCIe zero-copy in bandwidth on every platform — and its
        // queue-depth budget is deep enough to reach the device's IOPS
        // ceiling (shallow-queue starvation is a config override scenario,
        // not the default).
        for s in SystemProfile::all() {
            assert!(
                s.nvme.peak_bw < s.pcie.peak_bw * s.pcie.direct_efficiency,
                "{}: NVMe bw must sit below effective PCIe",
                s.name
            );
            assert!(
                s.nvme.queue_depth as f64 / s.nvme.read_latency_s >= s.nvme.iops,
                "{}: default queue depth must saturate device IOPS",
                s.name
            );
            assert_eq!(s.nvme.block_bytes, 4096, "{}", s.name);
        }
    }
}

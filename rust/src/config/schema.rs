//! Typed run configuration with TOML loading, defaults, and validation.
//!
//! This is the "real config system" of the launcher: every knob of a
//! training / benchmark run lives here, can be set from a TOML file
//! (`ptdirect train --config run.toml`) and overridden from the CLI.

use std::path::Path;

use crate::config::systems::SystemProfile;
use crate::config::toml::Document;
use crate::error::{Error, Result};

/// How features move from host memory to the (simulated) GPU.
/// These are the paper's compared designs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccessMode {
    /// Baseline PyTorch: CPU gathers into a pinned staging buffer, DMA copies.
    CpuGather,
    /// PyTorch-Direct: GPU zero-copy gather, *without* the alignment fix
    /// ("PyD Naive" of Fig. 7).
    UnifiedNaive,
    /// PyTorch-Direct with the circular-shift alignment optimization
    /// ("PyD Optimized", the paper's full design).
    UnifiedAligned,
    /// Conventional UVM page migration (the paper's §3 strawman).
    Uvm,
    /// Whole feature table resident in GPU memory (small graphs only).
    GpuResident,
    /// Tiered hot cache: a degree/frequency-ranked hot set pinned in GPU
    /// memory (kernel-launch-only, like `GpuResident`) over the
    /// `UnifiedAligned` zero-copy cold tier — the Data Tiering follow-up
    /// (arXiv:2111.05894) layered on the paper's unified tensors.
    Tiered,
    /// Multi-GPU sharded store: the feature table is partitioned across
    /// `num_gpus` simulated GPUs (policy-controlled, see [`ShardPolicy`]);
    /// each GPU keeps its own hot tier over its shard, peers exchange hot
    /// rows over NVLink, and rows cold everywhere fall back to the host
    /// unified zero-copy path — the multi-GPU extension of the same group
    /// (arXiv:2103.03330; GIDS, arXiv:2306.16384).  See DESIGN.md §6.
    Sharded,
    /// Three-tier storage mode: GPU hot tier over a `host_frac`-bounded
    /// host unified tier over an NVMe cold store with GPU-initiated block
    /// reads — the GIDS extension (arXiv:2306.16384) for graphs whose
    /// feature table exceeds host memory.  See DESIGN.md §8.
    Nvme,
}

impl AccessMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "py" | "cpu" | "cpu-gather" | "baseline" => Some(AccessMode::CpuGather),
            "pyd-naive" | "unified-naive" | "naive" => Some(AccessMode::UnifiedNaive),
            "pyd" | "unified" | "aligned" | "pyd-opt" => Some(AccessMode::UnifiedAligned),
            "uvm" => Some(AccessMode::Uvm),
            "gpu" | "resident" | "gpu-resident" => Some(AccessMode::GpuResident),
            "tiered" | "tier" | "hot-cache" => Some(AccessMode::Tiered),
            "sharded" | "shard" | "multi-gpu" => Some(AccessMode::Sharded),
            "nvme" | "storage" | "ssd" | "gids" => Some(AccessMode::Nvme),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AccessMode::CpuGather => "Py",
            AccessMode::UnifiedNaive => "PyD-Naive",
            AccessMode::UnifiedAligned => "PyD",
            AccessMode::Uvm => "UVM",
            AccessMode::GpuResident => "GPU-Resident",
            AccessMode::Tiered => "Tiered",
            AccessMode::Sharded => "Sharded",
            AccessMode::Nvme => "NVMe",
        }
    }

    /// All modes, in the order benches sweep them.
    pub fn all() -> [AccessMode; 8] {
        [
            AccessMode::CpuGather,
            AccessMode::UnifiedNaive,
            AccessMode::UnifiedAligned,
            AccessMode::Uvm,
            AccessMode::GpuResident,
            AccessMode::Tiered,
            AccessMode::Sharded,
            AccessMode::Nvme,
        ]
    }
}

/// How the `Sharded` mode assigns feature rows to GPU shards.
///
/// Every policy is a total function of the node id (plus, for `Degree`,
/// the degree ranking), so each row has exactly one owner and the union of
/// the shards covers the full node range — invariants pinned by
/// `rust/tests/sharded_properties.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShardPolicy {
    /// Multiplicative hash of the node id: shards are uniform random
    /// samples of the table, so both shard *sizes* and per-shard degree
    /// profiles balance in expectation.
    Hash,
    /// Round-robin over the descending-degree ranking: rank `i` goes to
    /// GPU `i % N`, so every shard holds an equal slice of the hottest
    /// rows (the best placement for skewed access — each GPU's hot tier
    /// caches globally hot rows).
    Degree,
    /// Contiguous ranges of node ids (`rows/N` each): the cheapest
    /// placement metadata, but on graphs whose degree correlates with id
    /// (R-MAT, most crawls) the hot rows concentrate in one shard and the
    /// aggregate hot tier wastes capacity on cold regions.
    Contig,
}

impl ShardPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(ShardPolicy::Hash),
            "degree" | "deg" => Some(ShardPolicy::Degree),
            "contig" | "contiguous" | "range" => Some(ShardPolicy::Contig),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShardPolicy::Hash => "hash",
            ShardPolicy::Degree => "degree",
            ShardPolicy::Contig => "contig",
        }
    }

    /// All policies, in the order benches sweep them.
    pub fn all() -> [ShardPolicy; 3] {
        [ShardPolicy::Hash, ShardPolicy::Degree, ShardPolicy::Contig]
    }
}

/// How a multi-host run (`--num-hosts > 1`, DESIGN.md §15) handles
/// feature rows homed on another host's partition.
///
/// The trainer models host 0's perspective: the graph's feature rows are
/// partitioned across hosts by the same [`ShardPolicy`] that splits each
/// host's slice across its GPUs, and a minibatch inevitably touches rows
/// another host owns.  The two classic designs trade network traffic
/// against memory capacity:
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FetchStrategy {
    /// Fetch remote rows over the network at gather time (DistDGL-style
    /// remote KVStore pulls): zero extra memory, every foreign-homed row
    /// pays a [`crate::interconnect::NetLink`] RPC.
    RemoteFetch,
    /// Replicate the halo: every row a local minibatch can touch is
    /// mirrored into the host's own tiers ahead of time, so sampling is
    /// partition-local and the steady-state gather pays zero network
    /// bytes — at the cost of the mirrored halo's capacity (reported as
    /// `halo_rows`).  Cost-wise this reproduces the single-host run
    /// bit-exactly; the halo counter is the only difference.
    PartitionLocal,
}

impl FetchStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "remote" | "remote-fetch" | "fetch" => Some(FetchStrategy::RemoteFetch),
            "local" | "partition-local" | "replicate" | "halo" => {
                Some(FetchStrategy::PartitionLocal)
            }
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FetchStrategy::RemoteFetch => "remote-fetch",
            FetchStrategy::PartitionLocal => "partition-local",
        }
    }

    /// Both strategies, in the order benches sweep them.
    pub fn all() -> [FetchStrategy; 2] {
        [FetchStrategy::RemoteFetch, FetchStrategy::PartitionLocal]
    }
}

/// Eviction policy of the shared paged feature cache (`--eviction`,
/// DESIGN.md §12).  Every hot tier in the memory hierarchy — tiered,
/// per-GPU sharded, and the NVMe store's GPU tier — runs one of these
/// over fixed-size pages of `page_rows` feature rows.
///
/// `Static` is today's degree-ranked prefix: the preseeded resident set
/// never changes (`--no-promote` forces it whatever `--eviction` says).
/// `Lfu` is the historical default — at `page_rows = 1` it reproduces the
/// pre-refactor row-granular LFU heap bit-exactly, the pinned anchor of
/// `tests/pagecache_properties.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EvictionPolicy {
    /// Static degree-ranked prefix placement; no admissions, no evictions.
    Static,
    /// Least-frequently-used: admit a cold page only when it is strictly
    /// more frequent than the least-frequent resident page.
    Lfu,
    /// Least-recently-used: always admit on miss, evicting the page with
    /// the oldest access stamp.
    Lru,
    /// CLOCK (second chance): a circular hand clears reference bits and
    /// evicts the first unreferenced, unpinned page it finds.
    Clock,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "none" => Some(EvictionPolicy::Static),
            "lfu" => Some(EvictionPolicy::Lfu),
            "lru" => Some(EvictionPolicy::Lru),
            "clock" | "second-chance" => Some(EvictionPolicy::Clock),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictionPolicy::Static => "static",
            EvictionPolicy::Lfu => "lfu",
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::Clock => "clock",
        }
    }

    /// All policies, in the order benches sweep them.
    pub fn all() -> [EvictionPolicy; 4] {
        [
            EvictionPolicy::Static,
            EvictionPolicy::Lfu,
            EvictionPolicy::Lru,
            EvictionPolicy::Clock,
        ]
    }
}

/// Storage precision of the feature table's cold tiers (`--precision`,
/// DESIGN.md §13).  Following the Data Tiering follow-up
/// (arXiv:2111.05894), cold/host/NVMe rows may be held in reduced
/// precision and dequantized on gather: every link-byte, block-IO, and
/// page-size computation prices the narrowed row width
/// (`dim × elem_bytes`), halving (`Fp16`) or quartering (`Int8`) the
/// traffic of every transfer-paying mode.
///
/// Quantization happens **once at table build**: the synthetic features
/// are round-tripped through the storage format before any mode sees
/// them, so all eight access modes stay *bitwise identical to each
/// other* at every precision — only the fp32 reference values move,
/// within the documented error bounds (`tests/quant_properties.rs`).
/// `Fp32` is the identity round-trip and reproduces every pre-precision
/// report bit-exactly — the newest link of the degeneracy chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full f32 rows (4 B/element) — the identity format and the
    /// bit-exact anchor.
    Fp32,
    /// IEEE 754 binary16 rows (2 B/element), round-to-nearest-even;
    /// exact for values with ≤ 11 significand bits in [2⁻¹⁴, 65504].
    Fp16,
    /// Affine int8 rows (1 B/element) with per-row scale + zero-point
    /// computed once at load; element error ≤ scale/2.
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float" | "full" => Some(Precision::Fp32),
            "fp16" | "f16" | "half" => Some(Precision::Fp16),
            "int8" | "i8" | "q8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// Bytes per stored feature element (4 / 2 / 1) — the factor every
    /// row-width computation narrows by.
    pub fn elem_bytes(&self) -> u64 {
        match self {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// Stored bytes of one feature row of `dim` elements.
    pub fn row_bytes(&self, dim: usize) -> u64 {
        dim as u64 * self.elem_bytes()
    }

    /// All precisions, widest first — the order the benches and the
    /// monotone-reduction tests sweep them.
    pub fn all() -> [Precision; 3] {
        [Precision::Fp32, Precision::Fp16, Precision::Int8]
    }
}

/// Which engine executes the training step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// PJRT when AOT artifacts are present, native otherwise.
    Auto,
    /// The AOT/PJRT path only (errors without artifacts).
    Pjrt,
    /// The built-in deterministic trainer (softmax regression over the
    /// gathered root features) — works everywhere, no artifacts needed.
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(Backend::Auto),
            "pjrt" | "xla" => Some(Backend::Pjrt),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
        }
    }
}

/// Full configuration of a training or benchmark run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Dataset preset name (paper Table 4 abbreviation: reddit, product, ...).
    pub dataset: String,
    /// Model architecture: "sage" | "gat".
    pub arch: String,
    /// Feature access mode under test.
    pub mode: AccessMode,
    /// Hardware profile (Table 5).
    pub system: SystemProfile,
    /// Epochs to run.
    pub epochs: u32,
    /// Steps per epoch (0 = derive from graph size / batch).
    pub steps_per_epoch: u32,
    /// Mini-batch root nodes — must match the AOT artifact.
    pub batch: usize,
    /// Sampling fan-outs per layer — must match the AOT artifact.
    pub fanouts: Vec<usize>,
    /// Graph scale divisor (1 = paper-size; bigger = smaller graph).
    pub scale: u32,
    /// Memory budget for the synthetic feature table, bytes. Datasets whose
    /// scaled table would exceed this get their scale raised automatically.
    pub feature_budget: u64,
    /// RNG seed for graph/sampler/params.
    pub seed: u64,
    /// Directory with `manifest.txt` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Number of sampler worker threads for the pipelined executor.
    pub sampler_workers: usize,
    /// Bounded queue depth between pipeline stages (backpressure window).
    pub queue_depth: usize,
    /// Skip PJRT execution (pipeline/transfer accounting only).
    pub skip_train: bool,
    /// Training-step engine (see [`Backend`]).
    pub backend: Backend,
    /// `Tiered` mode: target hot fraction of the feature rows in [0, 1].
    pub hot_frac: f64,
    /// `Tiered` mode: fraction of GPU memory reserved for model parameters
    /// and activations — the hot tier only uses what remains.
    pub gpu_reserve_frac: f64,
    /// `Tiered` mode: enable online LFU promotion (cache warming across
    /// epochs).
    pub tier_promote: bool,
    /// Feature rows per page of the shared paged cache (every hot tier:
    /// tiered, per-GPU sharded, NVMe GPU tier).  Residency, eviction, and
    /// pinning are page-granular; `1` is row-granular and reproduces the
    /// pre-refactor caches bit-exactly (DESIGN.md §12).
    pub page_rows: usize,
    /// Eviction policy of the paged cache (see [`EvictionPolicy`]).
    /// `--no-promote` (`tier_promote = false`) forces `Static` whatever
    /// this says — the two knobs compose, they don't conflict.
    pub eviction: EvictionPolicy,
    /// `Sharded` mode: number of simulated GPUs the feature table is
    /// partitioned across (1 degenerates bit-exactly to `Tiered`).
    pub num_gpus: u32,
    /// `Sharded` mode: row-to-shard placement policy.
    pub shard_policy: ShardPolicy,
    /// NVLink peer-bandwidth override in gigaBYTES per second (the unit
    /// the `SystemProfile` constants use; named to rule out a gigaBITS
    /// misreading).  Stored rather than applied in place so it survives a
    /// later `system` replacement — see [`RunConfig::apply_link_overrides`].
    pub nvlink_gb_per_s: Option<f64>,
    /// `Nvme` mode: fraction of the feature table's rows host memory
    /// holds, in [0, 1].  The degree-ranking prefix stays host-resident;
    /// the remaining rows spill to the NVMe cold store.  `1.0` degenerates
    /// bit-exactly to `Tiered` (nothing spills).
    pub host_frac: f64,
    /// NVMe sequential-read bandwidth override, gigaBYTES per second.
    /// Stored like [`RunConfig::nvlink_gb_per_s`] so it survives a later
    /// `system` replacement.
    pub nvme_gb_per_s: Option<f64>,
    /// NVMe device IOPS-ceiling override (4 KiB read commands per second).
    pub nvme_iops: Option<f64>,
    /// NVMe outstanding-command (queue depth) override.  Held as a knob
    /// value like the bandwidth overrides; integrality is enforced at
    /// parse time (see [`LINK_KNOBS`]).
    pub nvme_queue_depth: Option<u32>,
    /// Inter-host network bandwidth override, gigaBYTES per second
    /// (applies to the profile's [`crate::config::NetConfig`]).  Stored
    /// like [`RunConfig::nvlink_gb_per_s`] so it survives a later
    /// `system` replacement.
    pub net_gb_per_s: Option<f64>,
    /// Inter-host network per-message latency override, microseconds.
    pub net_latency_us: Option<f64>,
    /// Number of hosts the feature table is partitioned across
    /// (DESIGN.md §15).  `1` (the default) is the single-host anchor and
    /// reproduces every existing report bit-exactly; `> 1` requires
    /// `mode = "sharded"` — the only store with a partitionable owner
    /// map — and prices foreign-homed rows per [`FetchStrategy`].
    pub num_hosts: u32,
    /// Remote-row handling of a multi-host run (see [`FetchStrategy`]).
    pub fetch_strategy: FetchStrategy,
    /// Bounded prefetch window of the simulated overlap engine
    /// (DESIGN.md §9): up to this many steps may be in flight ahead of
    /// training (`sample(i)` waits for `train(i - depth)`).  `0` disables
    /// overlap and reproduces the serial additive accounting bit-exactly;
    /// `1` still serializes (one step in flight); `>= 2` pipelines.
    pub prefetch_depth: u32,
    /// Force the serial (unpipelined) timeline regardless of
    /// `prefetch_depth` — the `--no-overlap` escape hatch; equivalent to
    /// depth 0.
    pub no_overlap: bool,
    /// Deduplicate each mini-batch's requested node set before the
    /// feature gather (`GatherPlan`, DESIGN.md §10): every access mode
    /// fetches each distinct row once and scatters it back to the
    /// requested slots, so transfer costs shrink by the batch's
    /// duplication factor while numerics stay bitwise identical.  On by
    /// default; `--no-dedup` restores the duplicated stream bit-exactly
    /// (the regression anchor).
    pub dedup: bool,
    /// Override the dataset preset's synthetic-label class count
    /// (`None` keeps the preset's Table 4 value).  Labels are computed
    /// `node_hash % classes`, so zero is rejected at parse time instead
    /// of panicking deep in the epoch loop.
    pub classes: Option<u32>,
    /// `serve` mode: total inference requests the arrival stream offers.
    pub serve_requests: u64,
    /// `serve` mode: Poisson open-loop arrival rate in requests per
    /// second of simulated time.  `0.0` selects the closed loop driven by
    /// `clients` instead.
    pub arrival_rps: f64,
    /// `serve` mode, closed loop: concurrent clients, each re-issuing the
    /// moment its previous request completes.
    pub clients: u32,
    /// `serve` mode: bounded admission queue; an arrival that finds this
    /// many requests already queued is rejected (counted goodput loss).
    pub admit_depth: usize,
    /// `serve` mode: coalesce queued requests into one minibatch with
    /// cross-request gather dedup (`CoalescedGatherPlan`).  Per-request
    /// results stay bitwise identical to serving each request alone; off
    /// (`--no-coalesce`) dispatches one request per batch.
    pub coalesce: bool,
    /// `serve` mode: max requests folded into one coalesced batch.
    pub coalesce_limit: usize,
    /// Storage precision of the feature table (see [`Precision`]): cold
    /// tiers hold rows at this width and every cost model prices it.
    /// `Fp32` (the default) is the identity format and reproduces all
    /// pre-precision reports bit-exactly.
    pub precision: Precision,
    /// Near-memory aggregation push-down (GNNear, arXiv:2111.00680;
    /// DESIGN.md §14): each tier computes per-destination partial sums
    /// over its locally-resident layer-0 neighbor rows and ships one
    /// partial-aggregate row (plus a 4 B count) per destination instead
    /// of `fanout` raw rows — every cost model reprices the aggregate
    /// stream and a near-memory compute term joins the power model.
    /// Numerics are untouched (the physical gather still runs; the
    /// reduction order is pinned to ascending global neighbor id), so
    /// loss trajectories stay bitwise identical.  Off by default;
    /// `--no-pushdown` reproduces every pre-pushdown report bit-exactly.
    pub aggregate_pushdown: bool,
}

/// One table row per hardware-constant override knob.
///
/// TOML parsing, CLI flag matching, positivity validation, and
/// profile application used to be five hand-written call sites per knob
/// (`from_toml` block, CLI arm, HELP line, `apply_link_overrides` line,
/// default) that each new link had to extend in lockstep; the NVMe PR
/// already missed the CLI arm for `--nvlink-gb-per-s`.  Now a knob is
/// one [`LinkKnob`] entry and every site iterates [`LINK_KNOBS`].
pub struct LinkKnob {
    /// TOML key under `[run]` (also the name in error messages).
    pub key: &'static str,
    /// CLI flag that sets it (`ptdirect ... --nvme-gb-per-s 7`).
    pub flag: &'static str,
    /// Read the stored override back (as f64 whatever the storage type).
    pub get: fn(&RunConfig) -> Option<f64>,
    /// Store a parsed value; fallible so integer-valued knobs can reject
    /// fractional input.  The shared positivity/finiteness check runs
    /// before this is called.
    pub set: fn(&mut RunConfig, f64) -> Result<()>,
    /// Push the stored value onto a system profile (units converted
    /// here: `*_gb_per_s` are gigaBYTES/s, `*_us` microseconds).
    pub apply: fn(&mut SystemProfile, f64),
}

/// Every link-constant override, in HELP display order.
pub const LINK_KNOBS: &[LinkKnob] = &[
    LinkKnob {
        key: "nvlink_gb_per_s",
        flag: "--nvlink-gb-per-s",
        get: |c| c.nvlink_gb_per_s,
        set: |c, v| {
            c.nvlink_gb_per_s = Some(v);
            Ok(())
        },
        apply: |s, v| s.nvlink.peak_bw = v * 1e9,
    },
    LinkKnob {
        key: "nvme_gb_per_s",
        flag: "--nvme-gb-per-s",
        get: |c| c.nvme_gb_per_s,
        set: |c, v| {
            c.nvme_gb_per_s = Some(v);
            Ok(())
        },
        apply: |s, v| s.nvme.peak_bw = v * 1e9,
    },
    LinkKnob {
        key: "nvme_iops",
        flag: "--nvme-iops",
        get: |c| c.nvme_iops,
        set: |c, v| {
            c.nvme_iops = Some(v);
            Ok(())
        },
        apply: |s, v| s.nvme.iops = v,
    },
    LinkKnob {
        key: "nvme_queue_depth",
        flag: "--nvme-queue-depth",
        get: |c| c.nvme_queue_depth.map(|q| q as f64),
        set: |c, v| {
            // Positivity is already checked; reject fractions and u32
            // overflow (a wrapping cast would smuggle 2^32+1 through).
            if v.fract() != 0.0 || v > u32::MAX as f64 {
                return Err(Error::Config(format!(
                    "nvme_queue_depth {v} out of range"
                )));
            }
            c.nvme_queue_depth = Some(v as u32);
            Ok(())
        },
        apply: |s, v| s.nvme.queue_depth = v as u32,
    },
    LinkKnob {
        key: "net_gb_per_s",
        flag: "--net-gb-per-s",
        get: |c| c.net_gb_per_s,
        set: |c, v| {
            c.net_gb_per_s = Some(v);
            Ok(())
        },
        apply: |s, v| s.net.peak_bw = v * 1e9,
    },
    LinkKnob {
        key: "net_latency_us",
        flag: "--net-latency-us",
        get: |c| c.net_latency_us,
        set: |c, v| {
            c.net_latency_us = Some(v);
            Ok(())
        },
        apply: |s, v| s.net.latency_s = v * 1e-6,
    },
];

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "product".into(),
            arch: "sage".into(),
            mode: AccessMode::UnifiedAligned,
            system: SystemProfile::system1(),
            epochs: 1,
            steps_per_epoch: 0,
            batch: 64,
            fanouts: vec![5, 5],
            scale: 64,
            feature_budget: 256 << 20,
            seed: 0x5EED,
            artifacts_dir: "artifacts".into(),
            sampler_workers: 1,
            queue_depth: 4,
            skip_train: false,
            backend: Backend::Auto,
            hot_frac: 0.25,
            gpu_reserve_frac: 0.5,
            tier_promote: true,
            page_rows: 1,
            eviction: EvictionPolicy::Lfu,
            num_gpus: 1,
            shard_policy: ShardPolicy::Hash,
            nvlink_gb_per_s: None,
            host_frac: 0.5,
            nvme_gb_per_s: None,
            nvme_iops: None,
            nvme_queue_depth: None,
            net_gb_per_s: None,
            net_latency_us: None,
            num_hosts: 1,
            fetch_strategy: FetchStrategy::RemoteFetch,
            prefetch_depth: 2,
            no_overlap: false,
            dedup: true,
            classes: None,
            serve_requests: 64,
            arrival_rps: 0.0,
            clients: 1,
            admit_depth: 32,
            coalesce: true,
            coalesce_limit: 8,
            precision: Precision::Fp32,
            aggregate_pushdown: false,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file, starting from defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = Document::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(v) = doc.get_str("run.dataset") {
            cfg.dataset = v.into();
        }
        if let Some(v) = doc.get_str("run.arch") {
            cfg.arch = v.into();
        }
        if let Some(v) = doc.get_str("run.mode") {
            cfg.mode = AccessMode::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown mode `{v}`")))?;
        }
        if let Some(v) = doc.get_str("run.system") {
            cfg.system = SystemProfile::by_name(v)
                .ok_or_else(|| Error::Config(format!("unknown system `{v}`")))?;
        }
        if let Some(v) = doc.get_i64("run.epochs") {
            cfg.epochs = v as u32;
        }
        if let Some(v) = doc.get_i64("run.steps_per_epoch") {
            cfg.steps_per_epoch = v as u32;
        }
        if let Some(v) = doc.get_i64("run.batch") {
            cfg.batch = v as usize;
        }
        if let Some(arr) = doc.get("run.fanouts").and_then(|v| v.as_array()) {
            cfg.fanouts = arr
                .iter()
                .map(|v| {
                    v.as_i64()
                        .map(|i| i as usize)
                        .ok_or_else(|| Error::Config("fanouts must be ints".into()))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.get_i64("run.scale") {
            cfg.scale = v as u32;
        }
        if let Some(v) = doc.get_i64("run.feature_budget_mb") {
            cfg.feature_budget = (v as u64) << 20;
        }
        if let Some(v) = doc.get_i64("run.seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("run.artifacts_dir") {
            cfg.artifacts_dir = v.into();
        }
        if let Some(v) = doc.get_i64("run.sampler_workers") {
            // Checked conversions: a wrapping `as` cast would turn a
            // negative TOML value into a huge lane/queue allocation
            // instead of a config error (the caps live in `validate`).
            cfg.sampler_workers = usize::try_from(v)
                .map_err(|_| Error::Config(format!("sampler_workers {v} out of range")))?;
        }
        if let Some(v) = doc.get_i64("run.queue_depth") {
            cfg.queue_depth = usize::try_from(v)
                .map_err(|_| Error::Config(format!("queue_depth {v} out of range")))?;
        }
        if let Some(v) = doc.get_bool("run.skip_train") {
            cfg.skip_train = v;
        }
        if let Some(v) = doc.get_str("run.backend") {
            cfg.backend = Backend::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown backend `{v}`")))?;
        }
        if let Some(v) = doc.get_f64("run.hot_frac") {
            cfg.hot_frac = v;
        }
        if let Some(v) = doc.get_f64("run.gpu_reserve_frac") {
            cfg.gpu_reserve_frac = v;
        }
        if let Some(v) = doc.get_bool("run.tier_promote") {
            cfg.tier_promote = v;
        }
        if let Some(v) = doc.get_i64("run.page_rows") {
            // Checked conversion: a wrapping `as` cast could smuggle huge
            // or negative values past the [1, 65536] validation window.
            cfg.page_rows = usize::try_from(v)
                .map_err(|_| Error::Config(format!("page_rows {v} out of range")))?;
        }
        if let Some(v) = doc.get_str("run.eviction") {
            cfg.eviction = EvictionPolicy::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown eviction policy `{v}`")))?;
        }
        if let Some(v) = doc.get_i64("run.num_gpus") {
            // Checked conversion: a wrapping `as` cast could smuggle huge
            // or negative values into the valid [1, 64] window.
            cfg.num_gpus = u32::try_from(v)
                .map_err(|_| Error::Config(format!("num_gpus {v} out of range")))?;
        }
        if let Some(v) = doc.get_str("run.shard_policy") {
            cfg.shard_policy = ShardPolicy::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown shard policy `{v}`")))?;
        }
        // Link-constant overrides: one table walk instead of a
        // hand-written block per knob.  `as_f64` coerces TOML ints, so
        // integer-valued knobs (queue depth) flow through the same path
        // and enforce integrality in their `set`.
        for k in LINK_KNOBS {
            if let Some(v) = doc.get_f64(&format!("run.{}", k.key)) {
                // `v <= 0.0` alone would wave NaN through (comparisons
                // with NaN are false) and poison every downstream cost.
                if !(v.is_finite() && v > 0.0) {
                    return Err(Error::Config(format!(
                        "{} must be positive and finite, got {v}",
                        k.key
                    )));
                }
                (k.set)(&mut cfg, v)?;
            }
        }
        if let Some(v) = doc.get_f64("run.host_frac") {
            cfg.host_frac = v;
        }
        if let Some(v) = doc.get_i64("run.num_hosts") {
            // Checked conversion: a wrapping `as` cast could smuggle huge
            // or negative values into the valid [1, 64] window.
            cfg.num_hosts = u32::try_from(v)
                .map_err(|_| Error::Config(format!("num_hosts {v} out of range")))?;
        }
        if let Some(v) = doc.get_str("run.fetch_strategy") {
            cfg.fetch_strategy = FetchStrategy::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown fetch strategy `{v}`")))?;
        }
        if let Some(v) = doc.get_i64("run.prefetch_depth") {
            // Checked conversion: a wrapping `as` cast could smuggle huge
            // or negative values past the [0, 1024] validation window.
            cfg.prefetch_depth = u32::try_from(v)
                .map_err(|_| Error::Config(format!("prefetch_depth {v} out of range")))?;
        }
        if let Some(v) = doc.get_bool("run.no_overlap") {
            cfg.no_overlap = v;
        }
        if let Some(v) = doc.get_bool("run.dedup") {
            cfg.dedup = v;
        }
        if let Some(v) = doc.get_i64("run.classes") {
            // Checked conversion catches negatives and 2^32 wraps; the
            // [1, 2^20] window (and the modulo-by-zero rejection of 0)
            // lives once in `validate`, which every parse path runs.
            cfg.classes = Some(u32::try_from(v).map_err(|_| {
                Error::Config(format!("classes {v} out of range"))
            })?);
        }
        if let Some(v) = doc.get_i64("run.serve_requests") {
            cfg.serve_requests = u64::try_from(v)
                .map_err(|_| Error::Config(format!("serve_requests {v} out of range")))?;
        }
        if let Some(v) = doc.get_f64("run.arrival_rps") {
            // finiteness checked here (NaN passes every range comparison);
            // the rate itself is validated with the other serving knobs
            if !v.is_finite() {
                return Err(Error::Config(format!(
                    "arrival_rps must be finite, got {v}"
                )));
            }
            cfg.arrival_rps = v;
        }
        if let Some(v) = doc.get_i64("run.clients") {
            cfg.clients = u32::try_from(v)
                .map_err(|_| Error::Config(format!("clients {v} out of range")))?;
        }
        if let Some(v) = doc.get_i64("run.admit_depth") {
            cfg.admit_depth = usize::try_from(v)
                .map_err(|_| Error::Config(format!("admit_depth {v} out of range")))?;
        }
        if let Some(v) = doc.get_bool("run.coalesce") {
            cfg.coalesce = v;
        }
        if let Some(v) = doc.get_i64("run.coalesce_limit") {
            cfg.coalesce_limit = usize::try_from(v)
                .map_err(|_| Error::Config(format!("coalesce_limit {v} out of range")))?;
        }
        if let Some(v) = doc.get_str("run.precision") {
            cfg.precision = Precision::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown precision `{v}`")))?;
        }
        if let Some(v) = doc.get_bool("run.aggregate_pushdown") {
            cfg.aggregate_pushdown = v;
        }
        cfg.apply_link_overrides();
        cfg.validate()?;
        Ok(cfg)
    }

    /// The prefetch window the overlap engine actually runs with:
    /// `--no-overlap` forces the serial depth-0 timeline whatever
    /// `prefetch_depth` says.
    pub fn effective_prefetch_depth(&self) -> u32 {
        if self.no_overlap {
            0
        } else {
            self.prefetch_depth
        }
    }

    /// Re-apply the stored link overrides (`nvlink_gb_per_s`, `nvme_*`,
    /// `net_*`) onto the current system profile — a walk over
    /// [`LINK_KNOBS`].  Needed wherever the profile is replaced *after*
    /// TOML loading (the CLI's `--system` flag) — applying in place at
    /// parse time alone would silently clobber the configured constants.
    pub fn apply_link_overrides(&mut self) {
        for k in LINK_KNOBS {
            if let Some(v) = (k.get)(self) {
                (k.apply)(&mut self.system, v);
            }
        }
    }

    /// Artifact name this run needs ("sage_product").
    pub fn artifact_name(&self) -> String {
        format!("{}_{}", self.arch, self.dataset)
    }

    pub fn validate(&self) -> Result<()> {
        if self.arch != "sage" && self.arch != "gat" {
            return Err(Error::Config(format!("unknown arch `{}`", self.arch)));
        }
        if self.batch == 0 {
            return Err(Error::Config("batch must be > 0".into()));
        }
        if self.fanouts.is_empty() || self.fanouts.iter().any(|&f| f == 0) {
            return Err(Error::Config("fanouts must be non-empty, positive".into()));
        }
        if self.scale == 0 {
            return Err(Error::Config("scale must be >= 1".into()));
        }
        if !(1..=65536).contains(&self.queue_depth) {
            return Err(Error::Config(format!(
                "queue_depth must be in [1, 65536], got {}",
                self.queue_depth
            )));
        }
        if self.sampler_workers > 1024 {
            return Err(Error::Config(format!(
                "sampler_workers must be in [0, 1024], got {}",
                self.sampler_workers
            )));
        }
        if !(0.0..=1.0).contains(&self.hot_frac) {
            return Err(Error::Config(format!(
                "hot_frac must be in [0, 1], got {}",
                self.hot_frac
            )));
        }
        if !(0.0..=1.0).contains(&self.gpu_reserve_frac) {
            return Err(Error::Config(format!(
                "gpu_reserve_frac must be in [0, 1], got {}",
                self.gpu_reserve_frac
            )));
        }
        if !(1..=65536).contains(&self.page_rows) {
            return Err(Error::Config(format!(
                "page_rows must be in [1, 65536], got {}",
                self.page_rows
            )));
        }
        if !(1..=64).contains(&self.num_gpus) {
            return Err(Error::Config(format!(
                "num_gpus must be in [1, 64], got {}",
                self.num_gpus
            )));
        }
        if !(1..=64).contains(&self.num_hosts) {
            return Err(Error::Config(format!(
                "num_hosts must be in [1, 64], got {}",
                self.num_hosts
            )));
        }
        if self.num_hosts > 1 && self.mode != AccessMode::Sharded {
            // Only the sharded store carries the host-owner map that the
            // network tier partitions over; every other mode would
            // silently ignore the knob and misreport a multi-host run.
            return Err(Error::Config(format!(
                "num_hosts > 1 requires mode = \"sharded\", got {} hosts with mode {}",
                self.num_hosts,
                self.mode.label()
            )));
        }
        if !(0.0..=1.0).contains(&self.host_frac) {
            return Err(Error::Config(format!(
                "host_frac must be in [0, 1], got {}",
                self.host_frac
            )));
        }
        if self.prefetch_depth > 1024 {
            return Err(Error::Config(format!(
                "prefetch_depth must be in [0, 1024], got {}",
                self.prefetch_depth
            )));
        }
        // Serving knobs — single home of the range rules (the CLI/TOML
        // parse sites only do checked int conversion).
        if !(self.arrival_rps.is_finite() && self.arrival_rps >= 0.0) {
            return Err(Error::Config(format!(
                "arrival_rps must be >= 0 and finite (0 = closed loop), got {}",
                self.arrival_rps
            )));
        }
        if !(1..=65536).contains(&self.clients) {
            return Err(Error::Config(format!(
                "clients must be in [1, 65536], got {}",
                self.clients
            )));
        }
        if !(1..=65536).contains(&self.admit_depth) {
            return Err(Error::Config(format!(
                "admit_depth must be in [1, 65536], got {}",
                self.admit_depth
            )));
        }
        if !(1..=65536).contains(&self.coalesce_limit) {
            return Err(Error::Config(format!(
                "coalesce_limit must be in [1, 65536], got {}",
                self.coalesce_limit
            )));
        }
        if self.arrival_rps == 0.0 && self.clients as usize > self.admit_depth {
            // A closed loop never has more than `clients` requests in the
            // system, so a smaller admission queue would reject requests
            // that by construction should never be dropped.
            return Err(Error::Config(format!(
                "closed-loop serving needs clients <= admit_depth, got {} > {}",
                self.clients, self.admit_depth
            )));
        }
        if let Some(c) = self.classes {
            // Zero is a modulo-by-zero panic in `label_of`; the upper
            // bound keeps the native trainer's `dim x classes` weight
            // table allocatable.  This is the single home of the rule —
            // the CLI/TOML parse sites only do checked int conversion.
            if !(1u32..=1 << 20).contains(&c) {
                return Err(Error::Config(format!(
                    "classes must be >= 1 and <= 1048576 (labels are node_hash % classes), \
                     got {c}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides_defaults() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
dataset = "reddit"
arch = "gat"
mode = "py"
system = "system2"
epochs = 2
batch = 32
fanouts = [3, 4]
scale = 16
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "reddit");
        assert_eq!(cfg.arch, "gat");
        assert_eq!(cfg.mode, AccessMode::CpuGather);
        assert_eq!(cfg.system.name, "System2");
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.fanouts, vec![3, 4]);
        assert_eq!(cfg.artifact_name(), "gat_reddit");
    }

    #[test]
    fn bad_mode_rejected() {
        assert!(RunConfig::from_toml("[run]\nmode = \"warp-drive\"").is_err());
    }

    #[test]
    fn bad_arch_rejected() {
        assert!(RunConfig::from_toml("[run]\narch = \"cnn\"").is_err());
    }

    #[test]
    fn mode_aliases() {
        assert_eq!(AccessMode::parse("PyD"), Some(AccessMode::UnifiedAligned));
        assert_eq!(AccessMode::parse("baseline"), Some(AccessMode::CpuGather));
        assert_eq!(AccessMode::parse("uvm"), Some(AccessMode::Uvm));
        assert_eq!(AccessMode::parse("tiered"), Some(AccessMode::Tiered));
        assert_eq!(AccessMode::parse("hot-cache"), Some(AccessMode::Tiered));
        assert_eq!(AccessMode::parse("sharded"), Some(AccessMode::Sharded));
        assert_eq!(AccessMode::parse("multi-gpu"), Some(AccessMode::Sharded));
        assert_eq!(AccessMode::parse("nvme"), Some(AccessMode::Nvme));
        assert_eq!(AccessMode::parse("gids"), Some(AccessMode::Nvme));
        assert_eq!(AccessMode::parse("storage"), Some(AccessMode::Nvme));
        assert_eq!(AccessMode::parse("??"), None);
        assert_eq!(AccessMode::all().len(), 8);
    }

    #[test]
    fn shard_policy_aliases() {
        assert_eq!(ShardPolicy::parse("hash"), Some(ShardPolicy::Hash));
        assert_eq!(ShardPolicy::parse("DEG"), Some(ShardPolicy::Degree));
        assert_eq!(ShardPolicy::parse("range"), Some(ShardPolicy::Contig));
        assert_eq!(ShardPolicy::parse("modulo"), None);
        assert_eq!(ShardPolicy::all().len(), 3);
        assert_eq!(ShardPolicy::Degree.label(), "degree");
    }

    #[test]
    fn eviction_policy_aliases() {
        assert_eq!(EvictionPolicy::parse("static"), Some(EvictionPolicy::Static));
        assert_eq!(EvictionPolicy::parse("NONE"), Some(EvictionPolicy::Static));
        assert_eq!(EvictionPolicy::parse("lfu"), Some(EvictionPolicy::Lfu));
        assert_eq!(EvictionPolicy::parse("LRU"), Some(EvictionPolicy::Lru));
        assert_eq!(EvictionPolicy::parse("clock"), Some(EvictionPolicy::Clock));
        assert_eq!(
            EvictionPolicy::parse("second-chance"),
            Some(EvictionPolicy::Clock)
        );
        assert_eq!(EvictionPolicy::parse("fifo"), None);
        assert_eq!(EvictionPolicy::all().len(), 4);
        assert_eq!(EvictionPolicy::Clock.label(), "clock");
    }

    #[test]
    fn page_cache_knobs_parse_and_default_to_the_anchor() {
        // Defaults are the pre-refactor semantics: row-granular LFU.
        let d = RunConfig::default();
        assert_eq!(d.page_rows, 1);
        assert_eq!(d.eviction, EvictionPolicy::Lfu);

        let cfg = RunConfig::from_toml(
            "[run]\npage_rows = 8\neviction = \"clock\"",
        )
        .unwrap();
        assert_eq!(cfg.page_rows, 8);
        assert_eq!(cfg.eviction, EvictionPolicy::Clock);
    }

    #[test]
    fn page_cache_knobs_reject_bad_values() {
        assert!(RunConfig::from_toml("[run]\npage_rows = 0").is_err());
        assert!(RunConfig::from_toml("[run]\npage_rows = -4").is_err());
        assert!(RunConfig::from_toml("[run]\npage_rows = 100000").is_err());
        assert!(RunConfig::from_toml("[run]\neviction = \"fifo\"").is_err());
    }

    #[test]
    fn sharded_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
mode = "sharded"
num_gpus = 4
shard_policy = "degree"
hot_frac = 0.3
nvlink_gb_per_s = 100.0
"#,
        )
        .unwrap();
        assert_eq!(cfg.mode, AccessMode::Sharded);
        assert_eq!(cfg.num_gpus, 4);
        assert_eq!(cfg.shard_policy, ShardPolicy::Degree);
        assert!((cfg.system.nvlink.peak_bw - 100e9).abs() < 1.0);

        assert!(RunConfig::from_toml("[run]\nnum_gpus = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nnum_gpus = 65").is_err());
        assert!(RunConfig::from_toml("[run]\nnum_gpus = -1").is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        assert!(RunConfig::from_toml("[run]\nnum_gpus = 4294967297").is_err());
        assert!(RunConfig::from_toml("[run]\nshard_policy = \"modulo\"").is_err());
        assert!(RunConfig::from_toml("[run]\nnvlink_gb_per_s = -3.0").is_err());
        assert!(RunConfig::from_toml("[run]\nnvlink_gb_per_s = nan").is_err());
        assert!(RunConfig::from_toml("[run]\nnvlink_gb_per_s = inf").is_err());
    }

    #[test]
    fn tiered_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
mode = "tiered"
backend = "native"
hot_frac = 0.4
gpu_reserve_frac = 0.25
tier_promote = false
"#,
        )
        .unwrap();
        assert_eq!(cfg.mode, AccessMode::Tiered);
        assert_eq!(cfg.backend, Backend::Native);
        assert!((cfg.hot_frac - 0.4).abs() < 1e-12);
        assert!((cfg.gpu_reserve_frac - 0.25).abs() < 1e-12);
        assert!(!cfg.tier_promote);

        assert!(RunConfig::from_toml("[run]\nhot_frac = 1.5").is_err());
        assert!(RunConfig::from_toml("[run]\ngpu_reserve_frac = -0.1").is_err());
        assert!(RunConfig::from_toml("[run]\nbackend = \"quantum\"").is_err());
    }

    #[test]
    fn nvme_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
mode = "nvme"
host_frac = 0.4
nvme_gb_per_s = 7.0
nvme_iops = 1000000
nvme_queue_depth = 64
"#,
        )
        .unwrap();
        assert_eq!(cfg.mode, AccessMode::Nvme);
        assert!((cfg.host_frac - 0.4).abs() < 1e-12);
        assert!((cfg.system.nvme.peak_bw - 7e9).abs() < 1.0);
        assert!((cfg.system.nvme.iops - 1e6).abs() < 1e-6);
        assert_eq!(cfg.system.nvme.queue_depth, 64);

        assert!(RunConfig::from_toml("[run]\nhost_frac = 1.5").is_err());
        assert!(RunConfig::from_toml("[run]\nhost_frac = -0.1").is_err());
        assert!(RunConfig::from_toml("[run]\nnvme_gb_per_s = -3.0").is_err());
        assert!(RunConfig::from_toml("[run]\nnvme_gb_per_s = nan").is_err());
        assert!(RunConfig::from_toml("[run]\nnvme_iops = inf").is_err());
        assert!(RunConfig::from_toml("[run]\nnvme_queue_depth = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nnvme_queue_depth = -1").is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        assert!(RunConfig::from_toml("[run]\nnvme_queue_depth = 4294967297").is_err());
        // The shared f64 path must still reject fractional depths.
        assert!(RunConfig::from_toml("[run]\nnvme_queue_depth = 2.5").is_err());
    }

    #[test]
    fn fetch_strategy_aliases() {
        assert_eq!(
            FetchStrategy::parse("remote"),
            Some(FetchStrategy::RemoteFetch)
        );
        assert_eq!(
            FetchStrategy::parse("Remote-Fetch"),
            Some(FetchStrategy::RemoteFetch)
        );
        assert_eq!(
            FetchStrategy::parse("local"),
            Some(FetchStrategy::PartitionLocal)
        );
        assert_eq!(
            FetchStrategy::parse("halo"),
            Some(FetchStrategy::PartitionLocal)
        );
        assert_eq!(FetchStrategy::parse("teleport"), None);
        assert_eq!(FetchStrategy::all().len(), 2);
        assert_eq!(FetchStrategy::RemoteFetch.label(), "remote-fetch");
        assert_eq!(FetchStrategy::PartitionLocal.label(), "partition-local");
    }

    #[test]
    fn multi_host_knobs_parse_and_validate() {
        // Defaults are the single-host anchor.
        let d = RunConfig::default();
        assert_eq!(d.num_hosts, 1);
        assert_eq!(d.fetch_strategy, FetchStrategy::RemoteFetch);

        let cfg = RunConfig::from_toml(
            r#"
[run]
mode = "sharded"
num_hosts = 4
fetch_strategy = "partition-local"
"#,
        )
        .unwrap();
        assert_eq!(cfg.num_hosts, 4);
        assert_eq!(cfg.fetch_strategy, FetchStrategy::PartitionLocal);

        assert!(RunConfig::from_toml("[run]\nmode = \"sharded\"\nnum_hosts = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nmode = \"sharded\"\nnum_hosts = 65").is_err());
        assert!(RunConfig::from_toml("[run]\nmode = \"sharded\"\nnum_hosts = -1").is_err());
        // 2^32 + 2 must not wrap into the valid window via `as` truncation.
        assert!(
            RunConfig::from_toml("[run]\nmode = \"sharded\"\nnum_hosts = 4294967298").is_err()
        );
        assert!(RunConfig::from_toml("[run]\nfetch_strategy = \"teleport\"").is_err());
        // Only the sharded store carries a host-owner map.
        let err = RunConfig::from_toml("[run]\nmode = \"tiered\"\nnum_hosts = 2").unwrap_err();
        assert!(err.to_string().contains("sharded"), "{err}");
    }

    #[test]
    fn net_knobs_parse_and_apply_to_the_profile() {
        let cfg = RunConfig::from_toml("[run]\nnet_gb_per_s = 50.0\nnet_latency_us = 5.0")
            .unwrap();
        assert!((cfg.system.net.peak_bw - 50e9).abs() < 1.0);
        assert!((cfg.system.net.latency_s - 5e-6).abs() < 1e-12);

        assert!(RunConfig::from_toml("[run]\nnet_gb_per_s = -1.0").is_err());
        assert!(RunConfig::from_toml("[run]\nnet_gb_per_s = nan").is_err());
        assert!(RunConfig::from_toml("[run]\nnet_latency_us = inf").is_err());
        assert!(RunConfig::from_toml("[run]\nnet_latency_us = 0.0").is_err());
    }

    #[test]
    fn link_knob_table_covers_every_override_and_survives_system_swap() {
        assert_eq!(LINK_KNOBS.len(), 6, "one entry per link-constant knob");
        let mut cfg = RunConfig::from_toml(
            r#"
[run]
nvlink_gb_per_s = 100.0
nvme_gb_per_s = 7.0
nvme_iops = 1000000
nvme_queue_depth = 64
net_gb_per_s = 50.0
net_latency_us = 5.0
"#,
        )
        .unwrap();
        // Every entry stored a value, so `get` must see all six.
        for k in LINK_KNOBS {
            assert!((k.get)(&cfg).is_some(), "{} not stored", k.key);
            assert!(k.flag.starts_with("--"), "{} flag malformed", k.key);
        }
        // A later profile replacement (the CLI's `--system` flag) must
        // not clobber the stored overrides.
        cfg.system = SystemProfile::system2();
        cfg.apply_link_overrides();
        assert!((cfg.system.nvlink.peak_bw - 100e9).abs() < 1.0);
        assert!((cfg.system.nvme.peak_bw - 7e9).abs() < 1.0);
        assert!((cfg.system.nvme.iops - 1e6).abs() < 1e-6);
        assert_eq!(cfg.system.nvme.queue_depth, 64);
        assert!((cfg.system.net.peak_bw - 50e9).abs() < 1.0);
        assert!((cfg.system.net.latency_s - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn overlap_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
prefetch_depth = 6
no_overlap = true
"#,
        )
        .unwrap();
        assert_eq!(cfg.prefetch_depth, 6);
        assert!(cfg.no_overlap);
        assert_eq!(cfg.effective_prefetch_depth(), 0, "--no-overlap wins");

        let cfg = RunConfig::from_toml("[run]\nprefetch_depth = 0").unwrap();
        assert_eq!(cfg.effective_prefetch_depth(), 0);
        assert_eq!(RunConfig::default().effective_prefetch_depth(), 2);

        assert!(RunConfig::from_toml("[run]\nprefetch_depth = -1").is_err());
        assert!(RunConfig::from_toml("[run]\nprefetch_depth = 4096").is_err());
        // 2^32 + 2 must not wrap into the valid window via `as` truncation.
        assert!(RunConfig::from_toml("[run]\nprefetch_depth = 4294967298").is_err());
    }

    #[test]
    fn dedup_knob_parses_and_defaults_on() {
        assert!(RunConfig::default().dedup, "dedup must default on");
        let cfg = RunConfig::from_toml("[run]\ndedup = false").unwrap();
        assert!(!cfg.dedup);
        let cfg = RunConfig::from_toml("[run]\ndedup = true").unwrap();
        assert!(cfg.dedup);
    }

    #[test]
    fn classes_knob_parses_and_rejects_zero_at_parse_time() {
        assert_eq!(RunConfig::default().classes, None);
        let cfg = RunConfig::from_toml("[run]\nclasses = 12").unwrap();
        assert_eq!(cfg.classes, Some(12));

        // The modulo-by-zero satellite: `classes = 0` must be a config
        // error with a clear message, not a panic deep in the epoch loop.
        let err = RunConfig::from_toml("[run]\nclasses = 0").unwrap_err();
        assert!(err.to_string().contains("classes must be >= 1"), "{err}");
        assert!(RunConfig::from_toml("[run]\nclasses = -3").is_err());
        // 2^32 must not wrap into the valid window via `as` truncation.
        assert!(RunConfig::from_toml("[run]\nclasses = 4294967296").is_err());
        assert!(RunConfig::from_toml("[run]\nclasses = 2000000").is_err());
    }

    #[test]
    fn pipeline_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml("[run]\nqueue_depth = 8\nsampler_workers = 2").unwrap();
        assert_eq!(cfg.queue_depth, 8);
        assert_eq!(cfg.sampler_workers, 2);

        // Negative values must error, not wrap into huge allocations.
        assert!(RunConfig::from_toml("[run]\nqueue_depth = -1").is_err());
        assert!(RunConfig::from_toml("[run]\nsampler_workers = -1").is_err());
        assert!(RunConfig::from_toml("[run]\nqueue_depth = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nqueue_depth = 100000").is_err());
        assert!(RunConfig::from_toml("[run]\nsampler_workers = 100000").is_err());
    }

    #[test]
    fn serving_knobs_parse_and_validate() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
serve_requests = 128
arrival_rps = 250.5
clients = 4
admit_depth = 16
coalesce = false
coalesce_limit = 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.serve_requests, 128);
        assert!((cfg.arrival_rps - 250.5).abs() < 1e-12);
        assert_eq!(cfg.clients, 4);
        assert_eq!(cfg.admit_depth, 16);
        assert!(!cfg.coalesce);
        assert_eq!(cfg.coalesce_limit, 4);

        // serving defaults: closed loop, one client, coalescing on
        let d = RunConfig::default();
        assert_eq!(d.arrival_rps, 0.0);
        assert_eq!(d.clients, 1);
        assert!(d.coalesce);

        assert!(RunConfig::from_toml("[run]\narrival_rps = -1.0").is_err());
        assert!(RunConfig::from_toml("[run]\narrival_rps = nan").is_err());
        assert!(RunConfig::from_toml("[run]\narrival_rps = inf").is_err());
        assert!(RunConfig::from_toml("[run]\nclients = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nclients = -2").is_err());
        assert!(RunConfig::from_toml("[run]\nadmit_depth = 0").is_err());
        assert!(RunConfig::from_toml("[run]\ncoalesce_limit = 0").is_err());
        assert!(RunConfig::from_toml("[run]\nserve_requests = -1").is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        assert!(RunConfig::from_toml("[run]\nclients = 4294967297").is_err());
    }

    #[test]
    fn closed_loop_clients_must_fit_the_admission_queue() {
        // clients > admit_depth with arrival_rps = 0 would make the closed
        // loop reject requests that can never legitimately overflow
        let err =
            RunConfig::from_toml("[run]\nclients = 64\nadmit_depth = 8").unwrap_err();
        assert!(err.to_string().contains("clients <= admit_depth"), "{err}");
        // the same queue is fine under an open-loop arrival stream
        RunConfig::from_toml("[run]\nclients = 64\nadmit_depth = 8\narrival_rps = 100.0")
            .unwrap();
    }

    #[test]
    fn precision_aliases_and_widths() {
        assert_eq!(Precision::parse("fp32"), Some(Precision::Fp32));
        assert_eq!(Precision::parse("FP16"), Some(Precision::Fp16));
        assert_eq!(Precision::parse("half"), Some(Precision::Fp16));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("fp64"), None);
        assert_eq!(Precision::all().len(), 3);
        assert_eq!(Precision::Fp32.elem_bytes(), 4);
        assert_eq!(Precision::Fp16.elem_bytes(), 2);
        assert_eq!(Precision::Int8.elem_bytes(), 1);
        assert_eq!(Precision::Fp16.row_bytes(100), 200);
        assert_eq!(Precision::Int8.label(), "int8");
    }

    #[test]
    fn precision_knob_parses_and_defaults_fp32() {
        assert_eq!(RunConfig::default().precision, Precision::Fp32);
        let cfg = RunConfig::from_toml("[run]\nprecision = \"fp16\"").unwrap();
        assert_eq!(cfg.precision, Precision::Fp16);
        let cfg = RunConfig::from_toml("[run]\nprecision = \"int8\"").unwrap();
        assert_eq!(cfg.precision, Precision::Int8);
        assert!(RunConfig::from_toml("[run]\nprecision = \"bf16\"").is_err());
    }

    #[test]
    fn pushdown_knob_parses_and_defaults_off() {
        assert!(
            !RunConfig::default().aggregate_pushdown,
            "pushdown must default off (the bit-exact anchor)"
        );
        let cfg = RunConfig::from_toml("[run]\naggregate_pushdown = true").unwrap();
        assert!(cfg.aggregate_pushdown);
        let cfg = RunConfig::from_toml("[run]\naggregate_pushdown = false").unwrap();
        assert!(!cfg.aggregate_pushdown);
    }

    #[test]
    fn empty_fanouts_rejected_with_clear_error() {
        // The empty-fanout satellite: `fanouts = []` must be a config
        // error with a clear message, not a panic in the sampler.
        let err = RunConfig::from_toml("[run]\nfanouts = []").unwrap_err();
        assert!(err.to_string().contains("fanouts must be non-empty"), "{err}");
        assert!(RunConfig::from_toml("[run]\nfanouts = [5, 0]").is_err());
    }

    #[test]
    fn backend_aliases() {
        assert_eq!(Backend::parse("auto"), Some(Backend::Auto));
        assert_eq!(Backend::parse("PJRT"), Some(Backend::Pjrt));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("cuda"), None);
        assert_eq!(Backend::Native.label(), "native");
    }
}

//! Configuration subsystem: TOML-subset parser, typed run config with
//! validation, and the paper's Table 5 hardware profiles.

pub mod schema;
pub mod systems;
pub mod toml;

pub use schema::{
    AccessMode, Backend, EvictionPolicy, FetchStrategy, LinkKnob, Precision, RunConfig,
    ShardPolicy, LINK_KNOBS,
};
pub use systems::{NetConfig, NvlinkConfig, NvmeConfig, PcieConfig, PowerProfile, SystemProfile};

//! Configuration subsystem: TOML-subset parser, typed run config with
//! validation, and the paper's Table 5 hardware profiles.

pub mod schema;
pub mod systems;
pub mod toml;

pub use schema::{AccessMode, Backend, EvictionPolicy, Precision, RunConfig, ShardPolicy};
pub use systems::{NvlinkConfig, NvmeConfig, PcieConfig, PowerProfile, SystemProfile};

//! TOML-subset parser (serde/toml are not vendored offline).
//!
//! Supported grammar — everything the project's config files use:
//!   * `[section]` and `[section.subsection]` headers
//!   * `key = value` with value ∈ {string "..", integer, float, bool,
//!     flat array [v, v, ...]}
//!   * `#` comments, blank lines
//!
//! Values are stored flat under dotted paths (`section.key`). Unsupported
//! constructs (multi-line strings, tables-in-arrays, dates) are rejected
//! with a line-numbered error instead of being silently misparsed.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Flat dotted-path -> value document.
#[derive(Clone, Debug, Default)]
pub struct Document {
    pub values: BTreeMap<String, Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Document> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(err(lineno, "bad section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let val = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(path, val);
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_f64)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Keys under `prefix.` with the prefix stripped.
    pub fn section_keys(&self, prefix: &str) -> Vec<String> {
        let pre = format!("{prefix}.");
        self.values
            .keys()
            .filter_map(|k| k.strip_prefix(&pre).map(str::to_string))
            .collect()
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value> {
    let t = text.trim();
    if t.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(rest) = t.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p, lineno)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value `{t}`")))
}

/// Split on commas that are not inside quotes (arrays are flat, no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = Document::parse(
            r#"
# top comment
title = "ptdirect"
[run]
epochs = 3
lr = 0.0025
verbose = true
fanouts = [5, 10]
tag = "a # not a comment"
[run.deep]
x = 1
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("title"), Some("ptdirect"));
        assert_eq!(doc.get_i64("run.epochs"), Some(3));
        assert_eq!(doc.get_f64("run.lr"), Some(0.0025));
        assert_eq!(doc.get_bool("run.verbose"), Some(true));
        assert_eq!(doc.get_str("run.tag"), Some("a # not a comment"));
        assert_eq!(doc.get_i64("run.deep.x"), Some(1));
        let arr = doc.get("run.fanouts").unwrap().as_array().unwrap();
        assert_eq!(arr, &[Value::Int(5), Value::Int(10)]);
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = Document::parse("x = 4").unwrap();
        assert_eq!(doc.get_f64("x"), Some(4.0));
    }

    #[test]
    fn underscores_in_ints() {
        let doc = Document::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.get_i64("n"), Some(1_000_000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("a = 1\nbad line\n").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn rejects_unterminated_string() {
        assert!(Document::parse("s = \"oops").is_err());
    }

    #[test]
    fn rejects_unterminated_section() {
        assert!(Document::parse("[run\n").is_err());
    }

    #[test]
    fn section_keys_lists_children() {
        let doc = Document::parse("[a]\nx=1\ny=2\n[b]\nz=3").unwrap();
        let mut keys = doc.section_keys("a");
        keys.sort();
        assert_eq!(keys, vec!["x", "y"]);
    }
}

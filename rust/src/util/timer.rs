//! Wall-clock timing helpers used by the measured half of the time model
//! (DESIGN.md §5). Simulated durations are plain `f64` seconds and never go
//! through these types.

use std::time::Instant;

/// One-shot timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Accumulating stopwatch for per-stage busy time.
#[derive(Clone, Debug, Default)]
pub struct Stopwatch {
    total_s: f64,
    laps: u64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and accumulate its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.total_s += t.elapsed_s();
        self.laps += 1;
        out
    }

    pub fn add_s(&mut self, s: f64) {
        self.total_s += s;
        self.laps += 1;
    }

    pub fn total_s(&self) -> f64 {
        self.total_s
    }

    pub fn laps(&self) -> u64 {
        self.laps
    }

    pub fn mean_s(&self) -> f64 {
        if self.laps == 0 {
            0.0
        } else {
            self.total_s / self.laps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.add_s(0.5);
        sw.add_s(1.5);
        assert_eq!(sw.total_s(), 2.0);
        assert_eq!(sw.laps(), 2);
        assert_eq!(sw.mean_s(), 1.0);
    }

    #[test]
    fn stopwatch_times_closures() {
        let mut sw = Stopwatch::new();
        let v = sw.time(|| 42);
        assert_eq!(v, 42);
        assert!(sw.total_s() >= 0.0);
        assert_eq!(sw.laps(), 1);
    }
}

//! Tolerance-based float comparison (ULP distance + absolute tolerance).
//!
//! Everything this repo pins is *bitwise*: the eight access modes gather
//! identical bytes, dedup and coalescing change cost only, `--precision
//! fp32` reproduces every report exactly (DESIGN.md §13's degeneracy
//! chain).  Quantized tiers are the first place where exact equality is
//! the *wrong* spec — fp16/int8 runs track the fp32 loss trajectory
//! within a documented band, not to the bit.  This module is the one
//! sanctioned comparator for those bands, so "how close is close enough"
//! lives in a single tested place instead of ad-hoc `(a - b).abs() < eps`
//! scattered through tests.
//!
//! **ULP distance.**  Reinterpreting an IEEE 754 float's bits as a
//! sign-magnitude integer and unfolding the negative half-line onto
//! two's complement makes the integer distance between two finite floats
//! equal to the number of representable values between them (their
//! distance in Units in the Last Place).  ULP distance is scale-free —
//! 1 ULP near 1e-30 and 1 ULP near 1e+30 are both "adjacent" — which is
//! exactly the right ruler for "these two computations should have taken
//! the same path up to rounding".  Near zero, however, ULPs are absurdly
//! fine (adjacent subnormals differ by 1e-45), so [`approx_eq`] pairs the
//! ULP bound with an absolute floor: values within `abs_tol` pass
//! regardless of their ULP distance.
//!
//! ```
//! use ptdirect::util::approx::{approx_eq, ulp_diff};
//!
//! assert_eq!(ulp_diff(1.0, 1.0), 0);
//! assert_eq!(ulp_diff(1.0, 1.0 + f32::EPSILON), 1);
//! assert!(approx_eq(1.0, 1.0 + 2.0 * f32::EPSILON, 0.0, 4));
//! assert!(!approx_eq(1.0, 1.1, 0.0, 4));
//! ```

/// Map an `f32`'s bits onto a monotone signed integer line: positive
/// floats keep their bit pattern, negative floats fold below zero so
/// that integer order equals float order.  Both zeros map to 0, so the
/// two sides of the number line join without a phantom step.
fn monotone_bits(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 == 0 {
        b as i64
    } else {
        -((b & 0x7FFF_FFFF) as i64)
    }
}

/// Number of representable `f32` values between `a` and `b` (their ULP
/// distance).  0 means bitwise equal up to the sign of zero (`-0.0` and
/// `+0.0` are 1 apart on the monotone line but compare equal as floats,
/// so they report 0).  Any NaN involvement reports `u64::MAX` — NaNs are
/// never "close" to anything, including themselves.
pub fn ulp_diff(a: f32, b: f32) -> u64 {
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    if a == b {
        // covers -0.0 == +0.0 and inf == inf
        return 0;
    }
    if a.is_infinite() || b.is_infinite() {
        // finite-vs-inf (or opposing infinities): not a rounding story.
        return u64::MAX;
    }
    (monotone_bits(a) - monotone_bits(b)).unsigned_abs()
}

/// True when `a` and `b` agree within `abs_tol` *or* within `max_ulps`
/// representable values.  The absolute arm handles the near-zero regime
/// (where ULPs are vanishingly small) and sign-crossing noise; the ULP
/// arm handles every other magnitude scale-freely.  NaN never compares
/// equal; infinities compare equal only to themselves (exactly).
pub fn approx_eq(a: f32, b: f32, abs_tol: f32, max_ulps: u64) -> bool {
    if a.is_nan() || b.is_nan() {
        return false;
    }
    if a == b {
        return true;
    }
    if (a - b).abs() <= abs_tol {
        return true;
    }
    ulp_diff(a, b) <= max_ulps
}

/// Slice form of [`approx_eq`]: `Ok(())` when the slices have equal
/// length and agree element-wise, `Err(msg)` naming the first offending
/// index with both values and their ULP distance — so a failing
/// tolerance-band test reports *where* and *by how much*, not just
/// `assertion failed`.
pub fn approx_eq_slice(a: &[f32], b: &[f32], abs_tol: f32, max_ulps: u64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        if !approx_eq(x, y, abs_tol, max_ulps) {
            return Err(format!(
                "index {i}: {x:?} vs {y:?} (|Δ| = {:e}, {} ulps; abs_tol {abs_tol:e}, max_ulps {max_ulps})",
                (x - y).abs(),
                ulp_diff(x, y),
            ));
        }
    }
    Ok(())
}

/// Largest ULP distance between corresponding elements (for reporting a
/// measured band next to its documented bound).  `u64::MAX` on length
/// mismatch or any NaN.
pub fn max_ulp_diff(a: &[f32], b: &[f32]) -> u64 {
    if a.len() != b.len() {
        return u64::MAX;
    }
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| ulp_diff(x, y))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ulp_diff_counts_representable_steps() {
        assert_eq!(ulp_diff(1.0, 1.0), 0);
        // Adjacent floats are 1 ULP apart, at every scale.
        assert_eq!(ulp_diff(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_diff(1.0, 1.0 + f32::EPSILON), 1);
        assert_eq!(ulp_diff(1e30, f32::from_bits(1e30f32.to_bits() + 1)), 1);
        assert_eq!(ulp_diff(1e-38, f32::from_bits(1e-38f32.to_bits() + 1)), 1);
        // Multiple steps accumulate.
        assert_eq!(ulp_diff(1.0, 1.0 + 4.0 * f32::EPSILON), 4);
        // Symmetric.
        assert_eq!(ulp_diff(1.5, 2.5), ulp_diff(2.5, 1.5));
    }

    #[test]
    fn ulp_diff_crosses_zero_through_subnormals() {
        // The monotone mapping joins the halves at a shared zero:
        // +tiny → 0 → -tiny is two steps.
        let tiny = f32::from_bits(1); // smallest positive subnormal
        assert_eq!(ulp_diff(tiny, -tiny), 2);
        assert_eq!(ulp_diff(0.0, tiny), 1);
        assert_eq!(ulp_diff(-0.0, 0.0), 0); // equal as floats
        assert_eq!(ulp_diff(-0.0, tiny), 1);
    }

    #[test]
    fn ulp_diff_rejects_nan_and_mixed_infinities() {
        assert_eq!(ulp_diff(f32::NAN, f32::NAN), u64::MAX);
        assert_eq!(ulp_diff(f32::NAN, 1.0), u64::MAX);
        assert_eq!(ulp_diff(f32::INFINITY, 1.0), u64::MAX);
        assert_eq!(ulp_diff(f32::INFINITY, f32::NEG_INFINITY), u64::MAX);
        // Same infinity is exactly equal.
        assert_eq!(ulp_diff(f32::INFINITY, f32::INFINITY), 0);
    }

    #[test]
    fn approx_eq_combines_abs_and_ulp_arms() {
        // ULP arm: small relative drift passes, big drift fails.
        assert!(approx_eq(1.0, 1.0 + 2.0 * f32::EPSILON, 0.0, 2));
        assert!(!approx_eq(1.0, 1.0 + 8.0 * f32::EPSILON, 0.0, 2));
        // Abs arm: near-zero sign-crossing noise passes only with a floor.
        assert!(!approx_eq(1e-9, -1e-9, 0.0, 16));
        assert!(approx_eq(1e-9, -1e-9, 1e-8, 0));
        // NaN never, infinity only exactly.
        assert!(!approx_eq(f32::NAN, f32::NAN, f32::INFINITY, u64::MAX));
        assert!(approx_eq(f32::INFINITY, f32::INFINITY, 0.0, 0));
        assert!(!approx_eq(f32::INFINITY, 1.0, 1e30, 4));
    }

    #[test]
    fn slice_comparator_reports_first_offender() {
        let a = [1.0f32, 2.0, 3.0];
        assert!(approx_eq_slice(&a, &[1.0, 2.0, 3.0], 0.0, 0).is_ok());
        let msg = approx_eq_slice(&a, &[1.0, 2.5, 3.0], 0.0, 4).unwrap_err();
        assert!(msg.contains("index 1"), "{msg}");
        assert!(
            approx_eq_slice(&a, &[1.0, 2.0], 0.0, 0)
                .unwrap_err()
                .contains("length mismatch")
        );
    }

    #[test]
    fn max_ulp_diff_reports_worst_element() {
        let a = [1.0f32, 2.0, 3.0];
        let mut b = a;
        assert_eq!(max_ulp_diff(&a, &b), 0);
        b[1] = f32::from_bits(b[1].to_bits() + 7);
        assert_eq!(max_ulp_diff(&a, &b), 7);
        assert_eq!(max_ulp_diff(&a, &b[..2]), u64::MAX);
    }
}

//! Minimal `log` facade backend (env_logger is not vendored offline).
//!
//! Level comes from `PTDIRECT_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr with a monotonic timestamp.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

fn start_instant() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start_instant().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let _ = start_instant();
    let level = match std::env::var("PTDIRECT_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger));
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}

//! Small self-contained utilities shared by every subsystem.
//!
//! The offline build environment vendors only tiny shim crates (`log`,
//! `xla`, `anyhow` under rust/vendor/), so the usual ecosystem crates
//! (rand, rayon, serde, proptest, criterion) are unavailable — each of the
//! modules below is a from-scratch replacement scoped to exactly what this
//! project needs.

pub mod approx;
pub mod bytes;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;

//! Byte-size formatting and alignment arithmetic.

/// Format a byte count with binary units ("1.5 GiB").
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format seconds adaptively ("12.3 ms", "4.5 s").
pub fn human_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Round `x` up to a multiple of `align` (align must be a power of two).
#[inline]
pub fn align_up(x: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (x + align - 1) & !(align - 1)
}

/// Round `x` down to a multiple of `align` (align must be a power of two).
#[inline]
pub fn align_down(x: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    x & !(align - 1)
}

/// Number of `align`-sized units covering `[off, off+len)`.
#[inline]
pub fn span_units(off: u64, len: u64, align: u64) -> u64 {
    if len == 0 {
        return 0;
    }
    let first = off / align;
    let last = (off + len - 1) / align;
    last - first + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(1_572_864), "1.5 MiB");
    }

    #[test]
    fn human_duration_scales() {
        assert_eq!(human_duration(2.5), "2.50 s");
        assert_eq!(human_duration(0.0123), "12.30 ms");
        assert_eq!(human_duration(4.5e-6), "4.50 us");
    }

    #[test]
    fn align_roundtrips() {
        assert_eq!(align_up(0, 128), 0);
        assert_eq!(align_up(1, 128), 128);
        assert_eq!(align_up(128, 128), 128);
        assert_eq!(align_down(129, 128), 128);
    }

    #[test]
    fn span_units_counts_straddles() {
        // 11 bytes starting at byte 120 with 128B lines -> lines 0 and 1
        assert_eq!(span_units(120, 11, 128), 2);
        assert_eq!(span_units(0, 128, 128), 1);
        assert_eq!(span_units(0, 129, 128), 2);
        assert_eq!(span_units(5, 0, 128), 0);
    }
}

//! Streaming statistics for bench harnesses and pipeline metrics.

use crate::util::rng::Rng;

/// Welford online mean/variance plus min/max and a bounded percentile
/// reservoir.  Below [`RESERVOIR_CAP`] every sample is retained and
/// percentiles are exact; past the cap the reservoir switches to true
/// uniform reservoir sampling (Vitter's Algorithm R, driven by the
/// deterministic [`Rng`]), so long-run percentiles stay an unbiased
/// estimate of the whole stream instead of a snapshot of its warm-up.
#[derive(Clone, Debug)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    rng: Rng,
}

const RESERVOIR_CAP: usize = 65_536;

/// Fixed seed for the reservoir's replacement stream: every `Summary` is
/// deterministic on its input sequence alone, so reports reproduce
/// bit-for-bit across runs.
const RESERVOIR_SEED: u64 = 0x5EED_5A17;

impl Default for Summary {
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
            rng: Rng::new(RESERVOIR_SEED),
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            // Algorithm R: element n replaces a reservoir slot with
            // probability CAP/n, keeping the reservoir a uniform sample
            // of everything seen so far.
            let j = self.rng.gen_range(self.n) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Percentile over the retained samples (q in [0,1]): exact below the
    /// reservoir cap, an unbiased estimate above it.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        // lower nearest-rank convention (floor), so median of an even-sized
        // sample is the lower middle element
        let rank = (q.clamp(0.0, 1.0) * (s.len() - 1) as f64).floor() as usize;
        s[rank]
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(0.5), 0.0);
    }

    #[test]
    fn known_sequence() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.median(), 4.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        assert!(s.percentile(0.1) < s.percentile(0.5));
        assert!(s.percentile(0.5) < s.percentile(0.99));
    }

    #[test]
    fn exact_below_cap() {
        let mut s = Summary::new();
        for i in 0..RESERVOIR_CAP {
            s.add(i as f64);
        }
        // every sample retained, so percentiles are exact nearest-rank
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(1.0), (RESERVOIR_CAP - 1) as f64);
        assert_eq!(
            s.median(),
            ((RESERVOIR_CAP - 1) as f64 * 0.5).floor()
        );
    }

    #[test]
    fn reservoir_is_unbiased_past_cap() {
        // Feed 8x the cap in ascending order.  First-N truncation would
        // pin the median at ~CAP/2 (the warm-up); Algorithm R keeps a
        // uniform sample of the whole stream, so the sampled median must
        // track the true stream median within a few percent.
        let total = RESERVOIR_CAP * 8;
        let mut s = Summary::new();
        for i in 0..total {
            s.add(i as f64);
        }
        assert_eq!(s.samples.len(), RESERVOIR_CAP);
        let true_median = total as f64 / 2.0;
        let est = s.median();
        assert!(
            (est - true_median).abs() / true_median < 0.05,
            "median estimate {est} vs true {true_median}"
        );
        let p99 = s.percentile(0.99);
        let true_p99 = total as f64 * 0.99;
        assert!(
            (p99 - true_p99).abs() / true_p99 < 0.05,
            "p99 estimate {p99} vs true {true_p99}"
        );
    }

    #[test]
    fn reservoir_deterministic() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        for i in 0..(RESERVOIR_CAP * 2) {
            let x = (i as f64).sin();
            a.add(x);
            b.add(x);
        }
        assert_eq!(a.percentile(0.9), b.percentile(0.9));
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // total_cmp orders NaN above +inf; a stray NaN must not panic and
        // must not corrupt low/mid percentiles.
        let mut s = Summary::new();
        for i in 0..100 {
            s.add(i as f64);
        }
        s.add(f64::NAN);
        let med = s.median();
        assert!(med.is_finite());
        assert!((0.0..100.0).contains(&med));
    }
}

//! Streaming statistics for bench harnesses and pipeline metrics.

/// Welford online mean/variance plus min/max and a sample reservoir for
/// percentiles (exact when below the reservoir cap, which all benches are).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

const RESERVOIR_CAP: usize = 65_536;

impl Summary {
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Exact percentile over the retained samples (q in [0,1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // lower nearest-rank convention (floor), so median of an even-sized
        // sample is the lower middle element
        let rank = (q.clamp(0.0, 1.0) * (s.len() - 1) as f64).floor() as usize;
        s[rank]
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(0.5), 0.0);
    }

    #[test]
    fn known_sequence() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.median(), 4.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        assert!(s.percentile(0.1) < s.percentile(0.5));
        assert!(s.percentile(0.5) < s.percentile(0.99));
    }
}

//! Miniature property-based testing harness (proptest is not vendored).
//!
//! Usage:
//! ```ignore
//! check(128, |g| {
//!     let n = g.usize_in(1, 100);
//!     let xs = g.vec_u32(n, 0, 1000);
//!     prop_assert(invariant(&xs), format!("violated for {xs:?}"));
//! });
//! ```
//! On failure the harness re-runs with the failing seed printed so the case
//! can be reproduced with [`check_seeded`].  A bounded shrink pass retries
//! the property with progressively smaller size hints.

use crate::util::rng::Rng;

/// Generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0.0, 1.0]; shrinking lowers it so ranges get smaller.
    size: f64,
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
            seed,
        }
    }

    /// usize uniform in [lo, hi], scaled toward `lo` while shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).floor() as usize;
        lo + if span == 0 {
            0
        } else {
            self.rng.gen_range_usize(span + 1)
        }
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.usize_in(lo as usize, hi as usize) as u64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.gen_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_u32(&mut self, len: usize, lo: u32, hi: u32) -> Vec<u32> {
        (0..len)
            .map(|_| lo + self.rng.gen_range((hi - lo + 1) as u64) as u32)
            .collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.gen_f32_range(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range_usize(xs.len())]
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` for `iters` random cases; panic with the seed on failure.
pub fn check(iters: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    check_with_base_seed(iters, 0xDEAD_BEEF, prop)
}

/// Run with an explicit base seed (each iteration derives its own).
pub fn check_with_base_seed(
    iters: u64,
    base_seed: u64,
    prop: impl Fn(&mut Gen) -> PropResult,
) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed with smaller size hints and report
            // the smallest size that still fails.
            let mut fail_size = 1.0;
            let mut fail_msg = msg;
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let mut g2 = Gen::new(seed, size);
                match prop(&mut g2) {
                    Err(m) => {
                        fail_size = size;
                        fail_msg = m;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (iter {i}, seed {seed:#x}, size {fail_size}): {fail_msg}\n\
                 reproduce with check_seeded({seed:#x}, {fail_size}, prop)"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_seeded(seed: u64, size: f64, prop: impl Fn(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed, size);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let n = g.usize_in(0, 50);
            let v = g.vec_u32(n, 0, 9);
            prop_assert(v.iter().all(|&x| x <= 9), "range violated")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(64, |g| {
            let n = g.usize_in(0, 100);
            prop_assert(n < 90, format!("n={n}"))
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check(128, |g| {
            let x = g.usize_in(3, 7);
            let f = g.f64_in(-1.0, 1.0);
            prop_assert((3..=7).contains(&x) && (-1.0..1.0).contains(&f), "bounds")
        });
    }
}

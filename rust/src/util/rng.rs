//! Deterministic pseudo-random number generation (no `rand` crate offline).
//!
//! `Rng` is a SplitMix64-seeded xoshiro256** generator — fast, high quality,
//! and reproducible across platforms, which the graph generators and
//! samplers rely on for stable test fixtures.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for simulation workloads; the slight bias is < 2^-32).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn gen_range_usize(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f64() as f32
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = (1.0 - self.gen_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` items from `0..n` with replacement into `out`.
    pub fn sample_with_replacement(&mut self, n: usize, out: &mut [u32]) {
        for o in out.iter_mut() {
            *o = self.gen_range(n as u64) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn gen_range_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_chi_square_smoke() {
        let mut r = Rng::new(3);
        let mut buckets = [0usize; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[r.gen_range(16) as usize] += 1;
        }
        let expect = (n / 16) as f64;
        let chi2: f64 = buckets
            .iter()
            .map(|&c| (c as f64 - expect).powi(2) / expect)
            .sum();
        // 15 dof; p=0.001 critical value ~ 37.7
        assert!(chi2 < 37.7, "chi2={chi2}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}

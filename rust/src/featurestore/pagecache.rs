//! Shared paged feature cache: fixed-size refcounted pages of feature
//! rows under a pluggable eviction policy (DESIGN.md §12).
//!
//! Every hot tier in the memory hierarchy — the single-GPU tiered cache,
//! the sharded store's per-GPU tiers, and the NVMe store's GPU tier —
//! used to run its own bespoke row-granular LFU walk.  [`PageCache`] is
//! the one implementation they now share, generalized along three axes:
//!
//! * **Pages** (`--page-rows`): residency is tracked per fixed-size page
//!   of `page_rows` consecutive feature rows (page `p` covers rows
//!   `[p·page_rows, (p+1)·page_rows)`), the paged-KV `BlockRef` idiom.
//!   `page_rows = 1` is row-granular and reproduces the pre-refactor
//!   caches bit-exactly — the pinned anchor of
//!   `tests/pagecache_properties.rs`.
//! * **Eviction** ([`EvictionEngine`], `--eviction`): `static` (the
//!   degree-ranked prefix, never admits), `lfu` (the historical lazy
//!   min-heap), `lru` (oldest access stamp), and `clock` (second
//!   chance).  Model-based properties live in
//!   `tests/eviction_policies.rs`.
//! * **Pins** (refcounts): every gather pins the pages it touches for the
//!   duration of the classification, and serving streams keep a batch's
//!   pages pinned while per-request blocks scatter out of it — a pinned
//!   page is never a victim, whatever the policy says.  Refcounts return
//!   to zero after every gather (`pins == unpins` when no external pin
//!   is held).
//!
//! Like the caches it subsumes, this is placement metadata only: the
//! cache never stores feature *values*, so numerics stay bitwise
//! identical across access modes and only the
//! [`TransferCost`](crate::interconnect::TransferCost) attribution
//! changes.
//!
//! ```
//! use ptdirect::config::EvictionPolicy;
//! use ptdirect::featurestore::PageCache;
//!
//! // 10 rows, 2 rows per page, 2-page capacity, rows 0..4 preseeded.
//! let ranking: Vec<u32> = (0..10).collect();
//! let mut c = PageCache::build(10, 64, 2, EvictionPolicy::Static, 4, Some(&ranking));
//! let cold = c.record(&[0, 3, 9]);
//! assert_eq!(cold, vec![9]); // rows 0 and 3 sit on resident pages 0, 1
//! assert_eq!(c.stats().hits, 2);
//! assert_eq!(c.stats().resident_pages, 2);
//! ```

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::fmt;

use crate::config::EvictionPolicy;
use crate::featurestore::tiered::TierStats;

/// "No slot" marker for the CLOCK engine's page→slot map.
const NO_SLOT: u32 = u32::MAX;

/// Read-only view of the cache's per-page state, handed to eviction
/// engines when they pick a victim — engines own their *order* structures
/// (heaps, stamps, the clock hand) but never duplicate residency,
/// frequency, or refcount state.
pub struct PageView<'a> {
    /// Per-page cumulative access counts (the LFU signal).
    pub freq: &'a [u64],
    /// Per-page residency.
    pub resident: &'a [bool],
    /// Per-page pin refcounts; a page with `refcount > 0` has a gather
    /// in flight over it and must never be chosen as a victim.
    pub refcount: &'a [u32],
}

/// Outcome of an admission attempt against a full cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Admit the candidate after evicting this resident victim page (the
    /// engine has already forgotten the victim's order entry).
    Evict(u32),
    /// The candidate loses (LFU not frequent enough, static placement).
    Reject,
    /// Every would-be victim is pinned; admission is blocked.
    Blocked,
}

/// The pluggable eviction policy: order bookkeeping + victim selection.
///
/// The cache owns residency/frequency/refcount state and calls the
/// engine at three points: every access ([`EvictionEngine::touch`]),
/// every insertion ([`EvictionEngine::admitted`] — preseed or
/// promotion), and every full-cache admission attempt
/// ([`EvictionEngine::decide`]).  Free-capacity inserts bypass `decide`
/// entirely.  Victim selection must skip pinned pages, and ties must
/// break deterministically (lowest page id for the heap engines, hand
/// order for CLOCK) so reports are reproducible across runs.
pub trait EvictionEngine: fmt::Debug {
    fn label(&self) -> &'static str;
    /// Whether misses are ever admitted (`false` freezes the preseeded
    /// placement — the `static` policy and the `--no-promote` flag).
    fn admits(&self) -> bool {
        true
    }
    /// Note one access to `page` at logical time `tick` (one tick per
    /// `record` call).
    fn touch(&mut self, page: u32, resident: bool, tick: u64);
    /// `page` became resident with the given frequency, at `tick`.
    fn admitted(&mut self, page: u32, freq: u64, tick: u64);
    /// Pick the fate of missed page `cand` when the cache is full.
    fn decide(&mut self, cand: u32, view: PageView<'_>) -> Admission;
}

/// Static degree-ranked prefix: the preseed is the placement, forever.
#[derive(Debug, Default)]
struct StaticEngine;

impl EvictionEngine for StaticEngine {
    fn label(&self) -> &'static str {
        "static"
    }
    fn admits(&self) -> bool {
        false
    }
    fn touch(&mut self, _page: u32, _resident: bool, _tick: u64) {}
    fn admitted(&mut self, _page: u32, _freq: u64, _tick: u64) {}
    fn decide(&mut self, _cand: u32, _view: PageView<'_>) -> Admission {
        Admission::Reject
    }
}

/// Least-frequently-used: the pre-refactor lazy min-heap, verbatim.
/// Entries are `(freq-at-insert, page)`; they go stale when a page's
/// frequency moves or it is evicted, and are repaired/discarded on
/// inspection.  A candidate is admitted only when *strictly* more
/// frequent than the least-frequent unpinned resident page.
#[derive(Debug, Default)]
struct LfuEngine {
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl EvictionEngine for LfuEngine {
    fn label(&self) -> &'static str {
        "lfu"
    }
    fn touch(&mut self, _page: u32, _resident: bool, _tick: u64) {
        // Frequencies live in the cache; stale heap keys repair lazily.
    }
    fn admitted(&mut self, page: u32, freq: u64, _tick: u64) {
        self.heap.push(Reverse((freq, page)));
    }
    fn decide(&mut self, cand: u32, view: PageView<'_>) -> Admission {
        // Pinned minima are set aside (stash) and restored afterwards so
        // their heap entries survive; with no pins held this loop is the
        // historical refresh_min + evict_min sequence bit-exactly.
        let mut stash: Vec<Reverse<(u64, u32)>> = Vec::new();
        let decision = loop {
            let Some(&Reverse((f, page))) = self.heap.peek() else {
                break Admission::Blocked;
            };
            let pi = page as usize;
            if !view.resident[pi] {
                self.heap.pop(); // page was evicted; stale duplicate entry
                continue;
            }
            let current = view.freq[pi];
            if current != f {
                self.heap.pop();
                self.heap.push(Reverse((current, page)));
                continue;
            }
            if view.refcount[pi] > 0 {
                self.heap.pop();
                stash.push(Reverse((f, page)));
                continue;
            }
            if view.freq[cand as usize] > f {
                self.heap.pop();
                break Admission::Evict(page);
            }
            break Admission::Reject;
        };
        for e in stash {
            self.heap.push(e);
        }
        decision
    }
}

/// Least-recently-used: same lazy-heap machinery keyed by access stamp
/// instead of frequency.  Every miss is admitted (evicting the oldest
/// unpinned page); stamp ties — preseeded pages all carry stamp 0 —
/// break toward the lowest page id.
#[derive(Debug)]
struct LruEngine {
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    /// Per-page last-access tick (index = page id).
    stamp: Vec<u64>,
}

impl EvictionEngine for LruEngine {
    fn label(&self) -> &'static str {
        "lru"
    }
    fn touch(&mut self, page: u32, _resident: bool, tick: u64) {
        self.stamp[page as usize] = tick;
    }
    fn admitted(&mut self, page: u32, _freq: u64, tick: u64) {
        let s = self.stamp[page as usize].max(tick);
        self.stamp[page as usize] = s;
        self.heap.push(Reverse((s, page)));
    }
    fn decide(&mut self, _cand: u32, view: PageView<'_>) -> Admission {
        let mut stash: Vec<Reverse<(u64, u32)>> = Vec::new();
        let decision = loop {
            let Some(&Reverse((s, page))) = self.heap.peek() else {
                break Admission::Blocked;
            };
            let pi = page as usize;
            if !view.resident[pi] {
                self.heap.pop();
                continue;
            }
            let current = self.stamp[pi];
            if current != s {
                self.heap.pop();
                self.heap.push(Reverse((current, page)));
                continue;
            }
            if view.refcount[pi] > 0 {
                self.heap.pop();
                stash.push(Reverse((s, page)));
                continue;
            }
            self.heap.pop();
            break Admission::Evict(page);
        };
        for e in stash {
            self.heap.push(e);
        }
        decision
    }
}

/// CLOCK (second chance): resident pages sit in a circular buffer; a
/// touch sets the page's reference bit; the hand clears bits as it
/// sweeps and evicts the first unreferenced, unpinned page it reaches.
/// A page referenced since the hand last passed it is never the victim
/// (the property `tests/eviction_policies.rs` pins); pinned pages are
/// skipped *without* losing their reference bit.
#[derive(Debug)]
struct ClockEngine {
    /// Circular frame buffer of resident page ids.
    slots: Vec<u32>,
    /// Page id → slot index (`NO_SLOT` when not resident).
    pos: Vec<u32>,
    /// Per-page reference bits (index = page id).
    referenced: Vec<bool>,
    hand: usize,
}

impl EvictionEngine for ClockEngine {
    fn label(&self) -> &'static str {
        "clock"
    }
    fn touch(&mut self, page: u32, resident: bool, _tick: u64) {
        if resident {
            self.referenced[page as usize] = true;
        }
    }
    fn admitted(&mut self, page: u32, _freq: u64, _tick: u64) {
        // `decide` places replacement admissions in the victim's slot
        // itself; only free-capacity inserts and preseeds land here with
        // no slot yet.
        if self.pos[page as usize] == NO_SLOT {
            self.pos[page as usize] = self.slots.len() as u32;
            self.slots.push(page);
        }
        self.referenced[page as usize] = false;
    }
    fn decide(&mut self, cand: u32, view: PageView<'_>) -> Admission {
        let n = self.slots.len();
        if n == 0 {
            return Admission::Blocked;
        }
        // Two full sweeps suffice when any unpinned page exists: the
        // first clears reference bits, the second must find a victim.
        // The bound only triggers when every frame is pinned.
        let mut steps = 0usize;
        while steps < 2 * n + 1 {
            let page = self.slots[self.hand];
            let pi = page as usize;
            if view.refcount[pi] > 0 {
                self.hand = (self.hand + 1) % n;
                steps += 1;
                continue;
            }
            if self.referenced[pi] {
                self.referenced[pi] = false; // second chance spent
                self.hand = (self.hand + 1) % n;
                steps += 1;
                continue;
            }
            // Victim: the candidate takes over this frame in place.
            self.slots[self.hand] = cand;
            self.pos[pi] = NO_SLOT;
            self.pos[cand as usize] = self.hand as u32;
            self.referenced[cand as usize] = false;
            self.hand = (self.hand + 1) % n;
            return Admission::Evict(page);
        }
        Admission::Blocked
    }
}

/// One paged, refcounted feature cache (membership metadata only — the
/// unified feature table stays the single source of truth for values).
#[derive(Debug)]
pub struct PageCache {
    rows: usize,
    page_rows: usize,
    row_bytes: u64,
    policy: EvictionPolicy,
    capacity_pages: usize,
    /// Per-page residency / pin refcount / access frequency.
    resident: Vec<bool>,
    refcount: Vec<u32>,
    freq: Vec<u64>,
    engine: Box<dyn EvictionEngine + Send>,
    /// Logical clock: one tick per `record` call (the LRU stamp source).
    tick: u64,
    resident_pages: usize,
    /// Rows covered by resident pages (partial last page counted by its
    /// actual span, so `hot_bytes` never overstates the table).
    resident_rows: usize,
    pinned_pages: usize,
    hits: u64,
    misses: u64,
    promotions: u64,
    evictions: u64,
    pins: u64,
    unpins: u64,
    pin_blocked: u64,
}

impl PageCache {
    /// Build a cache over a `rows`-row table of `row_bytes`-byte rows:
    /// `capacity_rows` of budget at `page_rows` granularity (the page
    /// capacity is `capacity_rows / page_rows` — whole pages only), with
    /// the ranking's distinct in-range prefix preseeded page-wise.
    ///
    /// At `page_rows = 1` the preseed walk is exactly
    /// [`ranked_prefix`](crate::featurestore::placement::ranked_prefix)
    /// plus insertion, and the `Lfu` policy replays the pre-refactor
    /// [`TieredCache`](crate::featurestore::tiered::TieredCache)
    /// arithmetic bit-exactly.
    pub fn build(
        rows: usize,
        row_bytes: u64,
        page_rows: usize,
        policy: EvictionPolicy,
        capacity_rows: usize,
        ranking: Option<&[u32]>,
    ) -> PageCache {
        let page_rows = page_rows.max(1);
        let num_pages = rows.div_ceil(page_rows);
        let capacity_pages = (capacity_rows / page_rows).min(num_pages);
        let engine: Box<dyn EvictionEngine + Send> = match policy {
            EvictionPolicy::Static => Box::new(StaticEngine),
            EvictionPolicy::Lfu => Box::new(LfuEngine::default()),
            EvictionPolicy::Lru => Box::new(LruEngine {
                heap: BinaryHeap::new(),
                stamp: vec![0; num_pages],
            }),
            EvictionPolicy::Clock => Box::new(ClockEngine {
                slots: Vec::new(),
                pos: vec![NO_SLOT; num_pages],
                referenced: vec![false; num_pages],
                hand: 0,
            }),
        };
        let mut cache = PageCache {
            rows,
            page_rows,
            row_bytes,
            policy,
            capacity_pages,
            resident: vec![false; num_pages],
            refcount: vec![0; num_pages],
            freq: vec![0; num_pages],
            engine,
            tick: 0,
            resident_pages: 0,
            resident_rows: 0,
            pinned_pages: 0,
            hits: 0,
            misses: 0,
            promotions: 0,
            evictions: 0,
            pins: 0,
            unpins: 0,
            pin_blocked: 0,
        };
        if let Some(rk) = ranking {
            for &r in rk {
                if cache.resident_pages >= cache.capacity_pages {
                    break;
                }
                if (r as usize) < rows {
                    let p = (r as usize / page_rows) as u32;
                    if !cache.resident[p as usize] {
                        cache.insert(p);
                    }
                }
            }
        }
        cache
    }

    pub fn policy(&self) -> EvictionPolicy {
        self.policy
    }

    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    pub fn num_pages(&self) -> usize {
        self.resident.len()
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn resident_pages(&self) -> usize {
        self.resident_pages
    }

    /// Rows covered by resident pages (partial last page by actual span).
    pub fn resident_rows(&self) -> usize {
        self.resident_rows
    }

    pub fn pinned_pages(&self) -> usize {
        self.pinned_pages
    }

    /// Page a row lives on.
    pub fn page_of(&self, row: u32) -> u32 {
        (row as usize / self.page_rows) as u32
    }

    /// Rows page `p` actually covers (the last page may be partial).
    pub fn page_span(&self, p: usize) -> usize {
        let start = p * self.page_rows;
        debug_assert!(start < self.rows.max(1));
        (self.rows - start.min(self.rows)).min(self.page_rows)
    }

    pub fn is_resident_page(&self, page: u32) -> bool {
        self.resident[page as usize]
    }

    /// Whether a row's page is resident (the row-level membership the
    /// stores classify against).
    pub fn is_resident(&self, row: u32) -> bool {
        self.resident[row as usize / self.page_rows]
    }

    /// Current pin refcount of a page.
    pub fn refcount_of(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// Resident page ids in ascending order (test/diagnostic helper).
    pub fn resident_page_ids(&self) -> Vec<u32> {
        (0..self.resident.len() as u32)
            .filter(|&p| self.resident[p as usize])
            .collect()
    }

    /// Counters and gauges in the shared [`TierStats`] shape.
    pub fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits,
            misses: self.misses,
            promotions: self.promotions,
            evictions: self.evictions,
            hot_rows: self.resident_rows,
            hot_bytes: self.resident_rows as u64 * self.row_bytes,
            capacity_rows: self.capacity_pages * self.page_rows,
            capacity_bytes: (self.capacity_pages * self.page_rows) as u64 * self.row_bytes,
            pins: self.pins,
            unpins: self.unpins,
            pin_blocked: self.pin_blocked,
            resident_pages: self.resident_pages,
            capacity_pages: self.capacity_pages,
            page_rows: self.page_rows,
        }
    }

    /// Pin the pages covering `idx` (one refcount each per occurrence's
    /// page, deduplicated per call): a pinned page is never evicted.
    /// Callers must pair every `pin_rows` with an `unpin_rows` of the
    /// same `idx` — the serving engine holds a batch's pins while the
    /// per-request blocks scatter out of the gathered buffer.
    pub fn pin_rows(&mut self, idx: &[u32]) {
        let pages = self.pages_of(idx);
        self.pin_pages(&pages);
    }

    /// Release the pins `pin_rows(idx)` took.
    pub fn unpin_rows(&mut self, idx: &[u32]) {
        let pages = self.pages_of(idx);
        self.unpin_pages(&pages);
    }

    /// Distinct pages behind an id stream, ascending.
    fn pages_of(&self, idx: &[u32]) -> Vec<u32> {
        let mut pages: Vec<u32> = idx
            .iter()
            .map(|&r| (r as usize / self.page_rows) as u32)
            .collect();
        pages.sort_unstable();
        pages.dedup();
        pages
    }

    fn pin_pages(&mut self, pages: &[u32]) {
        for &p in pages {
            let pi = p as usize;
            if self.refcount[pi] == 0 {
                self.pinned_pages += 1;
            }
            self.refcount[pi] += 1;
            self.pins += 1;
        }
    }

    fn unpin_pages(&mut self, pages: &[u32]) {
        for &p in pages {
            let pi = p as usize;
            debug_assert!(self.refcount[pi] > 0, "unpin of unpinned page {p}");
            if self.refcount[pi] > 0 {
                self.refcount[pi] -= 1;
                if self.refcount[pi] == 0 {
                    self.pinned_pages -= 1;
                }
                self.unpins += 1;
            }
        }
    }

    /// Account one gather: splits `idx` into hits and the returned cold
    /// subset (original order preserved — the cold rows form the link
    /// request stream), bumps page frequencies, then runs the policy's
    /// admission pass over the missed pages (sorted, deduplicated).
    ///
    /// The touched pages are pinned for the duration of the
    /// classification and released before admission — the gather in
    /// flight can never lose its own pages, and promotion (which runs
    /// *between* batches: the first toucher still pays cold cost) sees
    /// the unpinned refcounts, exactly the pre-refactor semantics.
    pub fn record(&mut self, idx: &[u32]) -> Vec<u32> {
        self.tick += 1;
        let touched = self.pages_of(idx);
        self.pin_pages(&touched);
        let mut cold = Vec::new();
        for &r in idx {
            let p = r as usize / self.page_rows;
            self.freq[p] += 1;
            let resident = self.resident[p];
            self.engine.touch(p as u32, resident, self.tick);
            if resident {
                self.hits += 1;
            } else {
                self.misses += 1;
                cold.push(r);
            }
        }
        self.unpin_pages(&touched);
        if self.engine.admits() && self.capacity_pages > 0 && !cold.is_empty() {
            let mut candidates: Vec<u32> = cold
                .iter()
                .map(|&r| (r as usize / self.page_rows) as u32)
                .collect();
            candidates.sort_unstable();
            candidates.dedup();
            for p in candidates {
                self.maybe_admit(p);
            }
        }
        cold
    }

    fn maybe_admit(&mut self, p: u32) {
        if self.resident[p as usize] {
            return;
        }
        if self.resident_pages < self.capacity_pages {
            self.insert(p);
            self.promotions += 1;
            return;
        }
        let decision = self.engine.decide(
            p,
            PageView {
                freq: &self.freq,
                resident: &self.resident,
                refcount: &self.refcount,
            },
        );
        match decision {
            Admission::Evict(victim) => {
                self.evict(victim);
                self.insert(p);
                self.promotions += 1;
            }
            Admission::Reject => {}
            Admission::Blocked => self.pin_blocked += 1,
        }
    }

    fn insert(&mut self, p: u32) {
        let pi = p as usize;
        debug_assert!(!self.resident[pi]);
        self.resident[pi] = true;
        self.resident_pages += 1;
        self.resident_rows += self.page_span(pi);
        self.engine.admitted(p, self.freq[pi], self.tick);
    }

    fn evict(&mut self, p: u32) {
        let pi = p as usize;
        debug_assert!(self.resident[pi]);
        debug_assert_eq!(self.refcount[pi], 0, "pinned page {p} evicted");
        self.resident[pi] = false;
        self.resident_pages -= 1;
        self.resident_rows -= self.page_span(pi);
        self.evictions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(
        rows: usize,
        page_rows: usize,
        policy: EvictionPolicy,
        capacity_rows: usize,
        ranking: Option<Vec<u32>>,
    ) -> PageCache {
        PageCache::build(rows, 4, page_rows, policy, capacity_rows, ranking.as_deref())
    }

    #[test]
    fn pages_tile_the_table_with_a_partial_tail() {
        let c = build(10, 4, EvictionPolicy::Static, 8, None);
        assert_eq!(c.num_pages(), 3);
        assert_eq!(c.page_span(0), 4);
        assert_eq!(c.page_span(1), 4);
        assert_eq!(c.page_span(2), 2); // rows 8, 9 only
        for r in 0..10u32 {
            assert_eq!(c.page_of(r), r / 4);
        }
    }

    #[test]
    fn preseed_walks_the_ranking_page_wise() {
        // Ranking hits pages 2, 0, 2 (duplicate page skipped), 1 — but
        // capacity is 2 pages, so pages 2 and 0 go resident.
        let c = build(12, 4, EvictionPolicy::Static, 8, Some(vec![9, 1, 10, 4]));
        assert_eq!(c.resident_page_ids(), vec![0, 2]);
        assert_eq!(c.resident_rows(), 8);
    }

    #[test]
    fn record_splits_hits_by_page_membership() {
        let mut c = build(12, 4, EvictionPolicy::Static, 4, Some(vec![0]));
        // Page 0 resident: rows 0..4 hit; everything else is cold.
        let cold = c.record(&[1, 3, 4, 11, 1]);
        assert_eq!(cold, vec![4, 11]);
        let s = c.stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.misses, 2);
        assert_eq!(s.page_rows, 4);
    }

    #[test]
    fn static_never_admits_or_evicts() {
        let mut c = build(20, 1, EvictionPolicy::Static, 2, Some(vec![0, 1]));
        for _ in 0..10 {
            c.record(&[5, 6, 7]);
        }
        assert_eq!(c.resident_page_ids(), vec![0, 1]);
        let s = c.stats();
        assert_eq!(s.promotions, 0);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn lfu_admits_only_strictly_more_frequent_pages() {
        let mut c = build(10, 1, EvictionPolicy::Lfu, 2, None);
        c.record(&[1, 2]); // both promoted into free capacity
        assert!(c.is_resident(1) && c.is_resident(2));
        c.record(&[2]); // freq: p1=1, p2=2
        c.record(&[3]); // freq p3=1 == min -> rejected (strict >)
        assert!(!c.is_resident(3));
        c.record(&[3]); // freq p3=2 > freq p1=1 -> displaces the minimum
        assert!(c.is_resident(3) && !c.is_resident(1));
        c.record(&[3]); // now a hit
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn lru_evicts_the_oldest_stamp() {
        let mut c = build(10, 1, EvictionPolicy::Lru, 2, None);
        c.record(&[1]); // tick 1
        c.record(&[2]); // tick 2
        c.record(&[3]); // tick 3: page 1 (stamp 1) is the oldest
        assert!(!c.is_resident(1));
        assert!(c.is_resident(2) && c.is_resident(3));
        c.record(&[2]); // refresh 2's stamp
        c.record(&[4]); // evicts 3 (stamp 3 < stamp 4 of page 2)
        assert!(c.is_resident(2) && c.is_resident(4) && !c.is_resident(3));
    }

    #[test]
    fn lru_breaks_preseed_stamp_ties_by_lowest_page_id() {
        // Preseeded pages all carry stamp 0; the first eviction must take
        // the lowest page id deterministically.
        let mut c = build(10, 1, EvictionPolicy::Lru, 3, Some(vec![7, 2, 5]));
        c.record(&[9]);
        assert!(!c.is_resident(2), "lowest-id stamp-0 page must go first");
        assert!(c.is_resident(5) && c.is_resident(7) && c.is_resident(9));
    }

    #[test]
    fn clock_grants_a_second_chance_to_referenced_pages() {
        let mut c = build(10, 1, EvictionPolicy::Clock, 2, Some(vec![0, 1]));
        c.record(&[0]); // reference page 0
        // Miss on page 5: hand starts at slot 0 (page 0, referenced ->
        // spent), moves to page 1 (unreferenced) -> victim.
        c.record(&[5]);
        assert!(c.is_resident(0), "referenced page survived the sweep");
        assert!(!c.is_resident(1));
        assert!(c.is_resident(5));
    }

    #[test]
    fn pinned_pages_are_never_victims() {
        for policy in [
            EvictionPolicy::Lfu,
            EvictionPolicy::Lru,
            EvictionPolicy::Clock,
        ] {
            let mut c = build(10, 1, policy, 2, Some(vec![0, 1]));
            c.pin_rows(&[0, 1]);
            // Make the intruder overwhelmingly admissible under LFU.
            for _ in 0..5 {
                c.record(&[5]);
            }
            assert!(
                c.is_resident(0) && c.is_resident(1),
                "{policy:?} evicted a pinned page"
            );
            assert!(!c.is_resident(5), "{policy:?} admitted over pinned frames");
            assert!(c.stats().pin_blocked > 0, "{policy:?} never reported blocking");
            c.unpin_rows(&[0, 1]);
            assert_eq!(c.pinned_pages(), 0);
            // Unpinned again: the admission goes through.
            c.record(&[5]);
            assert!(c.is_resident(5), "{policy:?} stayed blocked after unpin");
        }
    }

    #[test]
    fn refcounts_return_to_zero_after_every_record() {
        let mut c = build(20, 2, EvictionPolicy::Lfu, 10, None);
        for step in 0..5u32 {
            c.record(&[step, step + 3, step + 7, step]);
            assert_eq!(c.pinned_pages(), 0, "step {step}");
            for p in 0..c.num_pages() as u32 {
                assert_eq!(c.refcount_of(p), 0, "page {p} after step {step}");
            }
        }
        let s = c.stats();
        assert_eq!(s.pins, s.unpins, "gather pins must balance");
        assert!(s.pins > 0);
    }

    #[test]
    fn residency_never_exceeds_the_page_budget() {
        let mut c = build(100, 8, EvictionPolicy::Lru, 30, None);
        assert_eq!(c.capacity_pages(), 3); // whole pages only: 30 / 8
        for i in 0..200u32 {
            c.record(&[(i * 13) % 100]);
            assert!(c.resident_pages() <= c.capacity_pages());
            assert!(c.resident_rows() <= c.capacity_pages() * c.page_rows());
        }
        let s = c.stats();
        assert_eq!(s.capacity_rows, 24);
        assert!(s.hot_rows <= s.capacity_rows);
    }

    #[test]
    fn partial_tail_page_reports_its_true_span() {
        // 10 rows at 4 rows/page: page 2 covers rows 8..10 only.
        let c = build(10, 4, EvictionPolicy::Static, 12, Some((0..10).collect()));
        assert_eq!(c.resident_pages(), 3);
        assert_eq!(c.resident_rows(), 10);
        assert_eq!(c.stats().hot_bytes, 10 * 4);
    }
}

//! Tiered hot-cache feature tier: a GPU-resident hot set over the unified
//! cold tier.
//!
//! The paper's unified-tensor modes make *every* gathered row pay PCIe
//! cost.  The follow-up "Graph Neural Network Training with Data Tiering"
//! (arXiv:2111.05894) observes that GNN feature accesses are extremely
//! skewed — access frequency is proportional to node degree under neighbor
//! sampling — so pinning the hottest rows in GPU memory recovers most of
//! the GPU-resident speedup without the out-of-memory wall; GIDS
//! (arXiv:2306.16384) ships the same hot/cold split in production.
//!
//! [`TieredCache`] tracks which rows are hot.  It is a thin
//! policy/capacity wrapper over the shared paged cache
//! ([`PageCache`](crate::featurestore::PageCache), DESIGN.md §12):
//! residency is per fixed-size page of `--page-rows` consecutive rows,
//! placement comes from two sources that compose:
//!
//! * a static *ranking* (descending node degree, [`degree_ranking`]) used
//!   to pre-seed the hot set page-wise, and
//! * an optional online eviction policy (`--eviction`, default LFU):
//!   per-page access frequencies are counted on every gather, and a cold
//!   page that the policy admits displaces a victim (for LFU: a page that
//!   becomes more frequent than the coldest hot page; lazy min-heap,
//!   stale entries repaired on inspection).  Repeated epochs therefore
//!   warm the cache even from an empty start.  `--no-promote` forces the
//!   `static` policy: the preseeded placement is frozen.
//!
//! `--eviction static --page-rows 1` (equivalently `--no-promote`) and
//! the default `--eviction lfu --page-rows 1` both reproduce the
//! pre-refactor row-granular cache bit-exactly — the differential anchor
//! of `tests/pagecache_properties.rs`.
//!
//! Capacity is `SystemProfile::gpu_mem_bytes` minus a configurable
//! model/activation reserve, and additionally capped by the `hot_frac`
//! sweep knob.  The cache never stores feature *values* — the single
//! unified table remains the source of truth, so numerics are identical
//! across access modes by construction; only the [`TransferCost`]
//! attribution changes (hot rows are kernel-launch-only like `GpuResident`,
//! cold rows pay the `UnifiedAligned` zero-copy PCIe path).
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::featurestore::{TierConfig, TieredCache};
//!
//! // 100-row table, 64 B rows, 20% hot, rows 0 and 1 pre-seeded hot.
//! let sys = SystemProfile::system1();
//! let cfg = TierConfig { hot_frac: 0.2, ranking: Some(vec![0, 1]), ..TierConfig::default() };
//! let mut cache = TieredCache::new(100, 64, &sys, &cfg);
//! let cold = cache.record(&[0, 5, 1]);
//! assert_eq!(cold, vec![5]); // rows 0 and 1 hit; 5 pays the cold path
//! assert_eq!(cache.stats().hits, 2);
//! ```
//!
//! [`TransferCost`]: crate::interconnect::TransferCost

use std::cmp::Reverse;

use crate::config::{EvictionPolicy, RunConfig, SystemProfile};
use crate::featurestore::pagecache::PageCache;
use crate::graph::Csr;

/// Placement/capacity knobs for the tiered store.
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Target hot fraction of the table's rows in [0, 1] (the sweep axis of
    /// `cargo bench --bench tiering_sweep`).
    pub hot_frac: f64,
    /// GPU bytes reserved for model parameters + activations; the hot tier
    /// may only use what remains of `gpu_mem_bytes`.
    pub reserve_bytes: u64,
    /// Enable online promotion (epoch-over-epoch warming).  `false`
    /// forces the `static` eviction policy regardless of `eviction`.
    pub promote: bool,
    /// Static placement ranking, hottest first (usually descending degree).
    /// `None` starts the cache cold and relies on promotion.
    pub ranking: Option<Vec<u32>>,
    /// Rows per cache page (`--page-rows`); 1 is row-granular and
    /// reproduces the pre-refactor cache bit-exactly.
    pub page_rows: usize,
    /// Eviction policy for online promotion (`--eviction`).
    pub eviction: EvictionPolicy,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            hot_frac: 0.25,
            reserve_bytes: 0,
            promote: true,
            ranking: None,
            page_rows: 1,
            eviction: EvictionPolicy::Lfu,
        }
    }
}

impl TierConfig {
    /// Derive the tier configuration a training run wants: degree ranking
    /// from its graph plus the `hot_frac`/reserve/promotion knobs of the
    /// run config.
    pub fn from_run(cfg: &RunConfig, graph: &Csr) -> TierConfig {
        TierConfig {
            hot_frac: cfg.hot_frac,
            reserve_bytes: (cfg.system.gpu_mem_bytes as f64
                * cfg.gpu_reserve_frac.clamp(0.0, 1.0)) as u64,
            promote: cfg.tier_promote,
            ranking: Some(degree_ranking(graph)),
            page_rows: cfg.page_rows,
            eviction: cfg.eviction,
        }
    }
}

/// Node ids ordered by descending degree (ties broken by id, so the
/// ranking — and with it every simulated cost — is deterministic).
pub fn degree_ranking(graph: &Csr) -> Vec<u32> {
    let mut order: Vec<u32> = (0..graph.num_nodes() as u32).collect();
    order.sort_by_key(|&v| (Reverse(graph.degree(v)), v));
    order
}

/// Counters and gauges of the tier (counters are cumulative; see
/// [`TierStats::since`] for per-epoch deltas).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Rows served from the GPU-resident hot tier.
    pub hits: u64,
    /// Rows served over PCIe from the unified cold tier.
    pub misses: u64,
    /// Online promotions performed (pages admitted).
    pub promotions: u64,
    /// Hot pages displaced by promotions.
    pub evictions: u64,
    /// Current hot-set size, rows / bytes.
    pub hot_rows: usize,
    pub hot_bytes: u64,
    /// Hot-set capacity, rows / bytes (never exceeded; whole pages only).
    pub capacity_rows: usize,
    pub capacity_bytes: u64,
    /// Page pins taken / released (gathers in flight plus serving
    /// streams holding scatter windows; equal whenever no pin is held).
    pub pins: u64,
    pub unpins: u64,
    /// Admissions that found every would-be victim pinned.
    pub pin_blocked: u64,
    /// Current resident pages / page capacity / page granularity.
    pub resident_pages: usize,
    pub capacity_pages: usize,
    pub page_rows: usize,
}

impl TierStats {
    /// Fraction of requested rows served from the hot tier.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas relative to an `earlier` snapshot; gauges keep their
    /// current (end-state) values.
    pub fn since(&self, earlier: &TierStats) -> TierStats {
        TierStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            promotions: self.promotions - earlier.promotions,
            evictions: self.evictions - earlier.evictions,
            pins: self.pins - earlier.pins,
            unpins: self.unpins - earlier.unpins,
            pin_blocked: self.pin_blocked - earlier.pin_blocked,
            ..*self
        }
    }
}

/// Hot-set membership for one feature table: capacity/policy resolution
/// over the shared [`PageCache`].
#[derive(Debug)]
pub struct TieredCache {
    cache: PageCache,
}

impl TieredCache {
    /// Build the cache for a `rows`-row table of `row_bytes`-byte rows.
    ///
    /// Capacity = min(`hot_frac` · rows, (gpu_mem − reserve) / row_bytes).
    /// When a ranking is supplied its prefix is pre-seeded hot; otherwise
    /// the cache starts cold and (if enabled) warms through promotion.
    pub fn new(rows: usize, row_bytes: u64, sys: &SystemProfile, cfg: &TierConfig) -> TieredCache {
        Self::with_row_basis(rows, rows, row_bytes, sys, cfg)
    }

    /// Like [`TieredCache::new`], but `hot_frac` (and the GPU-memory
    /// budget) apply to `basis_rows` instead of the full table — the
    /// sharded store builds one cache per GPU this way, with `basis_rows`
    /// set to that GPU's shard size while membership/frequency vectors
    /// still span the whole table (row ids stay global).
    ///
    /// `basis_rows == rows` reproduces [`TieredCache::new`] exactly.
    pub fn with_row_basis(
        rows: usize,
        basis_rows: usize,
        row_bytes: u64,
        sys: &SystemProfile,
        cfg: &TierConfig,
    ) -> TieredCache {
        let budget_bytes = sys.gpu_mem_bytes.saturating_sub(cfg.reserve_bytes);
        let budget_rows = if row_bytes == 0 {
            0
        } else {
            (budget_bytes / row_bytes).min(basis_rows as u64) as usize
        };
        let target_rows = (cfg.hot_frac.clamp(0.0, 1.0) * basis_rows as f64).floor() as usize;
        let capacity_rows = target_rows.min(budget_rows);
        // `--no-promote` freezes the preseeded placement no matter which
        // eviction policy is configured.
        let policy = if cfg.promote {
            cfg.eviction
        } else {
            EvictionPolicy::Static
        };
        TieredCache {
            cache: PageCache::build(
                rows,
                row_bytes,
                cfg.page_rows,
                policy,
                capacity_rows,
                cfg.ranking.as_deref(),
            ),
        }
    }

    /// Row capacity at page granularity (whole pages only; equal to the
    /// budgeted row capacity when `page_rows == 1`).
    pub fn capacity_rows(&self) -> usize {
        self.cache.capacity_pages() * self.cache.page_rows()
    }

    pub fn hot_rows(&self) -> usize {
        self.cache.resident_rows()
    }

    pub fn page_rows(&self) -> usize {
        self.cache.page_rows()
    }

    pub fn is_hot(&self, row: u32) -> bool {
        self.cache.is_resident(row)
    }

    pub fn stats(&self) -> TierStats {
        self.cache.stats()
    }

    /// Pin the pages covering `idx` so in-flight gathers are never
    /// evicted; pair with [`TieredCache::unpin_rows`].
    pub fn pin_rows(&mut self, idx: &[u32]) {
        self.cache.pin_rows(idx);
    }

    /// Release the pins [`TieredCache::pin_rows`] took.
    pub fn unpin_rows(&mut self, idx: &[u32]) {
        self.cache.unpin_rows(idx);
    }

    /// Account one gather: splits `idx` into hits and the returned cold
    /// subset (original order preserved — the cold rows form the PCIe
    /// request stream), bumps page frequencies, then applies the eviction
    /// policy's admission pass ([`PageCache::record`]).
    ///
    /// Promotion runs *after* the split on purpose: the batch that first
    /// touches a page still pays its cold cost; only later batches benefit.
    ///
    /// Under the default gather deduplication (DESIGN.md §10) `idx` is
    /// already the batch's *compacted* unique stream, so hits/misses and
    /// page frequencies count each distinct row once per batch; with
    /// `--no-dedup` every duplicated occurrence counts, as before.
    pub fn record(&mut self, idx: &[u32]) -> Vec<u32> {
        self.cache.record(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    fn cfg(hot_frac: f64, promote: bool, ranking: Option<Vec<u32>>) -> TierConfig {
        TierConfig {
            hot_frac,
            promote,
            ranking,
            ..TierConfig::default()
        }
    }

    #[test]
    fn capacity_is_min_of_frac_and_budget() {
        // 100 rows of 1 KiB; hot_frac 0.5 -> 50 rows unless budget is lower.
        let c = TieredCache::new(100, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 50);

        let mut small = sys();
        small.gpu_mem_bytes = 10 * 1024; // room for 10 rows
        let c = TieredCache::new(100, 1024, &small, &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 10);
    }

    #[test]
    fn row_basis_scales_capacity_to_the_shard() {
        // 100-row table, but hot_frac applies to a 40-row shard.
        let c = TieredCache::with_row_basis(100, 40, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 20);
        // basis == rows reproduces `new` exactly.
        let a = TieredCache::new(100, 1024, &sys(), &cfg(0.5, false, None));
        let b = TieredCache::with_row_basis(100, 100, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(a.capacity_rows(), b.capacity_rows());
    }

    #[test]
    fn reserve_shrinks_budget() {
        let mut s = sys();
        s.gpu_mem_bytes = 20 * 1024;
        let mut tc = cfg(1.0, false, Some((0..100).collect()));
        tc.reserve_bytes = 10 * 1024;
        let c = TieredCache::new(100, 1024, &s, &tc);
        assert_eq!(c.capacity_rows(), 10);
        assert_eq!(c.stats().hot_bytes, 10 * 1024);
        assert!(c.stats().hot_bytes <= s.gpu_mem_bytes - tc.reserve_bytes);
    }

    #[test]
    fn ranking_prefix_preseeds_hot() {
        let ranking = vec![7u32, 3, 9, 1];
        let c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(ranking)));
        assert_eq!(c.capacity_rows(), 2);
        assert!(c.is_hot(7) && c.is_hot(3));
        assert!(!c.is_hot(9) && !c.is_hot(1));
    }

    #[test]
    fn record_splits_hits_and_misses() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let cold = c.record(&[0, 5, 1, 5, 9]);
        assert_eq!(cold, vec![5, 5, 9]);
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 3);
        assert_eq!(s.hits + s.misses, 5);
    }

    #[test]
    fn compacted_stream_counts_each_distinct_row_once() {
        // The dedup subsystem hands `record` the unique stream: the cold
        // subset (and with it the whole PCIe request stream) shrinks from
        // per-occurrence to per-distinct-row.
        let duplicated = [5u32, 9, 5, 5, 9, 0];
        let compacted = crate::sampler::compact::GatherPlan::build(&duplicated);
        let mut dup = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let mut ded = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let cold_dup = dup.record(&duplicated);
        let cold_ded = ded.record(compacted.unique_nodes());
        assert_eq!(cold_dup, vec![5, 9, 5, 5, 9]);
        assert_eq!(cold_ded, vec![5, 9], "compacted cold stream must be distinct");
        assert_eq!(ded.stats().hits + ded.stats().misses, 3);
        assert_eq!(dup.stats().hits + dup.stats().misses, 6);
    }

    #[test]
    fn zero_frac_means_everything_cold() {
        let mut c = TieredCache::new(50, 8, &sys(), &cfg(0.0, true, Some((0..50).collect())));
        for _ in 0..5 {
            let cold = c.record(&[1, 2, 3]);
            assert_eq!(cold.len(), 3);
        }
        assert_eq!(c.stats().hits, 0);
        assert_eq!(c.stats().hot_rows, 0);
    }

    #[test]
    fn cold_start_warms_through_promotion() {
        let mut c = TieredCache::new(100, 4, &sys(), &cfg(0.1, true, None));
        assert_eq!(c.hot_rows(), 0);
        let idx = [4u32, 8, 15, 16, 23, 42];
        let first = c.record(&idx);
        assert_eq!(first.len(), idx.len()); // cold epoch pays full cost
        let second = c.record(&idx);
        assert!(second.len() < idx.len(), "promotion never warmed the cache");
        assert!(c.stats().promotions > 0);
        assert!(c.hot_rows() <= c.capacity_rows());
    }

    #[test]
    fn promotion_respects_capacity_and_evicts_lfu() {
        // capacity 2; rows 1,2 get hot; then row 3 becomes more frequent
        // than row 1 and displaces the LFU minimum.
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, true, None));
        c.record(&[1, 2]); // both promoted (capacity free)
        assert!(c.is_hot(1) && c.is_hot(2));
        c.record(&[2]); // freq: r1=1, r2=2
        for _ in 0..3 {
            c.record(&[3]); // freq r3 grows past r1
        }
        assert!(c.is_hot(3), "hotter row was not promoted");
        assert!(!c.is_hot(1), "LFU minimum was not evicted");
        assert_eq!(c.hot_rows(), 2);
        assert!(c.stats().evictions >= 1);
    }

    #[test]
    fn promotion_disabled_keeps_static_placement() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        for _ in 0..10 {
            c.record(&[5, 6, 7]);
        }
        assert!(c.is_hot(0) && c.is_hot(1));
        assert!(!c.is_hot(5) && !c.is_hot(6) && !c.is_hot(7));
        assert_eq!(c.stats().promotions, 0);
    }

    #[test]
    fn stats_since_gives_epoch_deltas() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        c.record(&[0, 5]);
        let snap = c.stats();
        c.record(&[0, 1, 5]);
        let delta = c.stats().since(&snap);
        assert_eq!(delta.hits, 2);
        assert_eq!(delta.misses, 1);
        assert!((delta.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn page_rows_truncates_capacity_to_whole_pages() {
        let mut tc = cfg(0.5, false, Some((0..100).collect()));
        tc.page_rows = 8;
        // 100 rows at hot_frac 0.5 -> 50-row budget -> 6 whole pages.
        let c = TieredCache::new(100, 4, &sys(), &tc);
        assert_eq!(c.page_rows(), 8);
        assert_eq!(c.capacity_rows(), 48);
        assert_eq!(c.stats().capacity_pages, 6);
        assert_eq!(c.stats().resident_pages, 6);
        // Row 47 sits on resident page 5; row 48 on page 6 (cold).
        assert!(c.is_hot(47));
        assert!(!c.is_hot(48));
    }

    #[test]
    fn eviction_knob_reaches_the_engine() {
        // Under LRU every miss is admitted; under LFU a once-seen row
        // cannot displace an equally-frequent resident (strict >).
        let mut lru = cfg(0.2, true, None);
        lru.eviction = EvictionPolicy::Lru;
        let mut lfu = cfg(0.2, true, None);
        lfu.eviction = EvictionPolicy::Lfu;
        let mut a = TieredCache::new(10, 4, &sys(), &lru);
        let mut b = TieredCache::new(10, 4, &sys(), &lfu);
        for c in [&mut a, &mut b] {
            c.record(&[1, 2]); // fill capacity 2
            c.record(&[3]); // one-shot intruder
        }
        assert!(a.is_hot(3), "LRU admits every miss");
        assert!(!b.is_hot(3), "LFU rejects a non-hotter intruder");
    }

    #[test]
    fn no_promote_overrides_the_eviction_knob() {
        let mut tc = cfg(0.2, false, Some(vec![0, 1]));
        tc.eviction = EvictionPolicy::Lru;
        let mut c = TieredCache::new(10, 4, &sys(), &tc);
        for _ in 0..5 {
            c.record(&[7, 8]);
        }
        assert!(c.is_hot(0) && c.is_hot(1), "static placement was disturbed");
        assert_eq!(c.stats().promotions, 0);
    }

    #[test]
    fn pins_block_eviction_until_released() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, true, Some(vec![0, 1])));
        c.pin_rows(&[0, 1]);
        for _ in 0..3 {
            c.record(&[5]); // freq 3 > 0 would normally displace row 0
        }
        assert!(c.is_hot(0) && c.is_hot(1));
        assert!(c.stats().pin_blocked > 0);
        c.unpin_rows(&[0, 1]);
        c.record(&[5]);
        assert!(c.is_hot(5), "admission still blocked after unpin");
        assert_eq!(c.stats().pins, c.stats().unpins);
    }

    #[test]
    fn degree_ranking_orders_by_degree_then_id() {
        let g = Csr::from_edges(4, &[(2, 0), (2, 1), (2, 3), (0, 1), (0, 2), (1, 0)]).unwrap();
        // degrees: 0 -> 2, 1 -> 1, 2 -> 3, 3 -> 0
        assert_eq!(degree_ranking(&g), vec![2, 0, 1, 3]);
    }
}

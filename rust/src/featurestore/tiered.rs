//! Tiered hot-cache feature tier: a GPU-resident hot set over the unified
//! cold tier.
//!
//! The paper's unified-tensor modes make *every* gathered row pay PCIe
//! cost.  The follow-up "Graph Neural Network Training with Data Tiering"
//! (arXiv:2111.05894) observes that GNN feature accesses are extremely
//! skewed — access frequency is proportional to node degree under neighbor
//! sampling — so pinning the hottest rows in GPU memory recovers most of
//! the GPU-resident speedup without the out-of-memory wall; GIDS
//! (arXiv:2306.16384) ships the same hot/cold split in production.
//!
//! [`TieredCache`] tracks which rows are hot.  Placement comes from two
//! sources that compose:
//!
//! * a static *ranking* (descending node degree, [`degree_ranking`]) used
//!   to pre-seed the hot set, and
//! * an optional online LFU promotion policy: per-row access frequencies
//!   are counted on every gather, and a cold row that becomes more frequent
//!   than the coldest hot row displaces it (lazy min-heap, stale entries
//!   repaired on inspection).  Repeated epochs therefore warm the cache
//!   even from an empty start.
//!
//! Capacity is `SystemProfile::gpu_mem_bytes` minus a configurable
//! model/activation reserve, and additionally capped by the `hot_frac`
//! sweep knob.  The cache never stores feature *values* — the single
//! unified table remains the source of truth, so numerics are identical
//! across access modes by construction; only the [`TransferCost`]
//! attribution changes (hot rows are kernel-launch-only like `GpuResident`,
//! cold rows pay the `UnifiedAligned` zero-copy PCIe path).
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::featurestore::{TierConfig, TieredCache};
//!
//! // 100-row table, 64 B rows, 20% hot, rows 0 and 1 pre-seeded hot.
//! let sys = SystemProfile::system1();
//! let cfg = TierConfig { hot_frac: 0.2, ranking: Some(vec![0, 1]), ..TierConfig::default() };
//! let mut cache = TieredCache::new(100, 64, &sys, &cfg);
//! let cold = cache.record(&[0, 5, 1]);
//! assert_eq!(cold, vec![5]); // rows 0 and 1 hit; 5 pays the cold path
//! assert_eq!(cache.stats().hits, 2);
//! ```
//!
//! [`TransferCost`]: crate::interconnect::TransferCost

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::{RunConfig, SystemProfile};
use crate::graph::Csr;

/// Placement/capacity knobs for the tiered store.
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Target hot fraction of the table's rows in [0, 1] (the sweep axis of
    /// `cargo bench --bench tiering_sweep`).
    pub hot_frac: f64,
    /// GPU bytes reserved for model parameters + activations; the hot tier
    /// may only use what remains of `gpu_mem_bytes`.
    pub reserve_bytes: u64,
    /// Enable online LFU promotion (epoch-over-epoch warming).
    pub promote: bool,
    /// Static placement ranking, hottest first (usually descending degree).
    /// `None` starts the cache cold and relies on promotion.
    pub ranking: Option<Vec<u32>>,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            hot_frac: 0.25,
            reserve_bytes: 0,
            promote: true,
            ranking: None,
        }
    }
}

impl TierConfig {
    /// Derive the tier configuration a training run wants: degree ranking
    /// from its graph plus the `hot_frac`/reserve/promotion knobs of the
    /// run config.
    pub fn from_run(cfg: &RunConfig, graph: &Csr) -> TierConfig {
        TierConfig {
            hot_frac: cfg.hot_frac,
            reserve_bytes: (cfg.system.gpu_mem_bytes as f64
                * cfg.gpu_reserve_frac.clamp(0.0, 1.0)) as u64,
            promote: cfg.tier_promote,
            ranking: Some(degree_ranking(graph)),
        }
    }
}

/// Node ids ordered by descending degree (ties broken by id, so the
/// ranking — and with it every simulated cost — is deterministic).
pub fn degree_ranking(graph: &Csr) -> Vec<u32> {
    let mut order: Vec<u32> = (0..graph.num_nodes() as u32).collect();
    order.sort_by_key(|&v| (Reverse(graph.degree(v)), v));
    order
}

/// Counters and gauges of the tier (counters are cumulative; see
/// [`TierStats::since`] for per-epoch deltas).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Rows served from the GPU-resident hot tier.
    pub hits: u64,
    /// Rows served over PCIe from the unified cold tier.
    pub misses: u64,
    /// Online LFU promotions performed.
    pub promotions: u64,
    /// Hot rows displaced by promotions.
    pub evictions: u64,
    /// Current hot-set size, rows / bytes.
    pub hot_rows: usize,
    pub hot_bytes: u64,
    /// Hot-set capacity, rows / bytes (never exceeded).
    pub capacity_rows: usize,
    pub capacity_bytes: u64,
}

impl TierStats {
    /// Fraction of requested rows served from the hot tier.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter deltas relative to an `earlier` snapshot; gauges keep their
    /// current (end-state) values.
    pub fn since(&self, earlier: &TierStats) -> TierStats {
        TierStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            promotions: self.promotions - earlier.promotions,
            evictions: self.evictions - earlier.evictions,
            ..*self
        }
    }
}

/// Hot-set membership + LFU machinery for one feature table.
#[derive(Debug)]
pub struct TieredCache {
    /// Per-row hot membership.
    hot: Vec<bool>,
    /// Per-row access counts (LFU signal).
    freq: Vec<u64>,
    /// Lazy min-heap over hot rows as `(freq-at-insert, row)`; entries go
    /// stale when a row's frequency moves or it is evicted, and are
    /// repaired/discarded on inspection.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    hot_rows: usize,
    capacity_rows: usize,
    row_bytes: u64,
    promote: bool,
    hits: u64,
    misses: u64,
    promotions: u64,
    evictions: u64,
}

impl TieredCache {
    /// Build the cache for a `rows`-row table of `row_bytes`-byte rows.
    ///
    /// Capacity = min(`hot_frac` · rows, (gpu_mem − reserve) / row_bytes).
    /// When a ranking is supplied its prefix is pre-seeded hot; otherwise
    /// the cache starts cold and (if enabled) warms through promotion.
    pub fn new(rows: usize, row_bytes: u64, sys: &SystemProfile, cfg: &TierConfig) -> TieredCache {
        Self::with_row_basis(rows, rows, row_bytes, sys, cfg)
    }

    /// Like [`TieredCache::new`], but `hot_frac` (and the GPU-memory
    /// budget) apply to `basis_rows` instead of the full table — the
    /// sharded store builds one cache per GPU this way, with `basis_rows`
    /// set to that GPU's shard size while membership/frequency vectors
    /// still span the whole table (row ids stay global).
    ///
    /// `basis_rows == rows` reproduces [`TieredCache::new`] exactly.
    pub fn with_row_basis(
        rows: usize,
        basis_rows: usize,
        row_bytes: u64,
        sys: &SystemProfile,
        cfg: &TierConfig,
    ) -> TieredCache {
        let budget_bytes = sys.gpu_mem_bytes.saturating_sub(cfg.reserve_bytes);
        let budget_rows = if row_bytes == 0 {
            0
        } else {
            (budget_bytes / row_bytes).min(basis_rows as u64) as usize
        };
        let target_rows = (cfg.hot_frac.clamp(0.0, 1.0) * basis_rows as f64).floor() as usize;
        let capacity_rows = target_rows.min(budget_rows);
        let mut cache = TieredCache {
            hot: vec![false; rows],
            freq: vec![0; rows],
            heap: BinaryHeap::new(),
            hot_rows: 0,
            capacity_rows,
            row_bytes,
            promote: cfg.promote,
            hits: 0,
            misses: 0,
            promotions: 0,
            evictions: 0,
        };
        if let Some(ranking) = &cfg.ranking {
            for v in crate::featurestore::placement::ranked_prefix(rows, capacity_rows, ranking) {
                cache.insert_hot(v);
            }
        }
        cache
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    pub fn hot_rows(&self) -> usize {
        self.hot_rows
    }

    pub fn is_hot(&self, row: u32) -> bool {
        self.hot[row as usize]
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits,
            misses: self.misses,
            promotions: self.promotions,
            evictions: self.evictions,
            hot_rows: self.hot_rows,
            hot_bytes: self.hot_rows as u64 * self.row_bytes,
            capacity_rows: self.capacity_rows,
            capacity_bytes: self.capacity_rows as u64 * self.row_bytes,
        }
    }

    /// Account one gather: splits `idx` into hits and the returned cold
    /// subset (original order preserved — the cold rows form the PCIe
    /// request stream), bumps LFU frequencies, then applies promotions.
    ///
    /// Promotion runs *after* the split on purpose: the batch that first
    /// touches a row still pays its cold cost; only later batches benefit.
    ///
    /// Under the default gather deduplication (DESIGN.md §10) `idx` is
    /// already the batch's *compacted* unique stream, so hits/misses and
    /// LFU frequencies count each distinct row once per batch; with
    /// `--no-dedup` every duplicated occurrence counts, as before.
    pub fn record(&mut self, idx: &[u32]) -> Vec<u32> {
        let mut cold = Vec::new();
        for &r in idx {
            let ri = r as usize;
            self.freq[ri] += 1;
            if self.hot[ri] {
                self.hits += 1;
            } else {
                self.misses += 1;
                cold.push(r);
            }
        }
        if self.promote && self.capacity_rows > 0 && !cold.is_empty() {
            let mut candidates = cold.clone();
            candidates.sort_unstable();
            candidates.dedup();
            for r in candidates {
                self.maybe_promote(r);
            }
        }
        cold
    }

    fn maybe_promote(&mut self, r: u32) {
        if self.hot[r as usize] {
            return;
        }
        if self.hot_rows < self.capacity_rows {
            self.insert_hot(r);
            self.promotions += 1;
            return;
        }
        match self.refresh_min() {
            Some((min_freq, _)) if self.freq[r as usize] > min_freq => {
                self.evict_min();
                self.insert_hot(r);
                self.promotions += 1;
            }
            _ => {}
        }
    }

    fn insert_hot(&mut self, r: u32) {
        debug_assert!(!self.hot[r as usize]);
        self.hot[r as usize] = true;
        self.hot_rows += 1;
        self.heap.push(Reverse((self.freq[r as usize], r)));
    }

    /// Make the heap top a valid `(current_freq, hot_row)` minimum, fixing
    /// stale entries (evicted rows, outdated frequencies) along the way.
    fn refresh_min(&mut self) -> Option<(u64, u32)> {
        while let Some(&Reverse((f, row))) = self.heap.peek() {
            if !self.hot[row as usize] {
                self.heap.pop(); // row was evicted; duplicate entry
                continue;
            }
            let current = self.freq[row as usize];
            if current != f {
                self.heap.pop();
                self.heap.push(Reverse((current, row)));
                continue;
            }
            return Some((f, row));
        }
        None
    }

    fn evict_min(&mut self) {
        if let Some((_, row)) = self.refresh_min() {
            self.heap.pop();
            self.hot[row as usize] = false;
            self.hot_rows -= 1;
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    fn cfg(hot_frac: f64, promote: bool, ranking: Option<Vec<u32>>) -> TierConfig {
        TierConfig {
            hot_frac,
            reserve_bytes: 0,
            promote,
            ranking,
        }
    }

    #[test]
    fn capacity_is_min_of_frac_and_budget() {
        // 100 rows of 1 KiB; hot_frac 0.5 -> 50 rows unless budget is lower.
        let c = TieredCache::new(100, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 50);

        let mut small = sys();
        small.gpu_mem_bytes = 10 * 1024; // room for 10 rows
        let c = TieredCache::new(100, 1024, &small, &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 10);
    }

    #[test]
    fn row_basis_scales_capacity_to_the_shard() {
        // 100-row table, but hot_frac applies to a 40-row shard.
        let c = TieredCache::with_row_basis(100, 40, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(c.capacity_rows(), 20);
        // basis == rows reproduces `new` exactly.
        let a = TieredCache::new(100, 1024, &sys(), &cfg(0.5, false, None));
        let b = TieredCache::with_row_basis(100, 100, 1024, &sys(), &cfg(0.5, false, None));
        assert_eq!(a.capacity_rows(), b.capacity_rows());
    }

    #[test]
    fn reserve_shrinks_budget() {
        let mut s = sys();
        s.gpu_mem_bytes = 20 * 1024;
        let mut tc = cfg(1.0, false, Some((0..100).collect()));
        tc.reserve_bytes = 10 * 1024;
        let c = TieredCache::new(100, 1024, &s, &tc);
        assert_eq!(c.capacity_rows(), 10);
        assert_eq!(c.stats().hot_bytes, 10 * 1024);
        assert!(c.stats().hot_bytes <= s.gpu_mem_bytes - tc.reserve_bytes);
    }

    #[test]
    fn ranking_prefix_preseeds_hot() {
        let ranking = vec![7u32, 3, 9, 1];
        let c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(ranking)));
        assert_eq!(c.capacity_rows(), 2);
        assert!(c.is_hot(7) && c.is_hot(3));
        assert!(!c.is_hot(9) && !c.is_hot(1));
    }

    #[test]
    fn record_splits_hits_and_misses() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let cold = c.record(&[0, 5, 1, 5, 9]);
        assert_eq!(cold, vec![5, 5, 9]);
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 3);
        assert_eq!(s.hits + s.misses, 5);
    }

    #[test]
    fn compacted_stream_counts_each_distinct_row_once() {
        // The dedup subsystem hands `record` the unique stream: the cold
        // subset (and with it the whole PCIe request stream) shrinks from
        // per-occurrence to per-distinct-row.
        let duplicated = [5u32, 9, 5, 5, 9, 0];
        let compacted = crate::sampler::compact::GatherPlan::build(&duplicated);
        let mut dup = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let mut ded = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        let cold_dup = dup.record(&duplicated);
        let cold_ded = ded.record(compacted.unique_nodes());
        assert_eq!(cold_dup, vec![5, 9, 5, 5, 9]);
        assert_eq!(cold_ded, vec![5, 9], "compacted cold stream must be distinct");
        assert_eq!(ded.stats().hits + ded.stats().misses, 3);
        assert_eq!(dup.stats().hits + dup.stats().misses, 6);
    }

    #[test]
    fn zero_frac_means_everything_cold() {
        let mut c = TieredCache::new(50, 8, &sys(), &cfg(0.0, true, Some((0..50).collect())));
        for _ in 0..5 {
            let cold = c.record(&[1, 2, 3]);
            assert_eq!(cold.len(), 3);
        }
        assert_eq!(c.stats().hits, 0);
        assert_eq!(c.stats().hot_rows, 0);
    }

    #[test]
    fn cold_start_warms_through_promotion() {
        let mut c = TieredCache::new(100, 4, &sys(), &cfg(0.1, true, None));
        assert_eq!(c.hot_rows(), 0);
        let idx = [4u32, 8, 15, 16, 23, 42];
        let first = c.record(&idx);
        assert_eq!(first.len(), idx.len()); // cold epoch pays full cost
        let second = c.record(&idx);
        assert!(second.len() < idx.len(), "promotion never warmed the cache");
        assert!(c.stats().promotions > 0);
        assert!(c.hot_rows() <= c.capacity_rows());
    }

    #[test]
    fn promotion_respects_capacity_and_evicts_lfu() {
        // capacity 2; rows 1,2 get hot; then row 3 becomes more frequent
        // than row 1 and displaces the LFU minimum.
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, true, None));
        c.record(&[1, 2]); // both promoted (capacity free)
        assert!(c.is_hot(1) && c.is_hot(2));
        c.record(&[2]); // freq: r1=1, r2=2
        for _ in 0..3 {
            c.record(&[3]); // freq r3 grows past r1
        }
        assert!(c.is_hot(3), "hotter row was not promoted");
        assert!(!c.is_hot(1), "LFU minimum was not evicted");
        assert_eq!(c.hot_rows(), 2);
        assert!(c.stats().evictions >= 1);
    }

    #[test]
    fn promotion_disabled_keeps_static_placement() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        for _ in 0..10 {
            c.record(&[5, 6, 7]);
        }
        assert!(c.is_hot(0) && c.is_hot(1));
        assert!(!c.is_hot(5) && !c.is_hot(6) && !c.is_hot(7));
        assert_eq!(c.stats().promotions, 0);
    }

    #[test]
    fn stats_since_gives_epoch_deltas() {
        let mut c = TieredCache::new(10, 4, &sys(), &cfg(0.2, false, Some(vec![0, 1])));
        c.record(&[0, 5]);
        let snap = c.stats();
        c.record(&[0, 1, 5]);
        let delta = c.stats().since(&snap);
        assert_eq!(delta.hits, 2);
        assert_eq!(delta.misses, 1);
        assert!((delta.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn degree_ranking_orders_by_degree_then_id() {
        let g = Csr::from_edges(4, &[(2, 0), (2, 1), (2, 3), (0, 1), (0, 2), (1, 0)]).unwrap();
        // degrees: 0 -> 2, 1 -> 1, 2 -> 3, 3 -> 0
        assert_eq!(degree_ranking(&g), vec![2, 0, 1, 3]);
    }
}

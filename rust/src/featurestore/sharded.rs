//! Multi-GPU sharded feature store: per-GPU hot tiers over one table,
//! peers linked by NVLink (DESIGN.md §6).
//!
//! The multi-GPU follow-up to PyTorch-Direct ("Large Graph Convolutional
//! Network Training with GPU-Oriented Data Communication Architecture",
//! arXiv:2103.03330) partitions the feature table across the GPUs of one
//! node: every GPU pins the hottest rows *of its shard* in device memory,
//! reads peer-owned hot rows directly over NVLink, and falls back to the
//! host unified zero-copy path for rows that are cold everywhere.  GIDS
//! (arXiv:2306.16384) ships the same split in production.
//!
//! This module is placement metadata only — like [`TieredCache`], it never
//! copies feature values.  The single unified table remains the source of
//! truth, so numerics are bitwise identical across every access mode by
//! construction; sharding changes exclusively the [`TransferCost`]
//! attribution.  Each simulated training step is data-parallel: the batch
//! is split into `num_gpus` contiguous sub-batches, each GPU resolves its
//! sub-batch against the three paths of the cost matrix (DESIGN.md §4):
//!
//! | path   | condition                           | cost model              |
//! |--------|-------------------------------------|-------------------------|
//! | local  | row hot in the requester's tier     | kernel launch only      |
//! | peer   | row hot in another GPU's tier       | [`NvlinkLink`] zero-copy|
//! | host   | row cold in its owner's tier        | [`PcieLink`] zero-copy  |
//! | remote | row homed on another host           | [`NetLink`] RPC fetch   |
//!
//! The remote path only exists with `--num-hosts > 1` under the
//! `RemoteFetch` strategy (DESIGN.md §15): the table is partitioned a
//! second time at *host* granularity with the same placement policy, the
//! trainer models host 0's perspective, and foreign-homed rows arrive as
//! batched per-home RPCs over the network link.  `PartitionLocal` instead
//! replicates the halo on every host — foreign-homed rows classify through
//! the normal local/peer/host matrix (counted as `halo_rows`), zero bytes
//! touch the NIC, and the gather cost is bitwise the `--num-hosts 1` cost.
//!
//! and the step's transfer time is the *maximum* over GPUs (they run
//! concurrently; the epoch-level spread is surfaced as the load-imbalance
//! factor in [`ShardStats`]).  With `num_gpus = 1` every row is
//! requester-owned, no peer traffic exists, and the arithmetic degenerates
//! bit-exactly to the single-GPU [`tiered`](crate::featurestore::tiered)
//! cost model — pinned by `benches/sharding_sweep.rs` and
//! `tests/sharded_properties.rs`.
//!
//! [`TransferCost`]: crate::interconnect::TransferCost
//! [`NvlinkLink`]: crate::interconnect::NvlinkLink
//! [`PcieLink`]: crate::interconnect::PcieLink
//! [`NetLink`]: crate::interconnect::NetLink

use crate::config::{FetchStrategy, RunConfig, ShardPolicy, SystemProfile};
use crate::device::warp::{count_requests, GatherTraffic, WarpModel};
use crate::featurestore::placement;
use crate::featurestore::tiered::{TierConfig, TierStats, TieredCache};
use crate::graph::Csr;
use crate::interconnect::{NetLink, NvlinkLink, PathSplit, PcieLink, TransferCost};

/// Placement + capacity knobs for the sharded store.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of simulated GPUs the table is partitioned across.
    pub num_gpus: usize,
    /// Number of simulated *hosts* the table is partitioned across above
    /// the GPU layer (`--num-hosts`, DESIGN.md §15).  The trainer models
    /// host 0's perspective; rows homed elsewhere are reached per
    /// `fetch_strategy`.  1 = the single-node model, bit-exactly.
    pub num_hosts: usize,
    /// Row-to-shard placement policy (reused at host granularity for the
    /// host partition, so both layers split the table the same way).
    pub policy: ShardPolicy,
    /// How rows homed on other hosts are reached when `num_hosts > 1`.
    pub fetch_strategy: FetchStrategy,
    /// Per-GPU hot-tier knobs (`hot_frac` applies to each *shard*, so the
    /// aggregate hot set stays a `hot_frac` share of the whole table); the
    /// ranking is the global one — each GPU seeds from its shard's slice.
    pub tier: TierConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            num_gpus: 1,
            num_hosts: 1,
            policy: ShardPolicy::Hash,
            fetch_strategy: FetchStrategy::RemoteFetch,
            tier: TierConfig::default(),
        }
    }
}

impl ShardConfig {
    /// Derive the shard configuration a training run wants: the run's
    /// `num_gpus`/`num_hosts`/`shard_policy`/`fetch_strategy` knobs plus
    /// the tier knobs (degree ranking from the graph, `hot_frac`, reserve,
    /// promotion).
    pub fn from_run(cfg: &RunConfig, graph: &Csr) -> ShardConfig {
        ShardConfig {
            num_gpus: cfg.num_gpus as usize,
            num_hosts: cfg.num_hosts as usize,
            policy: cfg.shard_policy,
            fetch_strategy: cfg.fetch_strategy,
            tier: TierConfig::from_run(cfg, graph),
        }
    }
}

/// Assign every row to exactly one owner GPU (`< num_gpus`).
///
/// `ranking` (hottest-first) is only consulted by [`ShardPolicy::Degree`];
/// rows a short ranking misses keep a round-robin fallback so coverage is
/// total for any input.
pub fn assign_owners(
    rows: usize,
    num_gpus: usize,
    policy: ShardPolicy,
    ranking: Option<&[u32]>,
) -> Vec<u8> {
    let n = num_gpus.clamp(1, 255);
    match policy {
        ShardPolicy::Hash => (0..rows)
            .map(|r| (((r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) % n as u64) as u8)
            .collect(),
        ShardPolicy::Degree => {
            // Round-robin over the ranking: every shard gets an equal slice
            // of the hottest rows.  Id round-robin is the coverage fallback.
            let mut owner: Vec<u8> = (0..rows).map(|r| (r % n) as u8).collect();
            if let Some(rk) = ranking {
                for (i, &r) in rk.iter().enumerate() {
                    if (r as usize) < rows {
                        owner[r as usize] = (i % n) as u8;
                    }
                }
            }
            owner
        }
        ShardPolicy::Contig => {
            let chunk = rows.div_ceil(n).max(1);
            (0..rows).map(|r| (r / chunk) as u8).collect()
        }
    }
}

/// Per-GPU counters (per-epoch deltas via [`GpuShardStats::since`]) and
/// end-of-epoch gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GpuShardStats {
    /// Rows this GPU served from its own hot tier.
    pub local_rows: u64,
    /// Rows this GPU fetched from peer hot tiers over NVLink.
    pub peer_rows: u64,
    /// Rows this GPU fetched from host memory over the host link.
    pub host_rows: u64,
    /// Rows this GPU fetched from other hosts over the network link
    /// (`RemoteFetch` with `num_hosts > 1`; always 0 otherwise).
    pub remote_rows: u64,
    /// Rows homed on other hosts that this host served from its local
    /// replica (`PartitionLocal` halo; always 0 under `RemoteFetch`).
    pub halo_rows: u64,
    /// Useful bytes per path (rows × row size).
    pub local_bytes: u64,
    pub peer_bytes: u64,
    pub host_bytes: u64,
    pub remote_bytes: u64,
    /// Simulated seconds of NVLink / host-link / network occupancy.
    pub peer_time_s: f64,
    pub host_time_s: f64,
    pub net_time_s: f64,
    /// Simulated seconds this GPU was busy in gather steps (the per-step
    /// maximum of its path times; the step barrier waits on the slowest
    /// GPU, so `max(busy) / mean(busy)` is the load-imbalance factor).
    pub busy_s: f64,
    /// Rows of the table this GPU owns (gauge).
    pub shard_rows: usize,
    /// Hot-tier occupancy/capacity gauges (mirrors [`TierStats`]).
    pub hot_rows: usize,
    pub capacity_rows: usize,
    pub hot_bytes: u64,
    pub capacity_bytes: u64,
}

impl GpuShardStats {
    /// Rows this GPU requested, across all paths.
    pub fn rows_served(&self) -> u64 {
        self.local_rows + self.peer_rows + self.host_rows + self.remote_rows
    }

    /// Counter deltas relative to an `earlier` snapshot; gauges keep their
    /// current (end-state) values.
    pub fn since(&self, earlier: &GpuShardStats) -> GpuShardStats {
        GpuShardStats {
            local_rows: self.local_rows - earlier.local_rows,
            peer_rows: self.peer_rows - earlier.peer_rows,
            host_rows: self.host_rows - earlier.host_rows,
            remote_rows: self.remote_rows - earlier.remote_rows,
            halo_rows: self.halo_rows - earlier.halo_rows,
            local_bytes: self.local_bytes - earlier.local_bytes,
            peer_bytes: self.peer_bytes - earlier.peer_bytes,
            host_bytes: self.host_bytes - earlier.host_bytes,
            remote_bytes: self.remote_bytes - earlier.remote_bytes,
            peer_time_s: self.peer_time_s - earlier.peer_time_s,
            host_time_s: self.host_time_s - earlier.host_time_s,
            net_time_s: self.net_time_s - earlier.net_time_s,
            busy_s: self.busy_s - earlier.busy_s,
            ..*self
        }
    }
}

/// All-GPU view of one sharded store (or one epoch of it, via
/// [`ShardStats::since`]).
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub policy: ShardPolicy,
    pub per_gpu: Vec<GpuShardStats>,
}

impl ShardStats {
    pub fn num_gpus(&self) -> usize {
        self.per_gpu.len()
    }

    /// Per-GPU counter deltas relative to an `earlier` snapshot.
    pub fn since(&self, earlier: &ShardStats) -> ShardStats {
        ShardStats {
            policy: self.policy,
            per_gpu: self
                .per_gpu
                .iter()
                .zip(&earlier.per_gpu)
                .map(|(now, then)| now.since(then))
                .collect(),
        }
    }

    /// Sum across GPUs (gauges sum too: aggregate hot set / capacity).
    pub fn totals(&self) -> GpuShardStats {
        let mut t = GpuShardStats::default();
        for g in &self.per_gpu {
            t.local_rows += g.local_rows;
            t.peer_rows += g.peer_rows;
            t.host_rows += g.host_rows;
            t.remote_rows += g.remote_rows;
            t.halo_rows += g.halo_rows;
            t.local_bytes += g.local_bytes;
            t.peer_bytes += g.peer_bytes;
            t.host_bytes += g.host_bytes;
            t.remote_bytes += g.remote_bytes;
            t.peer_time_s += g.peer_time_s;
            t.host_time_s += g.host_time_s;
            t.net_time_s += g.net_time_s;
            t.busy_s += g.busy_s;
            t.shard_rows += g.shard_rows;
            t.hot_rows += g.hot_rows;
            t.capacity_rows += g.capacity_rows;
            t.hot_bytes += g.hot_bytes;
            t.capacity_bytes += g.capacity_bytes;
        }
        t
    }

    /// Load-imbalance factor: slowest GPU's busy time over the mean
    /// (1.0 = perfectly balanced; the step barrier always waits on the
    /// max, so epoch time scales with this factor).
    pub fn load_imbalance(&self) -> f64 {
        let max = self.per_gpu.iter().map(|g| g.busy_s).fold(0.0, f64::max);
        let mean = self.per_gpu.iter().map(|g| g.busy_s).sum::<f64>()
            / self.per_gpu.len().max(1) as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }
}

/// Placement metadata + per-GPU tier machinery for one feature table.
#[derive(Debug)]
pub struct ShardedStore {
    /// Per-row owner GPU.
    owner: Vec<u8>,
    /// Per-row home *host* (`--num-hosts`): the same placement policy
    /// applied at host granularity.  All-zero when `num_hosts == 1`, so
    /// the single-node arithmetic is untouched by construction.
    host_owner: Vec<u8>,
    /// One hot tier per GPU, over that GPU's shard.  Row ids stay global,
    /// so each tier's membership/frequency vectors span the whole table —
    /// O(num_gpus × rows) metadata, ~9 bytes × rows per GPU.  Deliberate:
    /// global ids keep the N=1 path running the *identical* arithmetic to
    /// the single-GPU tiered store (the bit-exact degeneracy contract),
    /// and at this testbed's scaled table sizes the overhead is megabytes.
    /// Shard-local ids (plus a translation map) are the fix if tables grow.
    tiers: Vec<TieredCache>,
    policy: ShardPolicy,
    num_gpus: usize,
    num_hosts: usize,
    fetch_strategy: FetchStrategy,
    row_bytes: u64,
    /// Per-GPU cumulative counters (gauges are derived from `tiers`).
    acc: Vec<GpuShardStats>,
}

impl ShardedStore {
    /// Build placement + per-GPU tiers for a `rows`-row table of
    /// `row_bytes`-byte rows.
    ///
    /// Each GPU's tier capacity is `min(hot_frac · shard_rows,
    /// (gpu_mem − reserve) / row_bytes)` — `hot_frac` scales with the
    /// shard, so the aggregate hot set tracks the single-GPU tiered
    /// configuration whatever `num_gpus` is.
    pub fn new(
        rows: usize,
        row_bytes: u64,
        sys: &SystemProfile,
        cfg: &ShardConfig,
    ) -> ShardedStore {
        let n = cfg.num_gpus.clamp(1, 255);
        let owner = assign_owners(rows, n, cfg.policy, cfg.tier.ranking.as_deref());
        let hosts = cfg.num_hosts.clamp(1, 255);
        let host_owner = assign_owners(rows, hosts, cfg.policy, cfg.tier.ranking.as_deref());
        let mut shard_rows = vec![0usize; n];
        for &o in &owner {
            shard_rows[o as usize] += 1;
        }
        let tiers: Vec<TieredCache> = (0..n)
            .map(|g| {
                // This GPU seeds from the global ranking restricted to its
                // shard, so the hottest owned rows go hot first.
                let ranking = cfg
                    .tier
                    .ranking
                    .as_ref()
                    .map(|rk| placement::shard_slice(rows, rk, &owner, g as u8));
                let tier_cfg = TierConfig {
                    hot_frac: cfg.tier.hot_frac,
                    reserve_bytes: cfg.tier.reserve_bytes,
                    promote: cfg.tier.promote,
                    ranking,
                    page_rows: cfg.tier.page_rows,
                    eviction: cfg.tier.eviction,
                };
                TieredCache::with_row_basis(rows, shard_rows[g], row_bytes, sys, &tier_cfg)
            })
            .collect();
        let acc = (0..n)
            .map(|g| GpuShardStats {
                shard_rows: shard_rows[g],
                ..GpuShardStats::default()
            })
            .collect();
        ShardedStore {
            owner,
            host_owner,
            tiers,
            policy: cfg.policy,
            num_gpus: n,
            num_hosts: hosts,
            fetch_strategy: cfg.fetch_strategy,
            row_bytes,
            acc,
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.num_gpus
    }

    pub fn num_hosts(&self) -> usize {
        self.num_hosts
    }

    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    pub fn fetch_strategy(&self) -> FetchStrategy {
        self.fetch_strategy
    }

    /// Owner GPU of a row.
    pub fn owner_of(&self, row: u32) -> usize {
        self.owner[row as usize] as usize
    }

    /// Home host of a row (0 when `num_hosts == 1`).
    pub fn host_of(&self, row: u32) -> usize {
        self.host_owner[row as usize] as usize
    }

    /// Whether this row must travel the network under the configured
    /// fetch strategy: homed on a host other than the trainer's (host 0)
    /// with `RemoteFetch`.  `PartitionLocal` replicates the halo locally,
    /// so nothing is ever remote.
    pub fn is_remote(&self, row: u32) -> bool {
        self.fetch_strategy == FetchStrategy::RemoteFetch && self.host_owner[row as usize] != 0
    }

    /// Whether `row` currently sits in its owner GPU's hot tier — the
    /// read-only pre-step residency view [`ShardedStore::gather_cost`]
    /// classifies against before recording.  The push-down classifier
    /// (`FeatureStore::pushdown_cost`, DESIGN.md §14) uses it to replicate
    /// that classification without mutating tier state.
    pub fn is_hot_in_owner(&self, row: u32) -> bool {
        self.tiers[self.owner[row as usize] as usize].is_hot(row)
    }

    /// One GPU's hot-tier counters/gauges.
    pub fn tier_stats(&self, gpu: usize) -> TierStats {
        self.tiers[gpu].stats()
    }

    /// Pin the pages covering `idx` in each row's *owner* tier, so an
    /// in-flight gather's pages survive concurrent admissions; pair with
    /// [`ShardedStore::unpin_rows`].
    pub fn pin_rows(&mut self, idx: &[u32]) {
        self.route_pins(idx, true);
    }

    /// Release the pins [`ShardedStore::pin_rows`] took.
    pub fn unpin_rows(&mut self, idx: &[u32]) {
        self.route_pins(idx, false);
    }

    fn route_pins(&mut self, idx: &[u32], pin: bool) {
        let mut per_owner: Vec<Vec<u32>> = vec![Vec::new(); self.num_gpus];
        for &r in idx {
            per_owner[self.owner[r as usize] as usize].push(r);
        }
        for (o, rows) in per_owner.iter().enumerate() {
            if !rows.is_empty() {
                if pin {
                    self.tiers[o].pin_rows(rows);
                } else {
                    self.tiers[o].unpin_rows(rows);
                }
            }
        }
    }

    /// Snapshot of per-GPU counters + gauges.
    pub fn stats(&self) -> ShardStats {
        let per_gpu = self
            .acc
            .iter()
            .zip(&self.tiers)
            .map(|(acc, tier)| {
                let ts = tier.stats();
                GpuShardStats {
                    hot_rows: ts.hot_rows,
                    capacity_rows: ts.capacity_rows,
                    hot_bytes: ts.hot_bytes,
                    capacity_bytes: ts.capacity_bytes,
                    ..*acc
                }
            })
            .collect();
        ShardStats {
            policy: self.policy,
            per_gpu,
        }
    }

    /// Account one data-parallel gather step and return its simulated cost.
    ///
    /// The batch is split into `num_gpus` contiguous sub-batches; each GPU
    /// classifies its rows against the owners' hot tiers (local / peer /
    /// host, order preserved per stream — the streams are the warp request
    /// sequences the link models coalesce), then every owner tier records
    /// its share of the *whole* batch once, so LFU frequencies and
    /// promotions are step-granular exactly like the single-GPU tiered
    /// store.  Step time is the max over GPUs; per-GPU occupancy lands in
    /// the accumulators behind [`ShardedStore::stats`].
    ///
    /// Under the default gather deduplication (DESIGN.md §10) `idx` is
    /// the batch's compacted unique stream: the per-GPU sub-batches, the
    /// per-owner peer streams, and the host fallback then all price
    /// distinct rows only — duplicate hub rows stop multiplying NVLink
    /// and PCIe traffic.  `--no-dedup` hands in the raw duplicated
    /// stream, as before.
    pub fn gather_cost(
        &mut self,
        idx: &[u32],
        feat_elems: u64,
        sys: &SystemProfile,
    ) -> TransferCost {
        let n = self.num_gpus;
        // Recover the storage precision from the constructor's row width
        // (row_bytes / feat_elems): fp32 rows reproduce the default model
        // bit-exactly; fp16/int8 rows (DESIGN.md §13) narrow every NVLink
        // and PCIe byte priced below.
        let model = WarpModel::for_row_layout(self.row_bytes, feat_elems);
        let shifted = model.shift_applies(feat_elems);
        let pcie = PcieLink::new(sys);
        let nvlink = NvlinkLink::new(sys);
        let net = NetLink::new(sys);
        let row_bytes = self.row_bytes;

        let mut per_owner: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut peer_by_owner: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut split = PathSplit::default();
        let mut step_time = 0.0f64;
        let mut link_bytes = 0u64;
        let mut requests = 0u64;
        let mut host = Vec::new();
        let mut remote = Vec::new();
        let mut hosts_seen = vec![false; self.num_hosts];

        for g in 0..n {
            let chunk = &idx[g * idx.len() / n..(g + 1) * idx.len() / n];
            let mut local_rows = 0u64;
            let mut halo_rows = 0u64;
            host.clear();
            remote.clear();
            for v in &mut peer_by_owner {
                v.clear();
            }
            for &r in chunk {
                // Host layer first (DESIGN.md §15): under `RemoteFetch` a
                // row homed elsewhere never touches this host's tiers —
                // it arrives over the NIC; under `PartitionLocal` the halo
                // is replicated here and classifies like any local row.
                if self.is_remote(r) {
                    remote.push(r);
                    continue;
                }
                if self.host_owner[r as usize] != 0 {
                    halo_rows += 1;
                }
                let o = self.owner[r as usize] as usize;
                per_owner[o].push(r);
                if self.tiers[o].is_hot(r) {
                    if o == g {
                        local_rows += 1;
                    } else {
                        peer_by_owner[o].push(r);
                    }
                } else {
                    host.push(r);
                }
            }
            // Every GPU joins the step (data-parallel barrier), so each
            // pays at least its gather-kernel launch.
            let mut time_g = sys.kernel_launch_s;
            // Peer reads are pairwise streams: a cacheline never spans two
            // GPUs' memories, so request coalescing is counted per owner;
            // the summed traffic then shares the requester's single NVLink
            // ingress budget (the NvlinkConfig bandwidth).
            let mut peer_traffic = GatherTraffic::default();
            let mut peer_rows = 0u64;
            for rows_o in &peer_by_owner {
                if rows_o.is_empty() {
                    continue;
                }
                peer_rows += rows_o.len() as u64;
                let t = count_requests(rows_o, feat_elems, model, shifted);
                peer_traffic.requests += t.requests;
                peer_traffic.cachelines += t.cachelines;
                peer_traffic.bytes_moved += t.bytes_moved;
                peer_traffic.useful_bytes += t.useful_bytes;
            }
            if peer_rows > 0 {
                let c = nvlink.peer_gather(&peer_traffic);
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.peer_bytes += c.useful_bytes;
                split.peer_bytes_on_link += c.split.peer_bytes_on_link;
                // Occupancy accumulators take the launch-free link time
                // (c.split.*_time_s): one gather kernel serves the whole
                // step, so its launch is charged once via time_g, not per
                // path.
                split.peer_time_s += c.split.peer_time_s;
                self.acc[g].peer_time_s += c.split.peer_time_s;
            }
            if !host.is_empty() {
                let c = pcie.direct_gather(&count_requests(&host, feat_elems, model, shifted));
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.host_bytes += c.useful_bytes;
                split.host_bytes_on_link += c.split.host_bytes_on_link;
                split.host_time_s += c.split.host_time_s;
                self.acc[g].host_time_s += c.split.host_time_s;
            }
            if !remote.is_empty() {
                // Batched per-host RPCs: one message per distinct remote
                // home, each carrying that home's rows for this GPU.
                for s in &mut hosts_seen {
                    *s = false;
                }
                let mut messages = 0u64;
                for &r in &remote {
                    let h = self.host_owner[r as usize] as usize;
                    if !hosts_seen[h] {
                        hosts_seen[h] = true;
                        messages += 1;
                    }
                }
                let c = net.fetch(remote.len() as u64 * row_bytes, messages);
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.net_bytes += c.split.net_bytes;
                split.net_bytes_on_link += c.split.net_bytes_on_link;
                split.net_time_s += c.split.net_time_s;
                self.acc[g].net_time_s += c.split.net_time_s;
            }
            split.local_bytes += local_rows * row_bytes;
            let a = &mut self.acc[g];
            a.local_rows += local_rows;
            a.peer_rows += peer_rows;
            a.host_rows += host.len() as u64;
            a.remote_rows += remote.len() as u64;
            a.halo_rows += halo_rows;
            a.local_bytes += local_rows * row_bytes;
            a.peer_bytes += peer_rows * row_bytes;
            a.host_bytes += host.len() as u64 * row_bytes;
            a.remote_bytes += remote.len() as u64 * row_bytes;
            a.busy_s += time_g;
            step_time = step_time.max(time_g);
        }

        // LFU accounting + promotion, once per owner over its slice of the
        // whole batch (classification above used the pre-step tier state).
        for (o, rows) in per_owner.iter().enumerate() {
            if !rows.is_empty() {
                let _ = self.tiers[o].record(rows);
            }
        }

        TransferCost {
            time_s: step_time,
            bytes_on_link: link_bytes,
            useful_bytes: idx.len() as u64 * row_bytes,
            requests,
            cpu_time_s: 0.0,
            split,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    fn shard_cfg(n: usize, policy: ShardPolicy, hot_frac: f64) -> ShardConfig {
        ShardConfig {
            num_gpus: n,
            policy,
            tier: TierConfig {
                hot_frac,
                promote: false,
                ranking: Some((0..1000).collect()),
                ..TierConfig::default()
            },
        }
    }

    #[test]
    fn every_policy_covers_every_row() {
        for policy in ShardPolicy::all() {
            for n in [1usize, 2, 3, 8] {
                let owner = assign_owners(1000, n, policy, Some(&(0..1000).collect::<Vec<_>>()));
                assert_eq!(owner.len(), 1000);
                assert!(owner.iter().all(|&o| (o as usize) < n), "{policy:?} n={n}");
            }
        }
    }

    #[test]
    fn n1_owns_everything_on_gpu0() {
        for policy in ShardPolicy::all() {
            let owner = assign_owners(500, 1, policy, None);
            assert!(owner.iter().all(|&o| o == 0), "{policy:?}");
        }
    }

    #[test]
    fn degree_policy_spreads_ranking_round_robin() {
        let ranking: Vec<u32> = vec![9, 3, 7, 1]; // hottest first
        let owner = assign_owners(10, 2, ShardPolicy::Degree, Some(&ranking));
        assert_eq!(owner[9], 0);
        assert_eq!(owner[3], 1);
        assert_eq!(owner[7], 0);
        assert_eq!(owner[1], 1);
        // unranked rows keep the round-robin fallback
        assert_eq!(owner[0], 0);
        assert_eq!(owner[5], 1);
    }

    #[test]
    fn contig_policy_is_nondecreasing_ranges() {
        let owner = assign_owners(10, 3, ShardPolicy::Contig, None);
        assert!(owner.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(owner[0], 0);
        assert_eq!(owner[9], 2);
    }

    #[test]
    fn rows_split_across_paths_add_up() {
        let mut st = ShardedStore::new(1000, 64, &sys(), &shard_cfg(4, ShardPolicy::Hash, 0.3));
        let idx: Vec<u32> = (0..600u32).map(|i| i * 7 % 1000).collect();
        st.gather_cost(&idx, 16, &sys());
        let totals = st.stats().totals();
        assert_eq!(totals.rows_served(), 600);
        assert!(totals.local_rows > 0, "some rows must be requester-local");
        assert!(totals.peer_rows > 0, "a 4-way shard must see peer traffic");
        assert!(totals.host_rows > 0, "a 30% hot set must miss sometimes");
    }

    #[test]
    fn n1_has_no_peer_traffic_and_matches_tiered_time() {
        let rows = 800usize;
        let dim = 65u64; // misaligned 260 B rows exercise the shift path
        let mut st =
            ShardedStore::new(rows, dim * 4, &sys(), &shard_cfg(1, ShardPolicy::Hash, 0.25));
        let mut tier = TieredCache::new(
            rows,
            dim * 4,
            &sys(),
            &TierConfig {
                hot_frac: 0.25,
                promote: false,
                ranking: Some((0..1000).collect()),
                ..TierConfig::default()
            },
        );
        let idx: Vec<u32> = (0..500u32).map(|i| i * 13 % 800).collect();
        let c = st.gather_cost(&idx, dim, &sys());
        assert_eq!(c.split.peer_bytes, 0);
        assert_eq!(c.split.peer_time_s, 0.0);

        // Reference: the tiered arithmetic on the same cold subset.
        let cold = tier.record(&idx);
        let model = WarpModel::default();
        let want = PcieLink::new(&sys())
            .direct_gather(&count_requests(&cold, dim, model, model.shift_applies(dim)));
        assert_eq!(c.time_s, want.time_s);
        assert_eq!(c.bytes_on_link, want.bytes_on_link);
        assert_eq!(c.requests, want.requests);
    }

    #[test]
    fn peer_requests_are_counted_per_owner_not_merged_across_memories() {
        // 64 B rows, 128 B cachelines: rows 0 and 1 share a *global-table*
        // line, but live in different GPUs' memories under this placement,
        // so their peer reads must cost two requests, never one merged one.
        // Ranking [2, 3, 0, 1, 4, 5, ...] with N=3 degree round-robin
        // gives owners: row2 -> 0, row3 -> 1, row0 -> 2, row1 -> 0, and
        // every later rank i = r falls back to r % 3; the full-table
        // ranking plus hot_frac 1.0 makes every row hot (no host traffic).
        let cfg = ShardConfig {
            num_gpus: 3,
            policy: ShardPolicy::Degree,
            tier: TierConfig {
                hot_frac: 1.0,
                promote: false,
                ranking: Some([2u32, 3, 0, 1].into_iter().chain(4..100).collect()),
                ..TierConfig::default()
            },
        };
        let mut st = ShardedStore::new(100, 64, &sys(), &cfg);
        assert_eq!(st.owner_of(0), 2);
        assert_eq!(st.owner_of(1), 0);
        assert_eq!(st.owner_of(99), 0); // 99 % 3, round-robin fallback
        // Chunks of 2: g0 = [99, 99] (own shard -> local), g1 = [0, 1]
        // (owners 2 and 0 -> two distinct peers), g2 = [99, 99] (peer).
        let c = st.gather_cost(&[99, 99, 0, 1, 99, 99], 16, &sys());
        // g1: one request per owner stream (rows 0 and 1 would merge into
        // one line if miscounted jointly); g2: one request (same row twice).
        assert_eq!(c.requests, 3);
        let totals = st.stats().totals();
        assert_eq!(totals.local_rows, 2);
        assert_eq!(totals.peer_rows, 4);
        assert_eq!(totals.host_rows, 0);
    }

    #[test]
    fn compacted_stream_cuts_peer_and_host_traffic() {
        // A duplicated batch versus its compaction against identical
        // fresh stores: the unique stream must move strictly fewer bytes
        // across NVLink + host links while serving the same distinct rows.
        let duplicated: Vec<u32> = (0..600u32).map(|i| i * 7 % 150).collect();
        let plan = crate::sampler::compact::GatherPlan::build(&duplicated);
        let cfg = shard_cfg(4, ShardPolicy::Degree, 0.3);
        let mut dup_store = ShardedStore::new(1000, 64, &sys(), &cfg);
        let mut ded_store = ShardedStore::new(1000, 64, &sys(), &cfg);
        let c_dup = dup_store.gather_cost(&duplicated, 16, &sys());
        let c_ded = ded_store.gather_cost(plan.unique_nodes(), 16, &sys());
        assert!(
            c_ded.bytes_on_link < c_dup.bytes_on_link,
            "dedup {} !< naive {}",
            c_ded.bytes_on_link,
            c_dup.bytes_on_link
        );
        assert!(c_ded.time_s <= c_dup.time_s);
        assert_eq!(ded_store.stats().totals().rows_served(), 150);
    }

    #[test]
    fn fully_hot_shards_cost_kernel_launch_only() {
        let mut st = ShardedStore::new(200, 64, &sys(), &shard_cfg(1, ShardPolicy::Contig, 1.0));
        let idx: Vec<u32> = (0..200).collect();
        let c = st.gather_cost(&idx, 16, &sys());
        assert_eq!(c.time_s, sys().kernel_launch_s);
        assert_eq!(c.bytes_on_link, 0);
        assert_eq!(c.requests, 0);
        assert_eq!(c.split.local_bytes, c.useful_bytes);
    }

    #[test]
    fn per_gpu_hot_bytes_respect_budget() {
        let mut small = sys();
        small.gpu_mem_bytes = 50 * 64; // room for 50 rows per GPU
        let mut st = ShardedStore::new(1000, 64, &small, &shard_cfg(4, ShardPolicy::Degree, 1.0));
        let idx: Vec<u32> = (0..1000).collect();
        st.gather_cost(&idx, 16, &small);
        for g in st.stats().per_gpu {
            assert!(g.hot_bytes <= g.capacity_bytes);
            assert!(g.capacity_bytes <= small.gpu_mem_bytes);
        }
    }

    #[test]
    fn imbalance_is_one_when_balanced_and_above_for_skew() {
        let balanced = ShardStats {
            policy: ShardPolicy::Hash,
            per_gpu: vec![
                GpuShardStats { busy_s: 2.0, ..Default::default() },
                GpuShardStats { busy_s: 2.0, ..Default::default() },
            ],
        };
        assert!((balanced.load_imbalance() - 1.0).abs() < 1e-12);
        let skewed = ShardStats {
            policy: ShardPolicy::Contig,
            per_gpu: vec![
                GpuShardStats { busy_s: 3.0, ..Default::default() },
                GpuShardStats { busy_s: 1.0, ..Default::default() },
            ],
        };
        assert!((skewed.load_imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn pins_route_to_owner_tiers_and_balance() {
        let mut st = ShardedStore::new(100, 64, &sys(), &shard_cfg(3, ShardPolicy::Contig, 0.5));
        let idx: Vec<u32> = (0..100).collect();
        st.pin_rows(&idx);
        let pinned: u64 = (0..3).map(|g| st.tier_stats(g).pins).sum();
        assert!(pinned > 0);
        // Contig with 100 rows over 3 GPUs: every shard holds rows, so
        // every tier must have taken pins.
        for g in 0..3 {
            assert!(st.tier_stats(g).pins > 0, "gpu {g} got no pins");
        }
        st.unpin_rows(&idx);
        for g in 0..3 {
            let ts = st.tier_stats(g);
            assert_eq!(ts.pins, ts.unpins, "gpu {g} pins unbalanced");
        }
    }

    fn host_cfg(hosts: usize, strategy: FetchStrategy) -> ShardConfig {
        ShardConfig {
            num_hosts: hosts,
            fetch_strategy: strategy,
            ..shard_cfg(2, ShardPolicy::Hash, 0.5)
        }
    }

    #[test]
    fn partition_local_reproduces_the_single_host_cost_bitwise() {
        // Halo replication keeps every row on the local fast paths: the
        // gather arithmetic must be the `num_hosts = 1` arithmetic exactly.
        let idx: Vec<u32> = (0..400u32).map(|i| i * 7 % 1000).collect();
        let mut one = ShardedStore::new(1000, 64, &sys(), &host_cfg(1, FetchStrategy::RemoteFetch));
        let mut halo =
            ShardedStore::new(1000, 64, &sys(), &host_cfg(4, FetchStrategy::PartitionLocal));
        let c1 = one.gather_cost(&idx, 16, &sys());
        let ch = halo.gather_cost(&idx, 16, &sys());
        assert_eq!(c1.time_s.to_bits(), ch.time_s.to_bits());
        assert_eq!(c1.bytes_on_link, ch.bytes_on_link);
        assert_eq!(c1.requests, ch.requests);
        assert_eq!(ch.split.net_bytes, 0);
        assert_eq!(ch.split.net_time_s, 0.0);
        let t = halo.stats().totals();
        assert!(t.halo_rows > 0, "a 4-host partition must home rows elsewhere");
        assert_eq!(t.remote_rows, 0);
    }

    #[test]
    fn remote_fetch_routes_foreign_rows_over_the_network() {
        let idx: Vec<u32> = (0..400u32).map(|i| i * 7 % 1000).collect();
        let mut st =
            ShardedStore::new(1000, 64, &sys(), &host_cfg(4, FetchStrategy::RemoteFetch));
        let c = st.gather_cost(&idx, 16, &sys());
        assert!(c.split.net_bytes > 0, "3/4 of the table is foreign-homed");
        assert!(c.split.net_time_s > 0.0);
        let t = st.stats().totals();
        assert!(t.remote_rows > 0);
        assert_eq!(t.halo_rows, 0, "RemoteFetch never replicates");
        assert_eq!(t.rows_served(), 400);
        // RPC payloads ride the wire unamplified: useful == on-link.
        assert_eq!(c.split.net_bytes_on_link, t.remote_bytes);
        assert!(t.net_time_s > 0.0);
    }

    #[test]
    fn net_bytes_grow_monotonically_with_the_host_count() {
        // Host-0-local sets are nested as the host count doubles under
        // every policy (hash modulus, ranking round-robin, contiguous
        // chunks), so the wire bytes never shrink along 1 -> 2 -> 4 -> 8.
        let idx: Vec<u32> = (0..600u32).map(|i| i * 13 % 1000).collect();
        for policy in ShardPolicy::all() {
            let mut last = 0u64;
            for hosts in [1usize, 2, 4, 8] {
                let cfg = ShardConfig {
                    num_hosts: hosts,
                    ..shard_cfg(2, policy, 0.5)
                };
                let mut st = ShardedStore::new(1000, 64, &sys(), &cfg);
                let c = st.gather_cost(&idx, 16, &sys());
                assert!(
                    c.split.net_bytes_on_link >= last,
                    "{policy:?}: net bytes shrank at {hosts} hosts"
                );
                last = c.split.net_bytes_on_link;
            }
            assert!(last > 0, "{policy:?}: 8 hosts must push bytes onto the wire");
        }
    }

    #[test]
    fn stats_since_gives_epoch_deltas() {
        let mut st = ShardedStore::new(400, 64, &sys(), &shard_cfg(2, ShardPolicy::Hash, 0.5));
        let idx: Vec<u32> = (0..100).collect();
        st.gather_cost(&idx, 16, &sys());
        let snap = st.stats();
        st.gather_cost(&idx, 16, &sys());
        let delta = st.stats().since(&snap);
        assert_eq!(delta.totals().rows_served(), 100);
    }
}

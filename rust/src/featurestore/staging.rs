//! Pinned staging-buffer pool for the CPU-centric baseline (Fig. 2a, ②).
//!
//! The baseline PyTorch path gathers scattered rows into a host buffer
//! before the DMA.  Allocating (and `cudaHostRegister`-ing) such buffers per
//! step is expensive, so real frameworks reuse them; this pool does the
//! same and exposes reuse statistics for the ablation bench.

use std::sync::{Mutex, PoisonError};

/// Reusable staging buffers keyed by capacity.
#[derive(Debug, Default)]
pub struct StagingPool {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    buffers: Vec<Vec<f32>>,
    hits: u64,
    misses: u64,
}

impl StagingPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Poison-recovering lock, matching the feature store's guarantee: a
    /// panicked stage thread must not turn every later baseline gather
    /// into an `.unwrap()` cascade (the pool state — spare buffers and
    /// counters — is valid at every suspension point).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Take a buffer with at least `len` elements (zero-length tail beyond
    /// `len` is unspecified; callers overwrite).
    pub fn take(&self, len: usize) -> Vec<f32> {
        let mut inner = self.lock();
        if let Some(pos) = inner.buffers.iter().position(|b| b.capacity() >= len) {
            let mut buf = inner.buffers.swap_remove(pos);
            buf.resize(len, 0.0);
            inner.hits += 1;
            buf
        } else {
            inner.misses += 1;
            vec![0f32; len]
        }
    }

    pub fn give(&self, buf: Vec<f32>) {
        let mut inner = self.lock();
        // Bound the pool: keep at most 4 buffers (mirrors a small ring of
        // pinned buffers; unbounded pools would hide leaks).
        if inner.buffers.len() < 4 {
            inner.buffers.push(buf);
        }
    }

    pub fn hits(&self) -> u64 {
        self.lock().hits
    }

    pub fn misses(&self) -> u64 {
        self.lock().misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_buffers() {
        let p = StagingPool::new();
        let b = p.take(1024);
        p.give(b);
        let b2 = p.take(512); // smaller fits in recycled capacity
        assert_eq!(b2.len(), 512);
        assert_eq!(p.hits(), 1);
        assert_eq!(p.misses(), 1);
    }

    #[test]
    fn grows_on_demand() {
        let p = StagingPool::new();
        p.give(p.take(16));
        let big = p.take(1 << 16);
        assert_eq!(big.len(), 1 << 16);
        assert_eq!(p.misses(), 2);
    }

    #[test]
    fn pool_is_bounded() {
        let p = StagingPool::new();
        for _ in 0..10 {
            p.give(vec![0f32; 8]);
        }
        assert!(p.inner.lock().unwrap().buffers.len() <= 4);
    }
}

//! The unified feature store: one table, eight access designs.

use std::sync::{Mutex, PoisonError};

use crate::config::{AccessMode, Precision, SystemProfile};
use crate::device::warp::{count_requests, GatherTraffic, WarpModel};
use crate::error::{Error, Result};
use crate::featurestore::nvme::{NvmeStats, NvmeStore, NvmeStoreConfig};
use crate::featurestore::quant;
use crate::featurestore::sharded::{ShardConfig, ShardStats, ShardedStore};
use crate::featurestore::staging::StagingPool;
use crate::featurestore::synth::SyntheticFeatures;
use crate::featurestore::tiered::{TierConfig, TierStats, TieredCache};
use crate::interconnect::{
    count_block_ios, count_block_ios_excluding, DmaEngine, NetLink, NvlinkLink, NvmeLink,
    PathSplit, PcieLink, TransferCost, UvmSpace,
};
use crate::sampler::aggregate::AggregatePlan;
use crate::tensor::{Device, Tensor};
use crate::util::timer::Timer;

/// Node-feature table + access-mode machinery.
pub struct FeatureStore {
    table: Tensor,
    synth: SyntheticFeatures,
    rows: usize,
    mode: AccessMode,
    /// Storage precision of the table (DESIGN.md §13).  The table's
    /// values are the storage round-trip of the synthesized fp32 rows —
    /// quantized once at build, so every access mode gathers identical
    /// values — and every per-row cost below prices
    /// `precision.row_bytes(dim)` instead of `dim * 4`.
    precision: Precision,
    /// Worker threads for the measured host-side gather/scatter copies
    /// (`--sampler-workers`).  Purely a wall-clock knob: outputs are
    /// bitwise identical at every count (disjoint whole-row chunks —
    /// see `tensor::indexing::gather_rows_into_parallel`).
    gather_workers: usize,
    sys: SystemProfile,
    staging: StagingPool,
    uvm: Option<Mutex<UvmSpace>>,
    tier: Option<Mutex<TieredCache>>,
    shard: Option<Mutex<ShardedStore>>,
    nvme: Option<Mutex<NvmeStore>>,
    /// Cumulative measured CPU seconds spent in real gathers (diagnostic).
    measured_gather: Mutex<f64>,
}

impl FeatureStore {
    /// Poison-recovering lock for the store's internal state: a panic in
    /// a pipeline stage must degrade into a clean failed epoch, not an
    /// `.unwrap()` cascade on the next stats call or gather — the guarded
    /// values (counters and placement metadata) are valid at every
    /// suspension point, so resuming past a poison is sound.
    fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
        m.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Build a store of `rows` synthesized feature rows.
    ///
    /// `GpuResident` enforces the GPU memory capacity — requesting it for a
    /// table larger than the device is exactly the out-of-memory wall that
    /// motivates the paper (§2.2), surfaced as [`Error::GpuOom`].
    ///
    /// `Tiered` built through here starts with [`TierConfig::default`]
    /// (cold cache, LFU warming); use [`FeatureStore::build_tiered`] to
    /// supply a degree ranking and capacity knobs.  `Sharded` likewise
    /// starts with [`ShardConfig::default`] (one GPU); use
    /// [`FeatureStore::build_sharded`] for real partitioning.  `Nvme`
    /// starts with [`NvmeStoreConfig::default`] (half the table
    /// host-resident); use [`FeatureStore::build_nvme`] for real
    /// placement knobs.
    pub fn build(
        rows: usize,
        dim: usize,
        classes: u32,
        mode: AccessMode,
        sys: &SystemProfile,
        seed: u64,
    ) -> Result<FeatureStore> {
        Self::build_inner(rows, dim, classes, mode, sys, seed, Precision::Fp32, None, None, None)
    }

    /// Build with an explicit storage precision (DESIGN.md §13) plus
    /// whichever mode-specific placement knobs apply — the trainer's
    /// entry point.  `Precision::Fp32` reproduces the plain builders
    /// bit-exactly; fp16/int8 round-trip the table through the narrow
    /// format once at build and price the narrowed row on every link.
    #[allow(clippy::too_many_arguments)]
    pub fn build_quantized(
        rows: usize,
        dim: usize,
        classes: u32,
        mode: AccessMode,
        sys: &SystemProfile,
        seed: u64,
        precision: Precision,
        tier_cfg: Option<TierConfig>,
        shard_cfg: Option<ShardConfig>,
        nvme_cfg: Option<NvmeStoreConfig>,
    ) -> Result<FeatureStore> {
        Self::build_inner(
            rows, dim, classes, mode, sys, seed, precision, tier_cfg, shard_cfg, nvme_cfg,
        )
    }

    /// Build a `Tiered` store with explicit tier placement/capacity knobs.
    pub fn build_tiered(
        rows: usize,
        dim: usize,
        classes: u32,
        sys: &SystemProfile,
        seed: u64,
        tier_cfg: TierConfig,
    ) -> Result<FeatureStore> {
        Self::build_inner(
            rows,
            dim,
            classes,
            AccessMode::Tiered,
            sys,
            seed,
            Precision::Fp32,
            Some(tier_cfg),
            None,
            None,
        )
    }

    /// Build a `Sharded` store with explicit shard placement + tier knobs.
    pub fn build_sharded(
        rows: usize,
        dim: usize,
        classes: u32,
        sys: &SystemProfile,
        seed: u64,
        shard_cfg: ShardConfig,
    ) -> Result<FeatureStore> {
        Self::build_inner(
            rows,
            dim,
            classes,
            AccessMode::Sharded,
            sys,
            seed,
            Precision::Fp32,
            None,
            Some(shard_cfg),
            None,
        )
    }

    /// Build an `Nvme` three-tier store with explicit `host_frac` + tier
    /// knobs (DESIGN.md §8).
    pub fn build_nvme(
        rows: usize,
        dim: usize,
        classes: u32,
        sys: &SystemProfile,
        seed: u64,
        nvme_cfg: NvmeStoreConfig,
    ) -> Result<FeatureStore> {
        Self::build_inner(
            rows,
            dim,
            classes,
            AccessMode::Nvme,
            sys,
            seed,
            Precision::Fp32,
            None,
            None,
            Some(nvme_cfg),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn build_inner(
        rows: usize,
        dim: usize,
        classes: u32,
        mode: AccessMode,
        sys: &SystemProfile,
        seed: u64,
        precision: Precision,
        tier_cfg: Option<TierConfig>,
        shard_cfg: Option<ShardConfig>,
        nvme_cfg: Option<NvmeStoreConfig>,
    ) -> Result<FeatureStore> {
        let row_bytes = precision.row_bytes(dim);
        let bytes = rows as u64 * row_bytes;
        if mode == AccessMode::GpuResident && bytes > sys.gpu_mem_bytes {
            return Err(Error::GpuOom {
                need: bytes,
                capacity: sys.gpu_mem_bytes,
            });
        }
        let synth = SyntheticFeatures::new(dim, classes, seed);
        let mut data = synth.build_table(rows);
        // Round-trip the whole table through the storage format up front:
        // every access mode then gathers the same already-dequantized
        // values, preserving bitwise cross-mode equality at any precision
        // (fp32 is the identity — DESIGN.md §13).
        quant::quantize_table(&mut data, dim, precision);
        let device = match mode {
            AccessMode::CpuGather => Device::Cpu,
            AccessMode::GpuResident => Device::Cuda,
            // Tiered's source of truth is the unified table; the hot set is
            // placement metadata, not a second copy.
            _ => Device::Unified, // Listing 2: dataload().to("unified")
        };
        let table = Tensor::from_f32(&data, &[rows, dim], device)?;
        let uvm = if mode == AccessMode::Uvm {
            Some(Mutex::new(UvmSpace::new(sys, 0.5)))
        } else {
            None
        };
        let tier = if mode == AccessMode::Tiered {
            let cfg = tier_cfg.unwrap_or_default();
            Some(Mutex::new(TieredCache::new(rows, row_bytes, sys, &cfg)))
        } else {
            None
        };
        let shard = if mode == AccessMode::Sharded {
            let cfg = shard_cfg.unwrap_or_default();
            Some(Mutex::new(ShardedStore::new(rows, row_bytes, sys, &cfg)))
        } else {
            None
        };
        let nvme = if mode == AccessMode::Nvme {
            let cfg = nvme_cfg.unwrap_or_default();
            Some(Mutex::new(NvmeStore::new(rows, row_bytes, sys, &cfg)))
        } else {
            None
        };
        Ok(FeatureStore {
            table,
            synth,
            rows,
            mode,
            precision,
            gather_workers: 1,
            sys: sys.clone(),
            staging: StagingPool::new(),
            uvm,
            tier,
            shard,
            nvme,
            measured_gather: Mutex::new(0.0),
        })
    }

    pub fn mode(&self) -> AccessMode {
        self.mode
    }

    pub fn dim(&self) -> usize {
        self.synth.dim
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn table(&self) -> &Tensor {
        &self.table
    }

    pub fn label(&self, node: u32) -> i32 {
        self.synth.label(node)
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Set the worker-thread count for the measured gather/scatter copies
    /// (`--sampler-workers`); 0 is clamped to 1.  Bitwise invariant:
    /// `tests/parallel_gather.rs` pins gathers at 1/2/7/16 workers to the
    /// same bytes in every access mode.
    pub fn set_gather_workers(&mut self, workers: usize) {
        self.gather_workers = workers.max(1);
    }

    pub fn gather_workers(&self) -> usize {
        self.gather_workers
    }

    /// Bytes the stored table occupies at this store's precision.
    pub fn table_bytes(&self) -> u64 {
        self.rows as u64 * self.precision.row_bytes(self.synth.dim)
    }

    pub fn measured_gather_s(&self) -> f64 {
        *Self::lock(&self.measured_gather)
    }

    /// Staging-pool reuse statistics (CpuGather mode; ablation D).
    pub fn staging_hits(&self) -> u64 {
        self.staging.hits()
    }

    pub fn staging_misses(&self) -> u64 {
        self.staging.misses()
    }

    /// Hot-tier counters/gauges (`Tiered` mode only).
    pub fn tier_stats(&self) -> Option<TierStats> {
        self.tier.as_ref().map(|t| Self::lock(t).stats())
    }

    /// Per-GPU shard counters/gauges (`Sharded` mode only).
    pub fn shard_stats(&self) -> Option<ShardStats> {
        self.shard.as_ref().map(|s| Self::lock(s).stats())
    }

    /// Three-tier storage counters/gauges (`Nvme` mode only).
    pub fn nvme_stats(&self) -> Option<NvmeStats> {
        self.nvme.as_ref().map(|s| Self::lock(s).stats())
    }

    /// Pin the cache pages covering `idx` in whichever hot tier this mode
    /// has (tiered / sharded / nvme; no-op elsewhere), so the pages of an
    /// in-flight gather are never evicted while its rows scatter out —
    /// the serving engine holds these across a coalesced window's
    /// per-request scatters.  Pair with [`FeatureStore::unpin_rows`].
    pub fn pin_rows(&self, idx: &[u32]) {
        if let Some(t) = self.tier.as_ref() {
            Self::lock(t).pin_rows(idx);
        }
        if let Some(s) = self.shard.as_ref() {
            Self::lock(s).pin_rows(idx);
        }
        if let Some(n) = self.nvme.as_ref() {
            Self::lock(n).pin_rows(idx);
        }
    }

    /// Release the pins [`FeatureStore::pin_rows`] took.
    pub fn unpin_rows(&self, idx: &[u32]) {
        if let Some(t) = self.tier.as_ref() {
            Self::lock(t).unpin_rows(idx);
        }
        if let Some(s) = self.shard.as_ref() {
            Self::lock(s).unpin_rows(idx);
        }
        if let Some(n) = self.nvme.as_ref() {
            Self::lock(n).unpin_rows(idx);
        }
    }

    /// Simulated cost of a GPU zero-copy gather of `idx` over PCIe —
    /// shared by the `UnifiedNaive`/`UnifiedAligned` arms and the tiered
    /// cold path, so "tiered at hot_frac 0 costs exactly UnifiedAligned"
    /// holds structurally rather than by duplicated arithmetic.
    fn zero_copy_cost(&self, idx: &[u32], aligned: bool) -> TransferCost {
        let f = self.synth.dim as u64;
        // fp32 yields WarpModel::default() field-for-field (the bit-exact
        // anchor); fp16/int8 pack 64/128 elements per 128 B cacheline.
        let model = WarpModel::for_elem_bytes(self.precision.elem_bytes());
        let shifted = aligned && model.shift_applies(f);
        let traffic = count_requests(idx, f, model, shifted);
        PcieLink::new(&self.sys).direct_gather(&traffic)
    }

    /// Gather `idx` rows into `out` (len == idx.len()*dim), returning the
    /// simulated transfer cost for this store's access mode.
    pub fn gather_into(&self, idx: &[u32], out: &mut [f32]) -> Result<TransferCost> {
        let f = self.synth.dim;
        if out.len() != idx.len() * f {
            return Err(Error::Shape(format!(
                "out len {} != {}x{f}",
                out.len(),
                idx.len()
            )));
        }
        if let Some(&bad) = idx.iter().find(|&&i| i as usize >= self.rows) {
            return Err(Error::IndexOutOfBounds {
                index: bad as usize,
                bound: self.rows,
            });
        }
        let row_bytes = self.precision.row_bytes(f);
        let src = self.table.f32_data();

        let cost = match self.mode {
            AccessMode::CpuGather => {
                // ① gather into the pinned staging buffer (real memcpys)
                let timer = Timer::start();
                let mut staging = self.staging.take(idx.len() * f);
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    &mut staging,
                    self.gather_workers,
                )?;
                // ④ DMA lands the contiguous buffer in device memory
                out.copy_from_slice(&staging);
                self.staging.give(staging);
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                DmaEngine::new(&self.sys).cpu_gather_transfer(idx.len() as u64, row_bytes)
            }
            AccessMode::UnifiedNaive | AccessMode::UnifiedAligned => {
                // GPU zero-copy: device fetches rows directly; no staging.
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                self.zero_copy_cost(idx, self.mode == AccessMode::UnifiedAligned)
            }
            AccessMode::Uvm => {
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                let mut uvm = Self::lock(self.uvm.as_ref().unwrap());
                let mut c = uvm.access_rows(idx, row_bytes);
                // after migration the GPU still runs the gather kernel;
                // split.host_time_s stays launch-free (link occupancy).
                c.time_s += self.sys.kernel_launch_s;
                c
            }
            AccessMode::GpuResident => {
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                TransferCost {
                    time_s: self.sys.kernel_launch_s,
                    bytes_on_link: 0,
                    useful_bytes: idx.len() as u64 * row_bytes,
                    requests: 0,
                    cpu_time_s: 0.0,
                    split: PathSplit {
                        local_bytes: idx.len() as u64 * row_bytes,
                        ..PathSplit::default()
                    },
                }
            }
            AccessMode::Tiered => {
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                let tier = self.tier.as_ref().expect("tiered store has a cache");
                let cold = Self::lock(tier).record(idx);
                let useful = idx.len() as u64 * row_bytes;
                if cold.is_empty() {
                    // Entire batch in the hot tier: a device-memory gather,
                    // kernel launch only — the GpuResident endpoint.
                    TransferCost {
                        time_s: self.sys.kernel_launch_s,
                        bytes_on_link: 0,
                        useful_bytes: useful,
                        requests: 0,
                        cpu_time_s: 0.0,
                        split: PathSplit {
                            local_bytes: useful,
                            ..PathSplit::default()
                        },
                    }
                } else {
                    // One gather kernel serves both tiers; only the cold
                    // subset drives PCIe traffic, through the same aligned
                    // zero-copy model as UnifiedAligned (so hot_frac = 0
                    // reproduces that mode's cost exactly).
                    let mut cost = self.zero_copy_cost(&cold, true);
                    cost.useful_bytes = useful;
                    cost.split.local_bytes = useful - cost.split.host_bytes;
                    cost
                }
            }
            AccessMode::Sharded => {
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                Self::lock(self.shard.as_ref().expect("sharded store has placement"))
                    .gather_cost(idx, f as u64, &self.sys)
            }
            AccessMode::Nvme => {
                let timer = Timer::start();
                crate::tensor::indexing::gather_rows_into_parallel(
                    src,
                    f,
                    idx,
                    out,
                    self.gather_workers,
                )?;
                *Self::lock(&self.measured_gather) += timer.elapsed_s();
                Self::lock(self.nvme.as_ref().expect("nvme store has placement"))
                    .gather_cost(idx, f as u64, &self.sys)
            }
        };
        Ok(cost)
    }

    /// Gather through a [`GatherPlan`]: fetch each *distinct* requested
    /// row once — so the whole cost machinery of this store's mode (warp
    /// request coalescing, hot-tier hit accounting, per-shard peer
    /// streams, NVMe block I/Os) prices the deduplicated id stream — then
    /// scatter the unique rows back to the requested layout via the
    /// plan's inverse map.
    ///
    /// `out` keeps the requested shape (`plan.requested_rows() * dim`)
    /// and is bitwise identical to [`FeatureStore::gather_into`] on the
    /// original duplicated stream; only the returned [`TransferCost`]
    /// (and the mode's tier/shard/storage counters) shrink.  Stateful
    /// tiers therefore count one hit *or* miss per distinct row per
    /// batch, and LFU frequencies bump once per batch per row — the
    /// `--no-dedup` path restores the per-occurrence accounting.
    ///
    /// [`GatherPlan`]: crate::sampler::compact::GatherPlan
    pub fn gather_planned(
        &self,
        plan: &crate::sampler::compact::GatherPlan,
        out: &mut [f32],
    ) -> Result<TransferCost> {
        let f = self.synth.dim;
        if out.len() != plan.requested_rows() * f {
            return Err(Error::Shape(format!(
                "out len {} != {}x{f}",
                out.len(),
                plan.requested_rows()
            )));
        }
        let mut uniq = vec![0f32; plan.unique_rows() * f];
        let cost = self.gather_into(plan.unique_nodes(), &mut uniq)?;
        let timer = Timer::start();
        // Scatter is the same copy loop as gather with the plan's scatter map
        // as the index stream, so it parallelizes through the same seam.
        crate::tensor::indexing::gather_rows_into_parallel(
            &uniq,
            f,
            plan.scatter_map(),
            out,
            self.gather_workers,
        )?;
        *Self::lock(&self.measured_gather) += timer.elapsed_s();
        Ok(cost)
    }

    /// Convenience: gather into a fresh Vec.
    pub fn gather(&self, idx: &[u32]) -> Result<(Vec<f32>, TransferCost)> {
        let mut out = vec![0f32; idx.len() * self.synth.dim];
        let cost = self.gather_into(idx, &mut out)?;
        Ok((out, cost))
    }

    /// Price a pushed-down gather (`--aggregate-pushdown`, DESIGN.md §14)
    /// WITHOUT mutating any tier/placement state — call it *before* the
    /// physical gather, so the residency classification sees exactly the
    /// pre-batch state the raw path's own accounting will.
    ///
    /// Instead of `n_unique` raw neighbor rows, the pushed-down step ships
    /// two streams:
    ///
    /// * the **self stream** — every layer-0 destination still needs its
    ///   own feature row on the GPU; priced with this mode's raw gather
    ///   arithmetic on `agg.dst_nodes()` (deduplicated first when `dedup`,
    ///   which is how push-down composes with DESIGN.md §10), replicated
    ///   read-only via the stores' residency views
    ///   ([`TieredCache::is_hot`], [`ShardedStore::is_hot_in_owner`],
    ///   [`NvmeStore::is_gpu_hot`]/[`NvmeStore::cold_slot`]);
    /// * the **aggregate streams** — for each tier holding ≥ 1 of a
    ///   destination's neighbors, one partial-aggregate row plus a 4-byte
    ///   neighbor count (`row_bytes + 4`) crosses that tier's link as a
    ///   *contiguous* payload (the near-memory engine emits a dense
    ///   `n_dst × dim` block, so no warp-coalescing penalty applies): host
    ///   partials at the PCIe ideal-transfer rate, peer partials at the
    ///   effective NVLink bandwidth, and NVMe-cold partials pay their
    ///   block reads as *internal* storage-link occupancy
    ///   ([`count_block_ios`], unchanged) while only the aggregate bytes
    ///   cross the host link.  Neighbors resident in the requesting GPU's
    ///   own hot tier reduce locally: no link bytes, no near-memory work.
    ///
    /// The near-memory reduction time (off-GPU neighbor elements divided
    /// by [`SystemProfile::near_mem_fp32_flops`]) serializes into
    /// `cost.time_s` and feeds the power model's near-memory duty cycle;
    /// it is *not* CPU time — the engines sit beside the memory, off the
    /// cores — and it never loads a link occupancy.
    ///
    /// `Uvm`'s self stream is priced as a host ideal transfer plus one
    /// launch: re-running the fault machinery read-only is impossible, so
    /// push-down deliberately models UVM's post-migration steady state
    /// (the §14 compromise; `Uvm` is excluded from the strict-reduction
    /// acceptance set for the same reason).
    ///
    /// Numerics are untouched: the pushed-down aggregate itself is
    /// computed once on the gathered rows in the plan's pinned order
    /// ([`AggregatePlan::aggregate_gathered`]) — this method only reprices
    /// the traffic.
    pub fn pushdown_cost(&self, agg: &AggregatePlan, dedup: bool) -> Result<PushdownCost> {
        let f = self.synth.dim;
        let row_bytes = self.precision.row_bytes(f);
        let dst = agg.dst_nodes();
        for j in 0..agg.n_dst() {
            if let Some(&bad) = agg.neighbor_ids(j).iter().find(|&&r| r as usize >= self.rows) {
                return Err(Error::IndexOutOfBounds {
                    index: bad as usize,
                    bound: self.rows,
                });
            }
        }
        if let Some(&bad) = dst.iter().find(|&&r| r as usize >= self.rows) {
            return Err(Error::IndexOutOfBounds {
                index: bad as usize,
                bound: self.rows,
            });
        }
        // Self stream: the destinations' own rows, deduplicated like any
        // other gather stream when dedup is on (pushdown × dedup compose).
        let plan;
        let self_ids: &[u32] = if dedup {
            plan = crate::sampler::compact::GatherPlan::build(dst);
            plan.unique_nodes()
        } else {
            dst
        };
        let self_useful = self_ids.len() as u64 * row_bytes;
        let launch_only = || TransferCost {
            time_s: self.sys.kernel_launch_s,
            bytes_on_link: 0,
            useful_bytes: self_useful,
            requests: 0,
            cpu_time_s: 0.0,
            split: PathSplit {
                local_bytes: self_useful,
                ..PathSplit::default()
            },
        };
        let mut cost = match self.mode {
            AccessMode::CpuGather => {
                DmaEngine::new(&self.sys).cpu_gather_transfer(self_ids.len() as u64, row_bytes)
            }
            AccessMode::UnifiedNaive | AccessMode::UnifiedAligned => {
                self.zero_copy_cost(self_ids, self.mode == AccessMode::UnifiedAligned)
            }
            AccessMode::Uvm => {
                // Post-migration steady state (see the doc comment): the
                // self stream rides the host link at the ideal rate plus
                // the gather-kernel launch.
                let mut c = PcieLink::new(&self.sys).ideal(self_useful);
                c.time_s += self.sys.kernel_launch_s;
                c
            }
            AccessMode::GpuResident => launch_only(),
            AccessMode::Tiered => {
                let tier = self.tier.as_ref().expect("tiered store has a cache");
                let cold: Vec<u32> = {
                    let t = Self::lock(tier);
                    self_ids.iter().copied().filter(|&r| !t.is_hot(r)).collect()
                };
                if cold.is_empty() {
                    launch_only()
                } else {
                    let mut c = self.zero_copy_cost(&cold, true);
                    c.useful_bytes = self_useful;
                    c.split.local_bytes = self_useful - c.split.host_bytes;
                    c
                }
            }
            AccessMode::Sharded => {
                let shard = Self::lock(self.shard.as_ref().expect("sharded store has placement"));
                Self::sharded_classify_cost(&shard, self_ids, f as u64, row_bytes, &self.sys)
            }
            AccessMode::Nvme => {
                let nv = Self::lock(self.nvme.as_ref().expect("nvme store has placement"));
                Self::nvme_classify_cost(&nv, self_ids, f as u64, row_bytes, &self.sys)
            }
        };
        let self_bytes_on_link = cost.bytes_on_link;

        // Aggregate streams: classify every masked neighbor slot against
        // the same pre-batch residency and count, per destination, one
        // partial-aggregate row per contributing off-GPU tier.
        let mut agg_rows_host = 0u64; // partials computed host-side
        let mut agg_rows_peer = 0u64; // partials computed on peer GPUs
        let mut agg_rows_storage = 0u64; // partials computed storage-side
        let mut agg_rows_net = 0u64; // partials computed on remote hosts
        let mut off_gpu_slots = 0u64; // neighbor slots reduced off the requesting GPU
        let mut storage_slots: Vec<u32> = Vec::new();
        // Self-stream cold slots (`Nvme` mode): their block reads are
        // already priced inside the self stream above, so the aggregate
        // stream must not charge the shared blocks again (DESIGN.md §14).
        let mut self_storage_slots: Vec<u32> = Vec::new();
        // Distinct remote homes contributing partials this step — the
        // batched per-host RPC count the network link charges latency for.
        let mut remote_homes = 0u64;
        match self.mode {
            AccessMode::CpuGather
            | AccessMode::UnifiedNaive
            | AccessMode::UnifiedAligned
            | AccessMode::Uvm => {
                // Single host tier: every destination with neighbors gets
                // one host-side partial.
                for j in 0..agg.n_dst() {
                    let n = agg.neighbor_ids(j).len() as u64;
                    if n > 0 {
                        agg_rows_host += 1;
                        off_gpu_slots += n;
                    }
                }
            }
            // Everything already sits in device memory: the GPU reduces
            // its own rows exactly as before — push-down moves nothing.
            AccessMode::GpuResident => {}
            AccessMode::Tiered => {
                let t = Self::lock(self.tier.as_ref().expect("tiered store has a cache"));
                for j in 0..agg.n_dst() {
                    let cold = agg.neighbor_ids(j).iter().filter(|&&r| !t.is_hot(r)).count() as u64;
                    if cold > 0 {
                        agg_rows_host += 1;
                        off_gpu_slots += cold;
                    }
                }
            }
            AccessMode::Sharded => {
                // Destinations split across the GPUs with the same
                // contiguous chunk rule as the raw gather; each GPU's
                // neighbors classify local / peer-partial / host-partial /
                // net-partial (remote-homed neighbors reduce on their home
                // host and ship one partial per contributing home).
                let shard = Self::lock(self.shard.as_ref().expect("sharded store has placement"));
                let n = shard.num_gpus();
                let nd = agg.n_dst();
                let mut peer_owner_seen = vec![false; n];
                let mut remote_home_seen = vec![false; shard.num_hosts()];
                let mut step_home_seen = vec![false; shard.num_hosts()];
                for g in 0..n {
                    for j in g * nd / n..(g + 1) * nd / n {
                        let mut host_any = false;
                        for seen in peer_owner_seen.iter_mut() {
                            *seen = false;
                        }
                        for seen in remote_home_seen.iter_mut() {
                            *seen = false;
                        }
                        for &r in agg.neighbor_ids(j) {
                            if shard.is_remote(r) {
                                let h = shard.host_of(r);
                                remote_home_seen[h] = true;
                                step_home_seen[h] = true;
                                off_gpu_slots += 1;
                                continue;
                            }
                            let o = shard.owner_of(r);
                            if shard.is_hot_in_owner(r) {
                                if o != g {
                                    peer_owner_seen[o] = true;
                                    off_gpu_slots += 1;
                                }
                            } else {
                                host_any = true;
                                off_gpu_slots += 1;
                            }
                        }
                        agg_rows_peer +=
                            peer_owner_seen.iter().filter(|&&seen| seen).count() as u64;
                        agg_rows_net +=
                            remote_home_seen.iter().filter(|&&seen| seen).count() as u64;
                        if host_any {
                            agg_rows_host += 1;
                        }
                    }
                }
                remote_homes = step_home_seen.iter().filter(|&&seen| seen).count() as u64;
            }
            AccessMode::Nvme => {
                let nv = Self::lock(self.nvme.as_ref().expect("nvme store has placement"));
                // Replicate the self stream's cold-slot set under the same
                // lock: `nvme_classify_cost` above already paid these
                // slots' block reads, so the aggregate pricing below
                // excludes their blocks instead of charging them twice.
                for &r in self_ids {
                    if !nv.is_gpu_hot(r) {
                        if let Some(s) = nv.cold_slot(r) {
                            self_storage_slots.push(s);
                        }
                    }
                }
                for j in 0..agg.n_dst() {
                    let mut host_any = false;
                    let mut storage_any = false;
                    for &r in agg.neighbor_ids(j) {
                        if nv.is_gpu_hot(r) {
                            continue;
                        }
                        off_gpu_slots += 1;
                        match nv.cold_slot(r) {
                            None => host_any = true,
                            Some(s) => {
                                storage_any = true;
                                storage_slots.push(s);
                            }
                        }
                    }
                    if host_any {
                        agg_rows_host += 1;
                    }
                    if storage_any {
                        agg_rows_storage += 1;
                    }
                }
            }
        }

        // Price the aggregate payloads.  Partials are `row_bytes` of sums
        // plus the 4-byte neighbor count the consumer finishes a mean
        // with; storage-side partials cross the host link too (the SSD
        // hangs off the same PCIe root), paying their block reads as
        // internal storage occupancy.
        let agg_row_bytes = row_bytes + 4;
        let host_agg_bytes = (agg_rows_host + agg_rows_storage) * agg_row_bytes;
        let peer_agg_bytes = agg_rows_peer * agg_row_bytes;
        let mut agg_bytes_on_link = 0u64;
        if host_agg_bytes > 0 {
            let c = PcieLink::new(&self.sys).ideal(host_agg_bytes);
            cost.time_s += c.time_s;
            cost.bytes_on_link += c.bytes_on_link;
            cost.useful_bytes += host_agg_bytes;
            cost.requests += c.requests;
            cost.split.host_bytes += host_agg_bytes;
            cost.split.host_bytes_on_link += c.bytes_on_link;
            cost.split.host_time_s += c.time_s;
            agg_bytes_on_link += c.bytes_on_link;
        }
        if peer_agg_bytes > 0 {
            let nv = &self.sys.nvlink;
            let t = peer_agg_bytes as f64 / (nv.peak_bw * nv.direct_efficiency);
            cost.time_s += t;
            cost.bytes_on_link += peer_agg_bytes;
            cost.useful_bytes += peer_agg_bytes;
            cost.requests += peer_agg_bytes / nv.cacheline_bytes.max(1);
            cost.split.peer_bytes += peer_agg_bytes;
            cost.split.peer_bytes_on_link += peer_agg_bytes;
            cost.split.peer_time_s += t;
            agg_bytes_on_link += peer_agg_bytes;
        }
        let net_agg_bytes = agg_rows_net * agg_row_bytes;
        if net_agg_bytes > 0 {
            let c = NetLink::new(&self.sys).fetch(net_agg_bytes, remote_homes);
            cost.time_s += c.time_s;
            cost.bytes_on_link += c.bytes_on_link;
            cost.useful_bytes += net_agg_bytes;
            cost.requests += c.requests;
            cost.split.net_bytes += net_agg_bytes;
            cost.split.net_bytes_on_link += c.split.net_bytes_on_link;
            cost.split.net_time_s += c.split.net_time_s;
            agg_bytes_on_link += c.bytes_on_link;
        }
        if !storage_slots.is_empty() {
            // Blocks the self stream already read are free here: the SSD
            // serves each distinct block once per step (DESIGN.md §14).
            let traffic = count_block_ios_excluding(
                &storage_slots,
                row_bytes,
                self.sys.nvme.block_bytes,
                &self_storage_slots,
            );
            let c = NvmeLink::new(&self.sys).read(&traffic);
            cost.time_s += c.split.storage_time_s;
            cost.bytes_on_link += c.bytes_on_link;
            cost.requests += c.requests;
            cost.split.storage_bytes += c.split.storage_bytes;
            cost.split.storage_bytes_on_link += c.split.storage_bytes_on_link;
            cost.split.storage_time_s += c.split.storage_time_s;
            agg_bytes_on_link += c.bytes_on_link;
        }
        let near_mem_flops = off_gpu_slots * f as u64;
        let near_mem_s = if near_mem_flops > 0 {
            near_mem_flops as f64 / self.sys.near_mem_fp32_flops
        } else {
            0.0
        };
        cost.time_s += near_mem_s;

        Ok(PushdownCost {
            cost,
            self_bytes_on_link,
            agg_bytes_on_link,
            dst_rows: self_ids.len() as u64,
            neighbor_rows: agg.neighbor_rows() as u64,
            off_gpu_neighbor_rows: off_gpu_slots,
            agg_rows: agg_rows_host + agg_rows_peer + agg_rows_storage + agg_rows_net,
            near_mem_flops,
            near_mem_s,
        })
    }

    /// Read-only replica of [`ShardedStore::gather_cost`]'s pricing (same
    /// chunk rule, same per-owner peer streams, same link arithmetic) with
    /// the recording step omitted — classification against pre-step state
    /// is identical because `gather_cost` itself classifies before it
    /// records.
    fn sharded_classify_cost(
        shard: &ShardedStore,
        idx: &[u32],
        feat_elems: u64,
        row_bytes: u64,
        sys: &SystemProfile,
    ) -> TransferCost {
        let n = shard.num_gpus();
        let model = WarpModel::for_row_layout(row_bytes, feat_elems);
        let shifted = model.shift_applies(feat_elems);
        let pcie = PcieLink::new(sys);
        let nvlink = NvlinkLink::new(sys);
        let net = NetLink::new(sys);

        let mut peer_by_owner: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut split = PathSplit::default();
        let mut step_time = 0.0f64;
        let mut link_bytes = 0u64;
        let mut requests = 0u64;
        let mut host = Vec::new();
        let mut remote = Vec::new();
        let mut hosts_seen = vec![false; shard.num_hosts()];

        for g in 0..n {
            let chunk = &idx[g * idx.len() / n..(g + 1) * idx.len() / n];
            let mut local_rows = 0u64;
            host.clear();
            remote.clear();
            for v in &mut peer_by_owner {
                v.clear();
            }
            for &r in chunk {
                if shard.is_remote(r) {
                    remote.push(r);
                    continue;
                }
                let o = shard.owner_of(r);
                if shard.is_hot_in_owner(r) {
                    if o == g {
                        local_rows += 1;
                    } else {
                        peer_by_owner[o].push(r);
                    }
                } else {
                    host.push(r);
                }
            }
            let mut time_g = sys.kernel_launch_s;
            let mut peer_traffic = GatherTraffic::default();
            let mut peer_rows = 0u64;
            for rows_o in &peer_by_owner {
                if rows_o.is_empty() {
                    continue;
                }
                peer_rows += rows_o.len() as u64;
                let t = count_requests(rows_o, feat_elems, model, shifted);
                peer_traffic.requests += t.requests;
                peer_traffic.cachelines += t.cachelines;
                peer_traffic.bytes_moved += t.bytes_moved;
                peer_traffic.useful_bytes += t.useful_bytes;
            }
            if peer_rows > 0 {
                let c = nvlink.peer_gather(&peer_traffic);
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.peer_bytes += c.useful_bytes;
                split.peer_bytes_on_link += c.split.peer_bytes_on_link;
                split.peer_time_s += c.split.peer_time_s;
            }
            if !host.is_empty() {
                let c = pcie.direct_gather(&count_requests(&host, feat_elems, model, shifted));
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.host_bytes += c.useful_bytes;
                split.host_bytes_on_link += c.split.host_bytes_on_link;
                split.host_time_s += c.split.host_time_s;
            }
            if !remote.is_empty() {
                for s in &mut hosts_seen {
                    *s = false;
                }
                let mut messages = 0u64;
                for &r in &remote {
                    let h = shard.host_of(r);
                    if !hosts_seen[h] {
                        hosts_seen[h] = true;
                        messages += 1;
                    }
                }
                let c = net.fetch(remote.len() as u64 * row_bytes, messages);
                time_g = time_g.max(c.time_s);
                link_bytes += c.bytes_on_link;
                requests += c.requests;
                split.net_bytes += c.split.net_bytes;
                split.net_bytes_on_link += c.split.net_bytes_on_link;
                split.net_time_s += c.split.net_time_s;
            }
            split.local_bytes += local_rows * row_bytes;
            step_time = step_time.max(time_g);
        }

        TransferCost {
            time_s: step_time,
            bytes_on_link: link_bytes,
            useful_bytes: idx.len() as u64 * row_bytes,
            requests,
            cpu_time_s: 0.0,
            split,
        }
    }

    /// Read-only replica of [`NvmeStore::gather_cost`]'s pricing (hot
    /// split, host zero-copy stream, storage block reads, serialized link
    /// occupancies) with the recording step omitted.
    fn nvme_classify_cost(
        nv: &NvmeStore,
        idx: &[u32],
        feat_elems: u64,
        row_bytes: u64,
        sys: &SystemProfile,
    ) -> TransferCost {
        let useful = idx.len() as u64 * row_bytes;
        let mut host_stream = Vec::new();
        let mut storage_slots = Vec::new();
        for &r in idx {
            if nv.is_gpu_hot(r) {
                continue;
            }
            match nv.cold_slot(r) {
                None => host_stream.push(r),
                Some(s) => storage_slots.push(s),
            }
        }
        if host_stream.is_empty() && storage_slots.is_empty() {
            return TransferCost {
                time_s: sys.kernel_launch_s,
                bytes_on_link: 0,
                useful_bytes: useful,
                requests: 0,
                cpu_time_s: 0.0,
                split: PathSplit {
                    local_bytes: useful,
                    ..PathSplit::default()
                },
            };
        }
        let mut time_s = sys.kernel_launch_s;
        let mut bytes_on_link = 0u64;
        let mut requests = 0u64;
        let mut split = PathSplit::default();
        if !host_stream.is_empty() {
            let model = WarpModel::for_row_layout(row_bytes, feat_elems);
            let shifted = model.shift_applies(feat_elems);
            let c = PcieLink::new(sys)
                .direct_gather(&count_requests(&host_stream, feat_elems, model, shifted));
            time_s += c.split.host_time_s;
            bytes_on_link += c.bytes_on_link;
            requests += c.requests;
            split.host_bytes = c.split.host_bytes;
            split.host_bytes_on_link = c.split.host_bytes_on_link;
            split.host_time_s = c.split.host_time_s;
        }
        if !storage_slots.is_empty() {
            let traffic = count_block_ios(&storage_slots, row_bytes, sys.nvme.block_bytes);
            let c = NvmeLink::new(sys).read(&traffic);
            time_s += c.split.storage_time_s;
            bytes_on_link += c.bytes_on_link;
            requests += c.requests;
            split.storage_bytes = c.split.storage_bytes;
            split.storage_bytes_on_link = c.split.storage_bytes_on_link;
            split.storage_time_s = c.split.storage_time_s;
        }
        split.local_bytes = useful - split.host_bytes - split.storage_bytes;
        TransferCost {
            time_s,
            bytes_on_link,
            useful_bytes: useful,
            requests,
            cpu_time_s: 0.0,
            split,
        }
    }
}

/// Traffic accounting of one pushed-down gather
/// ([`FeatureStore::pushdown_cost`], DESIGN.md §14).  `cost` is what the
/// simulated epoch pays instead of the raw gather's [`TransferCost`]; the
/// remaining fields decompose it for `EpochReport::pushdown` and the
/// `pushdown_sweep` bench's reduction factors.
#[derive(Clone, Copy, Debug, Default)]
pub struct PushdownCost {
    /// Simulated transfer cost of the pushed-down step: self stream +
    /// aggregate streams + near-memory reduction time, serialized.
    pub cost: TransferCost,
    /// Link bytes of the destination self stream alone.
    pub self_bytes_on_link: u64,
    /// Link bytes of the aggregate streams (all tiers; includes the NVMe
    /// block reads behind storage-side partials).
    pub agg_bytes_on_link: u64,
    /// Destination rows the self stream priced (post-dedup when dedup on).
    pub dst_rows: u64,
    /// Masked neighbor slots the aggregate streams replace.
    pub neighbor_rows: u64,
    /// Neighbor slots reduced off the requesting GPU (host / peer /
    /// storage side) — the near-memory workload.
    pub off_gpu_neighbor_rows: u64,
    /// Partial-aggregate rows shipped across all tiers.
    pub agg_rows: u64,
    /// Near-memory reduction FLOPs (one add per off-GPU neighbor element).
    pub near_mem_flops: u64,
    /// Near-memory reduction seconds (`near_mem_flops /
    /// near_mem_fp32_flops`), serialized into `cost.time_s`.
    pub near_mem_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    fn store(mode: AccessMode) -> FeatureStore {
        FeatureStore::build(500, 24, 8, mode, &sys(), 42).unwrap()
    }

    #[test]
    fn all_modes_return_identical_values() {
        // The access mode must never change numerics — only cost.
        let idx: Vec<u32> = vec![5, 499, 5, 0, 123];
        let reference = store(AccessMode::CpuGather).gather(&idx).unwrap().0;
        for mode in [
            AccessMode::UnifiedNaive,
            AccessMode::UnifiedAligned,
            AccessMode::Uvm,
            AccessMode::GpuResident,
            AccessMode::Tiered,
            AccessMode::Sharded,
            AccessMode::Nvme,
        ] {
            let (vals, _) = store(mode).gather(&idx).unwrap();
            assert_eq!(vals, reference, "{mode:?}");
        }
    }

    #[test]
    fn gathered_rows_match_synth() {
        let st = store(AccessMode::UnifiedAligned);
        let (vals, _) = st.gather(&[7]).unwrap();
        let mut want = vec![0f32; 24];
        SyntheticFeatures::new(24, 8, 42).fill_row(7, &mut want);
        assert_eq!(vals, want);
    }

    #[test]
    fn planned_gather_is_bitwise_identical_in_every_mode() {
        // 300 slots over ~40 distinct rows: heavy duplication.
        let idx: Vec<u32> = (0..300u32).map(|i| i * 17 % 40).collect();
        let plan = crate::sampler::compact::GatherPlan::build(&idx);
        for mode in AccessMode::all() {
            let st = store(mode);
            let (naive, _) = st.gather(&idx).unwrap();
            let fresh = store(mode); // fresh tiers: same pre-gather state
            let mut planned = vec![0f32; idx.len() * 24];
            fresh.gather_planned(&plan, &mut planned).unwrap();
            assert_eq!(planned, naive, "{mode:?} dedup changed numerics");
        }
    }

    #[test]
    fn planned_gather_costs_the_unique_stream() {
        let idx: Vec<u32> = (0..300u32).map(|i| i * 17 % 40).collect();
        let plan = crate::sampler::compact::GatherPlan::build(&idx);
        for mode in AccessMode::all() {
            let via_plan = {
                let st = store(mode);
                let mut out = vec![0f32; idx.len() * 24];
                st.gather_planned(&plan, &mut out).unwrap()
            };
            let via_unique = store(mode).gather(plan.unique_nodes()).unwrap().1;
            assert_eq!(via_plan.time_s, via_unique.time_s, "{mode:?}");
            assert_eq!(via_plan.bytes_on_link, via_unique.bytes_on_link, "{mode:?}");
            assert_eq!(via_plan.requests, via_unique.requests, "{mode:?}");
            assert_eq!(via_plan.useful_bytes, via_unique.useful_bytes, "{mode:?}");
        }
    }

    #[test]
    fn planned_gather_strictly_cuts_duplicated_traffic() {
        // The acceptance shape of the dedup PR, at the store level where
        // the arithmetic is exact: a duplicated stream must cost strictly
        // more link bytes than its compaction in every transfer-paying
        // mode.
        let idx: Vec<u32> = (0..300u32).map(|i| i * 17 % 40).collect();
        let plan = crate::sampler::compact::GatherPlan::build(&idx);
        for mode in [
            AccessMode::CpuGather,
            AccessMode::UnifiedNaive,
            AccessMode::UnifiedAligned,
            AccessMode::Tiered,
            AccessMode::Sharded,
            AccessMode::Nvme,
        ] {
            let naive = store(mode).gather(&idx).unwrap().1;
            let planned = {
                let st = store(mode);
                let mut out = vec![0f32; idx.len() * 24];
                st.gather_planned(&plan, &mut out).unwrap()
            };
            assert!(
                planned.bytes_on_link < naive.bytes_on_link,
                "{mode:?}: dedup {} !< naive {}",
                planned.bytes_on_link,
                naive.bytes_on_link
            );
            assert!(planned.useful_bytes < naive.useful_bytes, "{mode:?}");
            assert!(planned.time_s <= naive.time_s, "{mode:?}");
        }
    }

    #[test]
    fn store_survives_poisoned_internal_locks() {
        // A panicked pipeline stage must not wedge the store: every
        // internal mutex recovers from poisoning, so the next epoch's
        // gathers and stats calls keep working instead of cascading
        // `.unwrap()` panics.
        let st = store(AccessMode::Tiered);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gauge = st.measured_gather.lock().unwrap();
            let _tier = st.tier.as_ref().unwrap().lock().unwrap();
            panic!("poison the held locks");
        }));
        assert!(st.measured_gather.is_poisoned());
        assert!(st.tier.as_ref().unwrap().is_poisoned());
        st.gather(&[1, 2, 3]).unwrap();
        assert!(st.measured_gather_s() >= 0.0);
        let stats = st.tier_stats().expect("tier stats after poison");
        assert_eq!(stats.hits + stats.misses, 3);
    }

    #[test]
    fn planned_gather_rejects_wrong_output_shape() {
        let st = store(AccessMode::UnifiedAligned);
        let plan = crate::sampler::compact::GatherPlan::build(&[1, 2, 1]);
        let mut too_small = vec![0f32; 2 * 24];
        assert!(st.gather_planned(&plan, &mut too_small).is_err());
    }

    #[test]
    fn gpu_resident_respects_capacity() {
        let mut small_sys = sys();
        small_sys.gpu_mem_bytes = 1024; // 1 KiB GPU
        let err = FeatureStore::build(500, 24, 8, AccessMode::GpuResident, &small_sys, 1);
        assert!(matches!(err, Err(Error::GpuOom { .. })));
        // the unified store has no such limit — the paper's point
        assert!(FeatureStore::build(500, 24, 8, AccessMode::UnifiedAligned, &small_sys, 1).is_ok());
    }

    #[test]
    fn baseline_costs_cpu_time_unified_does_not() {
        let idx: Vec<u32> = (0..100).collect();
        let (_, py) = store(AccessMode::CpuGather).gather(&idx).unwrap();
        let (_, pyd) = store(AccessMode::UnifiedAligned).gather(&idx).unwrap();
        assert!(py.cpu_time_s > 0.0);
        assert_eq!(pyd.cpu_time_s, 0.0);
        assert!(py.time_s > pyd.time_s);
    }

    #[test]
    fn uvm_warm_epoch_cheaper_than_cold() {
        let st = store(AccessMode::Uvm);
        let idx: Vec<u32> = (0..200).collect();
        let (_, cold) = st.gather(&idx).unwrap();
        let (_, warm) = st.gather(&idx).unwrap();
        assert!(warm.time_s < cold.time_s);
    }

    #[test]
    fn staging_pool_reused_across_steps() {
        let st = store(AccessMode::CpuGather);
        let idx: Vec<u32> = (0..64).collect();
        st.gather(&idx).unwrap();
        st.gather(&idx).unwrap();
        st.gather(&idx).unwrap();
        assert!(st.staging.hits() >= 2);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let st = store(AccessMode::UnifiedAligned);
        assert!(st.gather(&[500]).is_err());
    }

    fn tiered_store(hot_frac: f64) -> FeatureStore {
        FeatureStore::build_tiered(
            500,
            24,
            8,
            &sys(),
            42,
            crate::featurestore::tiered::TierConfig {
                hot_frac,
                promote: false,
                ranking: Some((0..500).collect()),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn tiered_at_zero_matches_unified_aligned_exactly() {
        let idx: Vec<u32> = (0..128u32).map(|i| i * 37 % 500).collect();
        let (_, ua) = store(AccessMode::UnifiedAligned).gather(&idx).unwrap();
        let (_, tz) = tiered_store(0.0).gather(&idx).unwrap();
        assert_eq!(tz.time_s, ua.time_s);
        assert_eq!(tz.bytes_on_link, ua.bytes_on_link);
        assert_eq!(tz.requests, ua.requests);
        assert_eq!(tz.useful_bytes, ua.useful_bytes);
    }

    #[test]
    fn tiered_at_one_matches_gpu_resident() {
        let idx: Vec<u32> = (0..128u32).collect();
        let (_, gpu) = store(AccessMode::GpuResident).gather(&idx).unwrap();
        let (_, th) = tiered_store(1.0).gather(&idx).unwrap();
        assert_eq!(th.time_s, gpu.time_s); // kernel launch only
        assert_eq!(th.bytes_on_link, 0);
        assert_eq!(th.requests, 0);
    }

    #[test]
    fn tiered_accounts_every_row_and_stays_in_budget() {
        let st = tiered_store(0.25);
        let idx: Vec<u32> = (0..300u32).map(|i| i * 7 % 500).collect();
        st.gather(&idx).unwrap();
        st.gather(&idx).unwrap();
        let stats = st.tier_stats().unwrap();
        assert_eq!(stats.hits + stats.misses, 600);
        assert!(stats.hits > 0 && stats.misses > 0);
        assert!(stats.hot_bytes <= stats.capacity_bytes);
        assert_eq!(stats.capacity_rows, 125);
    }

    #[test]
    fn tiered_cost_between_endpoints_and_monotone() {
        let idx: Vec<u32> = (0..256u32).map(|i| i * 13 % 500).collect();
        let mut last = f64::INFINITY;
        for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let (_, c) = tiered_store(frac).gather(&idx).unwrap();
            assert!(
                c.time_s <= last + 1e-15,
                "transfer time rose when hot_frac grew to {frac}"
            );
            last = c.time_s;
        }
        let (_, ua) = store(AccessMode::UnifiedAligned).gather(&idx).unwrap();
        assert!(last < ua.time_s, "fully hot tier should beat zero-copy");
    }

    #[test]
    fn non_tiered_modes_report_no_tier_stats() {
        assert!(store(AccessMode::UnifiedAligned).tier_stats().is_none());
        assert!(tiered_store(0.5).tier_stats().is_some());
    }

    fn sharded_store(num_gpus: usize, hot_frac: f64) -> FeatureStore {
        FeatureStore::build_sharded(
            500,
            24,
            8,
            &sys(),
            42,
            crate::featurestore::sharded::ShardConfig {
                num_gpus,
                policy: crate::config::ShardPolicy::Hash,
                tier: crate::featurestore::tiered::TierConfig {
                    hot_frac,
                    promote: false,
                    ranking: Some((0..500).collect()),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn multi_host_store(num_hosts: usize, strategy: crate::config::FetchStrategy) -> FeatureStore {
        FeatureStore::build_sharded(
            500,
            24,
            8,
            &sys(),
            42,
            crate::featurestore::sharded::ShardConfig {
                num_gpus: 2,
                num_hosts,
                policy: crate::config::ShardPolicy::Hash,
                fetch_strategy: strategy,
                tier: crate::featurestore::tiered::TierConfig {
                    hot_frac: 0.5,
                    promote: false,
                    ranking: Some((0..500).collect()),
                    ..Default::default()
                },
            },
        )
        .unwrap()
    }

    #[test]
    fn sharded_n1_matches_tiered_bit_exactly() {
        let idx: Vec<u32> = (0..256u32).map(|i| i * 37 % 500).collect();
        for hot_frac in [0.0, 0.25, 1.0] {
            let (_, ti) = tiered_store(hot_frac).gather(&idx).unwrap();
            let (_, sh) = sharded_store(1, hot_frac).gather(&idx).unwrap();
            assert_eq!(sh.time_s, ti.time_s, "hot_frac {hot_frac}");
            assert_eq!(sh.bytes_on_link, ti.bytes_on_link);
            assert_eq!(sh.requests, ti.requests);
            assert_eq!(sh.useful_bytes, ti.useful_bytes);
            assert_eq!(sh.split.peer_bytes, 0, "one GPU has no peers");
        }
    }

    #[test]
    fn sharded_accounts_every_row_across_paths() {
        let st = sharded_store(4, 0.4);
        let idx: Vec<u32> = (0..300u32).map(|i| i * 7 % 500).collect();
        let (_, cost) = st.gather(&idx).unwrap();
        let stats = st.shard_stats().unwrap();
        let totals = stats.totals();
        assert_eq!(totals.rows_served(), 300);
        assert_eq!(
            totals.local_bytes + totals.peer_bytes + totals.host_bytes,
            cost.useful_bytes
        );
        assert_eq!(stats.num_gpus(), 4);
    }

    #[test]
    fn non_sharded_modes_report_no_shard_stats() {
        assert!(store(AccessMode::UnifiedAligned).shard_stats().is_none());
        assert!(tiered_store(0.5).shard_stats().is_none());
        assert!(sharded_store(2, 0.5).shard_stats().is_some());
    }

    fn nvme_store(host_frac: f64, hot_frac: f64) -> FeatureStore {
        FeatureStore::build_nvme(
            500,
            24,
            8,
            &sys(),
            42,
            crate::featurestore::nvme::NvmeStoreConfig {
                host_frac,
                tier: crate::featurestore::tiered::TierConfig {
                    hot_frac,
                    promote: false,
                    ranking: Some((0..500).collect()),
                    ..Default::default()
                },
            },
        )
        .unwrap()
    }

    #[test]
    fn nvme_at_host_frac_one_matches_tiered_bit_exactly() {
        let idx: Vec<u32> = (0..256u32).map(|i| i * 37 % 500).collect();
        for hot_frac in [0.0, 0.25, 1.0] {
            let (_, ti) = tiered_store(hot_frac).gather(&idx).unwrap();
            let (_, nv) = nvme_store(1.0, hot_frac).gather(&idx).unwrap();
            assert_eq!(nv.time_s, ti.time_s, "hot_frac {hot_frac}");
            assert_eq!(nv.bytes_on_link, ti.bytes_on_link);
            assert_eq!(nv.requests, ti.requests);
            assert_eq!(nv.useful_bytes, ti.useful_bytes);
            assert_eq!(nv.split.storage_bytes, 0, "nothing spills at host_frac 1");
        }
    }

    #[test]
    fn nvme_spill_costs_more_than_host_resident() {
        let idx: Vec<u32> = (0..256u32).map(|i| i * 37 % 500).collect();
        let (_, resident) = nvme_store(1.0, 0.1).gather(&idx).unwrap();
        let (_, spilled) = nvme_store(0.2, 0.1).gather(&idx).unwrap();
        assert!(
            spilled.time_s > resident.time_s,
            "spilled {} !> resident {}",
            spilled.time_s,
            resident.time_s
        );
        assert!(spilled.split.storage_bytes > 0);
    }

    #[test]
    fn nvme_accounts_every_row_across_tiers() {
        let st = nvme_store(0.5, 0.2);
        let idx: Vec<u32> = (0..300u32).map(|i| i * 7 % 500).collect();
        let (_, cost) = st.gather(&idx).unwrap();
        let stats = st.nvme_stats().unwrap();
        assert_eq!(stats.rows_served(), 300);
        assert_eq!(
            cost.split.local_bytes + cost.split.host_bytes + cost.split.storage_bytes,
            cost.useful_bytes
        );
        assert!(stats.amplification() >= 1.0);
        assert_eq!(stats.host_resident_rows, 250);
        assert_eq!(stats.spilled_rows, 250);
    }

    #[test]
    fn pin_rows_reaches_the_hot_tier_and_is_a_noop_elsewhere() {
        let st = tiered_store(0.25);
        st.pin_rows(&[0, 1, 2]);
        assert!(st.tier_stats().unwrap().pins > 0);
        st.unpin_rows(&[0, 1, 2]);
        let ts = st.tier_stats().unwrap();
        assert_eq!(ts.pins, ts.unpins);
        // Modes without a hot tier accept (and ignore) pins.
        let flat = store(AccessMode::UnifiedAligned);
        flat.pin_rows(&[0, 1, 2]);
        flat.unpin_rows(&[0, 1, 2]);
    }

    #[test]
    fn non_nvme_modes_report_no_nvme_stats() {
        assert!(store(AccessMode::UnifiedAligned).nvme_stats().is_none());
        assert!(tiered_store(0.5).nvme_stats().is_none());
        assert!(nvme_store(0.5, 0.2).nvme_stats().is_some());
    }

    fn quantized_store(mode: AccessMode, precision: Precision) -> FeatureStore {
        FeatureStore::build_quantized(500, 24, 8, mode, &sys(), 42, precision, None, None, None)
            .unwrap()
    }

    #[test]
    fn fp32_quantized_builder_is_bit_exact_vs_plain_builder() {
        // The degeneracy anchor: Precision::Fp32 through build_quantized
        // must reproduce the plain builder's values *and* costs exactly.
        let idx: Vec<u32> = (0..128u32).map(|i| i * 37 % 500).collect();
        for mode in AccessMode::all() {
            let (vp, cp) = store(mode).gather(&idx).unwrap();
            let (vq, cq) = quantized_store(mode, Precision::Fp32).gather(&idx).unwrap();
            assert_eq!(vp, vq, "{mode:?} values moved");
            assert_eq!(cp.time_s, cq.time_s, "{mode:?}");
            assert_eq!(cp.bytes_on_link, cq.bytes_on_link, "{mode:?}");
            assert_eq!(cp.requests, cq.requests, "{mode:?}");
            assert_eq!(cp.useful_bytes, cq.useful_bytes, "{mode:?}");
        }
    }

    #[test]
    fn cross_mode_equality_holds_at_every_precision() {
        // Quantize-once-at-build keeps all eight modes bitwise identical
        // to *each other* at any precision; only the fp32 reference moves.
        let idx: Vec<u32> = vec![5, 499, 5, 0, 123, 321, 17];
        for precision in Precision::all() {
            let reference = quantized_store(AccessMode::CpuGather, precision).gather(&idx).unwrap().0;
            for mode in AccessMode::all() {
                let (vals, _) = quantized_store(mode, precision).gather(&idx).unwrap();
                assert_eq!(vals, reference, "{mode:?} at {precision:?}");
            }
        }
    }

    #[test]
    fn narrow_precision_shrinks_stored_and_useful_bytes() {
        let idx: Vec<u32> = (0..200u32).map(|i| i * 13 % 500).collect();
        let mut last_table = u64::MAX;
        let mut last_useful = u64::MAX;
        for precision in Precision::all() {
            let st = quantized_store(AccessMode::UnifiedAligned, precision);
            assert!(st.table_bytes() < last_table, "{precision:?}");
            last_table = st.table_bytes();
            let (_, cost) = st.gather(&idx).unwrap();
            assert!(cost.useful_bytes < last_useful, "{precision:?}");
            last_useful = cost.useful_bytes;
        }
    }

    #[test]
    fn int8_gpu_resident_fits_where_fp32_overflows() {
        // The point of quantized tiers: a table 2.5x over GPU capacity in
        // fp32 fits resident at a quarter of the bytes.
        let mut small = sys();
        small.gpu_mem_bytes = 500 * 24 * 2; // half the fp32 table
        let fp32 = FeatureStore::build_quantized(
            500, 24, 8, AccessMode::GpuResident, &small, 1, Precision::Fp32, None, None, None,
        );
        assert!(matches!(fp32, Err(Error::GpuOom { .. })));
        FeatureStore::build_quantized(
            500, 24, 8, AccessMode::GpuResident, &small, 1, Precision::Int8, None, None, None,
        )
        .unwrap();
    }

    /// Deterministic single-layer batch over this fixture's 500-row table:
    /// destination `j` is `j*7 % 500`, neighbor `k` of `j` is
    /// `(j*13 + k*29) % 500`, all slots masked in.
    fn pushdown_batch(n_dst: usize, fanout: usize) -> crate::sampler::batch::MiniBatch {
        let mut src: Vec<u32> = (0..n_dst as u32).map(|j| j * 7 % 500).collect();
        let mut nbr = Vec::new();
        for j in 0..n_dst {
            for k in 0..fanout {
                nbr.push((n_dst + j * fanout + k) as i32);
                src.push((j as u32 * 13 + k as u32 * 29) % 500);
            }
        }
        crate::sampler::batch::MiniBatch {
            src_nodes: src,
            layers: vec![crate::sampler::batch::LayerBlock {
                n_dst,
                fanout,
                nbr,
                mask: vec![1.0; n_dst * fanout],
            }],
            seeds: vec![0; n_dst],
            labels: vec![0; n_dst],
        }
    }

    #[test]
    fn gpu_resident_pushdown_is_launch_only() {
        // Everything already sits in device memory: push-down moves
        // nothing, reduces nothing near-memory, and costs one launch.
        let st = store(AccessMode::GpuResident);
        let plan = AggregatePlan::build(&pushdown_batch(16, 5)).unwrap();
        let pd = st.pushdown_cost(&plan, true).unwrap();
        assert_eq!(pd.cost.bytes_on_link, 0);
        assert_eq!(pd.agg_rows, 0);
        assert_eq!(pd.near_mem_flops, 0);
        assert_eq!(pd.off_gpu_neighbor_rows, 0);
        assert_eq!(pd.cost.time_s, sys().kernel_launch_s);
    }

    #[test]
    fn pushdown_cuts_link_bytes_vs_raw_gather_when_fanout_amplifies() {
        // The tentpole claim at the store level: shipping one partial per
        // destination beats shipping `fanout` raw neighbor rows on every
        // link-paying single-tier mode (push-down priced *before* the raw
        // gather, so stateful tiers classify the same pre-batch state).
        let mb = pushdown_batch(32, 8);
        let plan = AggregatePlan::build(&mb).unwrap();
        for (label, st) in [
            ("cpu", store(AccessMode::CpuGather)),
            ("naive", store(AccessMode::UnifiedNaive)),
            ("aligned", store(AccessMode::UnifiedAligned)),
            ("tiered", tiered_store(0.2)),
            ("sharded", sharded_store(4, 0.5)),
            ("nvme", nvme_store(0.9, 0.1)),
        ] {
            let pd = st.pushdown_cost(&plan, false).unwrap();
            let raw = st.gather(&mb.src_nodes).unwrap().1;
            assert!(
                pd.cost.bytes_on_link < raw.bytes_on_link,
                "{label}: pushdown {} !< raw {}",
                pd.cost.bytes_on_link,
                raw.bytes_on_link
            );
            assert_eq!(pd.neighbor_rows, 32 * 8);
            assert_eq!(pd.near_mem_flops, pd.off_gpu_neighbor_rows * 24);
        }
    }

    #[test]
    fn pushdown_composes_with_dedup_on_the_self_stream() {
        // Repeated destinations (hub seeds) dedup away from the self
        // stream exactly like any other gather stream.
        let mut mb = pushdown_batch(40, 4);
        for j in 0..40 {
            mb.src_nodes[j] = (j as u32 % 10) * 3; // 10 distinct dsts
        }
        let plan = AggregatePlan::build(&mb).unwrap();
        let st = store(AccessMode::UnifiedAligned);
        let raw = st.pushdown_cost(&plan, false).unwrap();
        let ded = st.pushdown_cost(&plan, true).unwrap();
        assert_eq!(raw.dst_rows, 40);
        assert_eq!(ded.dst_rows, 10);
        assert!(ded.self_bytes_on_link < raw.self_bytes_on_link);
        // The aggregate streams are per-destination-slot either way.
        assert_eq!(ded.agg_bytes_on_link, raw.agg_bytes_on_link);
        assert_eq!(ded.agg_rows, raw.agg_rows);
    }

    #[test]
    fn fully_hot_tiered_pushdown_ships_nothing() {
        let st = tiered_store(1.0);
        let plan = AggregatePlan::build(&pushdown_batch(16, 6)).unwrap();
        let pd = st.pushdown_cost(&plan, true).unwrap();
        assert_eq!(pd.cost.bytes_on_link, 0);
        assert_eq!(pd.agg_rows, 0);
        assert_eq!(pd.near_mem_flops, 0);
    }

    #[test]
    fn pushdown_cost_is_read_only() {
        // Pricing the pushed-down step must not perturb tier state: two
        // calls agree bitwise, and the store's subsequent raw gather costs
        // exactly what a fresh store's does.
        let mb = pushdown_batch(24, 6);
        let plan = AggregatePlan::build(&mb).unwrap();
        for (label, mk) in [
            ("tiered", (|| tiered_store(0.3)) as fn() -> FeatureStore),
            ("sharded", || sharded_store(4, 0.5)),
            ("nvme", || nvme_store(0.8, 0.2)),
        ] {
            let st = mk();
            let a = st.pushdown_cost(&plan, true).unwrap();
            let b = st.pushdown_cost(&plan, true).unwrap();
            assert_eq!(a.cost.bytes_on_link, b.cost.bytes_on_link, "{label}");
            assert_eq!(a.cost.time_s.to_bits(), b.cost.time_s.to_bits(), "{label}");
            assert_eq!(a.agg_rows, b.agg_rows, "{label}");
            let priced = st.gather(&mb.src_nodes).unwrap().1;
            let fresh = mk().gather(&mb.src_nodes).unwrap().1;
            assert_eq!(priced.bytes_on_link, fresh.bytes_on_link, "{label}");
            assert_eq!(priced.time_s.to_bits(), fresh.time_s.to_bits(), "{label}");
        }
    }

    #[test]
    fn sharded_pushdown_ships_peer_partials_and_nvme_pays_block_reads() {
        let plan = AggregatePlan::build(&pushdown_batch(24, 6)).unwrap();
        // 4 GPUs, fully hot shards: neighbors owned elsewhere arrive as
        // NVLink peer partials, none via the host.
        let pd = sharded_store(4, 1.0).pushdown_cost(&plan, true).unwrap();
        assert!(pd.cost.split.peer_bytes > 0);
        assert_eq!(pd.cost.split.host_bytes, 0);
        assert!(pd.agg_rows > 0 && pd.near_mem_flops > 0);
        // Mostly-cold NVMe store: storage-side partials pay their block
        // reads (storage occupancy) and cross the host link as aggregates.
        let pd = nvme_store(0.2, 0.05).pushdown_cost(&plan, true).unwrap();
        assert!(pd.cost.split.storage_bytes_on_link > 0);
        assert!(pd.cost.split.storage_time_s > 0.0);
        assert!(pd.agg_bytes_on_link > 0);
    }

    #[test]
    fn nvme_pushdown_reads_each_shared_block_once() {
        // Self-stream destinations and aggregate-stream neighbors land in
        // the same SSD blocks in this fixture; the step must pay the
        // *union* of their block sets, not the sum (the DESIGN.md §14
        // double-count fix).
        let mb = pushdown_batch(32, 6);
        let plan = AggregatePlan::build(&mb).unwrap();
        let st = nvme_store(0.2, 0.05);
        let pd = st.pushdown_cost(&plan, true).unwrap();

        // Recompute both cold-slot streams from the same residency state.
        let nv = FeatureStore::lock(st.nvme.as_ref().unwrap());
        let row_bytes = st.precision.row_bytes(st.synth.dim);
        let block = sys().nvme.block_bytes;
        let cold = |ids: &[u32]| -> Vec<u32> {
            ids.iter()
                .filter(|&&r| !nv.is_gpu_hot(r))
                .filter_map(|&r| nv.cold_slot(r))
                .collect()
        };
        let gplan = crate::sampler::compact::GatherPlan::build(plan.dst_nodes());
        let self_slots = cold(gplan.unique_nodes());
        let mut nbr_slots = Vec::new();
        for j in 0..plan.n_dst() {
            nbr_slots.extend(cold(plan.neighbor_ids(j)));
        }
        assert!(!self_slots.is_empty() && !nbr_slots.is_empty());

        let link = NvmeLink::new(&sys());
        let self_c = link.read(&count_block_ios(&self_slots, row_bytes, block));
        let agg_c = link.read(&count_block_ios_excluding(
            &nbr_slots, row_bytes, block, &self_slots,
        ));
        assert_eq!(
            pd.cost.split.storage_bytes_on_link,
            self_c.split.storage_bytes_on_link + agg_c.split.storage_bytes_on_link,
            "pushdown must price the aggregate stream net of self-stream blocks"
        );
        // The fixture really overlaps: the naive double-charge is strictly
        // more, and self + excluded-aggregate together equal the union.
        let naive = link.read(&count_block_ios(&nbr_slots, row_bytes, block));
        assert!(agg_c.bytes_on_link < naive.bytes_on_link, "no shared blocks in fixture");
        let union: Vec<u32> = self_slots.iter().chain(&nbr_slots).copied().collect();
        let union_t = count_block_ios(&union, row_bytes, block);
        assert_eq!(
            count_block_ios(&self_slots, row_bytes, block).ios
                + count_block_ios_excluding(&nbr_slots, row_bytes, block, &self_slots).ios,
            union_t.ios
        );
    }

    #[test]
    fn multi_host_gather_and_pushdown_price_the_network() {
        let mb = pushdown_batch(24, 6);
        let plan = AggregatePlan::build(&mb).unwrap();
        // RemoteFetch: foreign-homed rows hit the wire in both the raw
        // gather and the pushed-down step.
        let st = multi_host_store(4, crate::config::FetchStrategy::RemoteFetch);
        let raw = st.gather(&mb.src_nodes).unwrap().1;
        assert!(raw.split.net_bytes > 0);
        let pd = st.pushdown_cost(&plan, true).unwrap();
        assert!(pd.cost.split.net_bytes > 0);
        assert!(pd.cost.split.net_time_s > 0.0);
        // Partials undercut raw remote rows: fanout 6 ships 6 rows raw,
        // one partial (+count) per contributing home pushed down.
        assert!(pd.cost.split.net_bytes_on_link < raw.split.net_bytes_on_link);

        // PartitionLocal halo: bitwise the single-host pricing, both ways.
        let one = multi_host_store(1, crate::config::FetchStrategy::RemoteFetch);
        let halo = multi_host_store(4, crate::config::FetchStrategy::PartitionLocal);
        let c1 = one.gather(&mb.src_nodes).unwrap().1;
        let ch = halo.gather(&mb.src_nodes).unwrap().1;
        assert_eq!(c1.time_s.to_bits(), ch.time_s.to_bits());
        assert_eq!(c1.bytes_on_link, ch.bytes_on_link);
        let p1 = one.pushdown_cost(&plan, true).unwrap();
        let ph = halo.pushdown_cost(&plan, true).unwrap();
        assert_eq!(p1.cost.time_s.to_bits(), ph.cost.time_s.to_bits());
        assert_eq!(p1.cost.bytes_on_link, ph.cost.bytes_on_link);
        assert_eq!(ph.cost.split.net_bytes, 0);
    }
}

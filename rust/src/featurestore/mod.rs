//! Feature store: the node-feature table plus the paper's competing access
//! designs behind one interface.
//!
//! | mode              | storage device | transfer model                       |
//! |-------------------|----------------|--------------------------------------|
//! | `CpuGather` (Py)  | cpu            | host gather -> pinned staging -> DMA |
//! | `UnifiedNaive`    | unified        | zero-copy, unaligned warp stream     |
//! | `UnifiedAligned`  | unified        | zero-copy + circular-shift (§4.5)    |
//! | `Uvm`             | unified        | page-fault migration (§3 strawman)   |
//! | `GpuResident`     | cuda           | in-memory (small graphs only)        |
//! | `Tiered`          | unified        | hot rows free (GPU-resident cache),  |
//! |                   |                | cold rows via the aligned zero-copy  |
//! |                   |                | path (see [`tiered`])                |
//! | `Sharded`         | unified + N gpus | per-GPU hot tiers over shards of  |
//! |                   |                | the table; peer rows over NVLink,    |
//! |                   |                | cold rows via the host zero-copy     |
//! |                   |                | path (see [`sharded`])               |
//!
//! Feature values are synthesized deterministically per node such that the
//! classification task is *learnable* (the first `classes` dimensions carry
//! a noisy one-hot of the label) — the end-to-end example's loss curve is
//! real learning, not noise fitting.  Whatever the access mode, the table
//! is a single source of truth: tier and shard structures are placement
//! metadata only, so numerics are bitwise identical across modes
//! (DESIGN.md §5).

pub mod sharded;
pub mod staging;
pub mod store;
pub mod synth;
pub mod tiered;

pub use sharded::{assign_owners, GpuShardStats, ShardConfig, ShardStats, ShardedStore};
pub use staging::StagingPool;
pub use store::FeatureStore;
pub use synth::SyntheticFeatures;
pub use tiered::{degree_ranking, TierConfig, TierStats, TieredCache};

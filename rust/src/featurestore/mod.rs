//! Feature store: the node-feature table plus the paper's competing access
//! designs behind one interface.
//!
//! | mode              | storage device | transfer model                       |
//! |-------------------|----------------|--------------------------------------|
//! | `CpuGather` (Py)  | cpu            | host gather -> pinned staging -> DMA |
//! | `UnifiedNaive`    | unified        | zero-copy, unaligned warp stream     |
//! | `UnifiedAligned`  | unified        | zero-copy + circular-shift (§4.5)    |
//! | `Uvm`             | unified        | page-fault migration (§3 strawman)   |
//! | `GpuResident`     | cuda           | in-memory (small graphs only)        |
//! | `Tiered`          | unified        | hot rows free (GPU-resident cache),  |
//! |                   |                | cold rows via the aligned zero-copy  |
//! |                   |                | path (see [`tiered`])                |
//! | `Sharded`         | unified + N gpus | per-GPU hot tiers over shards of  |
//! |                   |                | the table; peer rows over NVLink,    |
//! |                   |                | cold rows via the host zero-copy     |
//! |                   |                | path (see [`sharded`])               |
//! | `Nvme`            | unified + nvme | GPU hot tier over a `host_frac`-     |
//! |                   |                | bounded host tier; spilled rows via  |
//! |                   |                | GPU-initiated block reads (see       |
//! |                   |                | [`nvme`])                            |
//!
//! Feature values are synthesized deterministically per node such that the
//! classification task is *learnable* (the first `classes` dimensions carry
//! a noisy one-hot of the label) — the end-to-end example's loss curve is
//! real learning, not noise fitting.  Whatever the access mode, the table
//! is a single source of truth: tier, shard, and storage structures are
//! placement metadata only, so numerics are bitwise identical across modes
//! (DESIGN.md §5).
//!
//! ```
//! use ptdirect::config::{AccessMode, SystemProfile};
//! use ptdirect::featurestore::FeatureStore;
//!
//! // 500 rows × 24 f32, gathered through the zero-copy unified design.
//! let sys = SystemProfile::system1();
//! let store = FeatureStore::build(500, 24, 8, AccessMode::UnifiedAligned, &sys, 42).unwrap();
//! let (values, cost) = store.gather(&[5, 499, 5]).unwrap();
//! assert_eq!(values.len(), 3 * 24);
//! assert_eq!(cost.useful_bytes, 3 * 24 * 4);
//! // Same indices, any mode → bitwise identical values (only cost moves).
//! let gpu = FeatureStore::build(500, 24, 8, AccessMode::GpuResident, &sys, 42).unwrap();
//! assert_eq!(gpu.gather(&[5, 499, 5]).unwrap().0, values);
//! ```

pub mod nvme;
pub mod pagecache;
pub mod placement;
pub mod quant;
pub mod sharded;
pub mod staging;
pub mod store;
pub mod synth;
pub mod tiered;

pub use nvme::{NvmeStats, NvmeStore, NvmeStoreConfig};
pub use pagecache::{Admission, EvictionEngine, PageCache, PageView};
pub use sharded::{assign_owners, GpuShardStats, ShardConfig, ShardStats, ShardedStore};
pub use staging::StagingPool;
pub use store::{FeatureStore, PushdownCost};
pub use synth::SyntheticFeatures;
pub use tiered::{degree_ranking, TierConfig, TierStats, TieredCache};

//! Shared degree-ranking placement helpers for the tiered memory
//! hierarchy.
//!
//! Three stores place rows by walking a hottest-first ranking (descending
//! node degree, [`degree_ranking`]) and keeping a bounded prefix:
//!
//! * the tiered cache pre-seeds its GPU hot set from the ranking prefix,
//! * the sharded store seeds each GPU from the global ranking restricted
//!   to that GPU's shard,
//! * the NVMe store keeps the ranking prefix host-resident and spills the
//!   tail to storage.
//!
//! Each used to re-derive the prefix walk inline; this module is the one
//! implementation they share, so the "hottest rows sit highest in the
//! hierarchy" rule (Data Tiering, arXiv:2111.05894) stays a single piece
//! of arithmetic.
//!
//! ```
//! use ptdirect::featurestore::placement::{ranked_prefix, ranked_prefix_mask};
//!
//! // Hottest-first ranking over a 6-row table; keep the top 3.
//! let ranking = [4u32, 4, 9, 1, 0, 2]; // duplicates and out-of-range ignored
//! assert_eq!(ranked_prefix(6, 3, &ranking), vec![4, 1, 0]);
//!
//! // Mask form with id-order fallback: a missing ranking still fills cap.
//! let mask = ranked_prefix_mask(6, 3, None);
//! assert_eq!(mask, vec![true, true, true, false, false, false]);
//! ```
//!
//! [`degree_ranking`]: crate::featurestore::tiered::degree_ranking

/// First `cap` *distinct, in-range* row ids of `ranking`, in ranking
/// order.  Duplicates and out-of-range entries are skipped (not counted
/// against `cap`), so a noisy ranking still yields a full prefix whenever
/// it covers enough rows.
pub fn ranked_prefix(rows: usize, cap: usize, ranking: &[u32]) -> Vec<u32> {
    let cap = cap.min(rows);
    let mut chosen = vec![false; rows];
    let mut prefix = Vec::with_capacity(cap);
    for &v in ranking {
        if prefix.len() >= cap {
            break;
        }
        let vi = v as usize;
        if vi < rows && !chosen[vi] {
            chosen[vi] = true;
            prefix.push(v);
        }
    }
    prefix
}

/// Membership mask of the ranked prefix, filled to exactly
/// `min(cap, rows)` rows by an id-order fallback — a missing or short
/// ranking never shrinks the placement below its budget (the NVMe host
/// tier leans on this: `host_frac` always bounds the host/storage split).
pub fn ranked_prefix_mask(rows: usize, cap: usize, ranking: Option<&[u32]>) -> Vec<bool> {
    let cap = cap.min(rows);
    let mut mask = vec![false; rows];
    let mut marked = 0usize;
    if let Some(rk) = ranking {
        for v in ranked_prefix(rows, cap, rk) {
            mask[v as usize] = true;
            marked += 1;
        }
    }
    for m in mask.iter_mut() {
        if marked >= cap {
            break;
        }
        if !*m {
            *m = true;
            marked += 1;
        }
    }
    mask
}

/// Restrict a global hottest-first `ranking` to the rows `owner` assigns
/// to `gpu` (out-of-range entries dropped, order preserved) — each GPU of
/// the sharded store seeds its hot tier from this slice, so the hottest
/// *owned* rows go hot first.
pub fn shard_slice(rows: usize, ranking: &[u32], owner: &[u8], gpu: u8) -> Vec<u32> {
    ranking
        .iter()
        .copied()
        .filter(|&r| (r as usize) < rows && owner[r as usize] == gpu)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_takes_ranking_order() {
        assert_eq!(ranked_prefix(10, 3, &[7, 3, 9, 1]), vec![7, 3, 9]);
        assert_eq!(ranked_prefix(10, 8, &[7, 3]), vec![7, 3]);
    }

    #[test]
    fn prefix_skips_duplicates_and_out_of_range_without_losing_budget() {
        // Duplicates and out-of-range ids don't consume cap slots.
        assert_eq!(ranked_prefix(5, 3, &[4, 4, 99, 1, 0, 2]), vec![4, 1, 0]);
    }

    #[test]
    fn prefix_cap_clamps_to_rows() {
        assert_eq!(ranked_prefix(2, 10, &[1, 0, 1]), vec![1, 0]);
        assert!(ranked_prefix(5, 0, &[1, 2, 3]).is_empty());
    }

    #[test]
    fn mask_marks_the_prefix() {
        let mask = ranked_prefix_mask(5, 2, Some(&[3, 1, 0]));
        assert_eq!(mask, vec![false, true, false, true, false]);
    }

    #[test]
    fn mask_falls_back_to_id_order() {
        // No ranking: the first `cap` ids fill in.
        assert_eq!(
            ranked_prefix_mask(5, 3, None),
            vec![true, true, true, false, false]
        );
        // Short ranking: its rows first, id order tops up to cap.
        let mask = ranked_prefix_mask(5, 3, Some(&[4]));
        assert_eq!(mask, vec![true, true, false, false, true]);
    }

    #[test]
    fn mask_always_marks_exactly_cap_rows() {
        for cap in 0..=6 {
            let mask = ranked_prefix_mask(4, cap, Some(&[2, 2, 9, 0]));
            assert_eq!(
                mask.iter().filter(|&&m| m).count(),
                cap.min(4),
                "cap {cap}"
            );
        }
    }

    #[test]
    fn shard_slice_keeps_order_and_ownership() {
        let owner = vec![0u8, 1, 0, 1, 0];
        let ranking = vec![3u32, 0, 99, 4, 1, 2];
        assert_eq!(shard_slice(5, &ranking, &owner, 0), vec![0, 4, 2]);
        assert_eq!(shard_slice(5, &ranking, &owner, 1), vec![3, 1]);
        assert!(shard_slice(5, &ranking, &owner, 2).is_empty());
    }
}

//! Three-tier storage feature store: GPU hot tier over a bounded host
//! unified tier over an NVMe cold store (DESIGN.md §8).
//!
//! The paper's unified tensors assume the feature table fits in host
//! memory; GIDS (arXiv:2306.16384) drops that assumption by letting GPU
//! threads read NVMe blocks directly, and Data Tiering (arXiv:2111.05894)
//! shows the degree-skew argument generalizes across tiers: the hotter a
//! row, the higher up the hierarchy it belongs.  [`NvmeStore`] composes
//! the three tiers:
//!
//! | tier    | holds                                   | cost model         |
//! |---------|------------------------------------------|--------------------|
//! | GPU hot | hottest rows ([`TieredCache`], `hot_frac`) | kernel launch only |
//! | host    | degree-ranking prefix, `host_frac` of rows | PCIe zero-copy     |
//! | NVMe    | everything that spilled                  | [`NvmeLink`] blocks |
//!
//! Placement is static and degree-ranked: the hottest `host_frac · rows`
//! rows (by the supplied ranking) stay host-resident; the rest spill to
//! the cold store, which packs spilled rows in **id order** so
//! neighboring rows share 4 KiB blocks (read coalescing,
//! [`count_block_ios`]).  The GPU hot tier floats above both with the
//! unchanged [`TieredCache`] machinery — LFU promotion can pull a
//! storage-resident row all the way into GPU memory, exactly the GIDS
//! GPU-cache-over-storage design.
//!
//! Like every other mode, this is placement metadata only: the single
//! unified table remains the source of truth, numerics are bitwise
//! identical, and only the [`TransferCost`] attribution changes.  The
//! storage read and the host zero-copy read *serialize* on the simulated
//! host link (the SSD hangs off the same PCIe root complex the zero-copy
//! reads traverse), so a step costs one kernel launch plus the sum of the
//! two launch-free link occupancies — which makes `host_frac = 1`
//! degenerate bit-exactly to the tiered cost model (no storage term at
//! all), the endpoint contract `benches/storage_sweep.rs` pins.
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::featurestore::{NvmeStore, NvmeStoreConfig, TierConfig};
//!
//! // 100-row table, 516 B rows, no GPU cache, 40% host-resident.
//! let sys = SystemProfile::system1();
//! let cfg = NvmeStoreConfig {
//!     host_frac: 0.4,
//!     tier: TierConfig { hot_frac: 0.0, ranking: None, ..TierConfig::default() },
//! };
//! let mut store = NvmeStore::new(100, 516, &sys, &cfg);
//! assert_eq!(store.host_resident_rows(), 40);
//! let cost = store.gather_cost(&[0, 50, 99], 129, &sys);
//! assert_eq!(cost.split.host_bytes, 516);        // row 0 is host-resident
//! assert_eq!(cost.split.storage_bytes, 2 * 516); // rows 50, 99 spilled
//! assert!(store.stats().amplification() >= 1.0);
//! ```
//!
//! [`TransferCost`]: crate::interconnect::TransferCost
//! [`NvmeLink`]: crate::interconnect::NvmeLink
//! [`count_block_ios`]: crate::interconnect::count_block_ios

use crate::config::{RunConfig, SystemProfile};
use crate::device::warp::{count_requests, WarpModel};
use crate::featurestore::placement;
use crate::featurestore::tiered::{TierConfig, TierStats, TieredCache};
use crate::graph::Csr;
use crate::interconnect::{count_block_ios, NvmeLink, PathSplit, PcieLink, TransferCost};

/// Placement + capacity knobs for the three-tier store.
#[derive(Clone, Debug)]
pub struct NvmeStoreConfig {
    /// Fraction of the table's rows host memory holds, in [0, 1].  The
    /// degree-ranking prefix stays host-resident; the rest spill to NVMe.
    /// `1.0` keeps everything in host memory (bit-exact `Tiered`
    /// degeneracy); `0.0` spills the whole table.
    pub host_frac: f64,
    /// GPU hot-tier knobs (the unchanged tiered machinery on top).
    pub tier: TierConfig,
}

impl Default for NvmeStoreConfig {
    fn default() -> Self {
        NvmeStoreConfig {
            host_frac: 0.5,
            tier: TierConfig::default(),
        }
    }
}

impl NvmeStoreConfig {
    /// Derive the storage configuration a training run wants: the run's
    /// `host_frac` knob plus the tier knobs (degree ranking from the
    /// graph, `hot_frac`, reserve, promotion).
    pub fn from_run(cfg: &RunConfig, graph: &Csr) -> NvmeStoreConfig {
        NvmeStoreConfig {
            host_frac: cfg.host_frac,
            tier: TierConfig::from_run(cfg, graph),
        }
    }
}

/// Counters and gauges of the three-tier store (counters cumulative;
/// per-epoch deltas via [`NvmeStats::since`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NvmeStats {
    /// GPU hot-tier counters/gauges (`tier.hits` are GPU-served rows;
    /// `tier.misses` split into `host_rows + storage_rows` below).
    pub tier: TierStats,
    /// Cold rows served from host memory over the PCIe zero-copy path.
    pub host_rows: u64,
    /// Cold rows read from the NVMe store.
    pub storage_rows: u64,
    /// NVMe read commands (block reads) issued.
    pub ios: u64,
    /// Useful bytes of storage-served rows (requested basis).
    pub storage_bytes: u64,
    /// Block-granular bytes the SSD read (`ios × block_bytes`).
    pub storage_bytes_on_link: u64,
    /// Distinct-row payload behind the storage reads (the amplification
    /// denominator; see [`NvmeTraffic`](crate::interconnect::NvmeTraffic)).
    pub storage_distinct_bytes: u64,
    /// Distinct cold *cache pages* behind the gathers (summed per gather).
    /// When `row_bytes × page_rows == block_bytes` and every cold row is
    /// storage-resident (`host_frac = 0`), pages line up 1:1 with the
    /// storage blocks and `cold_pages == ios` — the alignment contract
    /// `page_reads_line_up_with_block_ios` pins.
    pub cold_pages: u64,
    /// Rows resident in host memory / spilled to storage (gauges).
    pub host_resident_rows: usize,
    pub spilled_rows: usize,
}

impl NvmeStats {
    /// Rows served across all three tiers.
    pub fn rows_served(&self) -> u64 {
        self.tier.hits + self.host_rows + self.storage_rows
    }

    /// Fraction of requested rows served from the GPU hot tier.
    pub fn hit_rate(&self) -> f64 {
        self.tier.hit_rate()
    }

    /// Cumulative block-read I/O amplification (≥ 1 whenever storage was
    /// touched; 1.0 on a storage-quiet epoch).
    pub fn amplification(&self) -> f64 {
        if self.storage_distinct_bytes == 0 {
            1.0
        } else {
            self.storage_bytes_on_link as f64 / self.storage_distinct_bytes as f64
        }
    }

    /// Counter deltas relative to an `earlier` snapshot; gauges keep their
    /// current (end-state) values.
    pub fn since(&self, earlier: &NvmeStats) -> NvmeStats {
        NvmeStats {
            tier: self.tier.since(&earlier.tier),
            host_rows: self.host_rows - earlier.host_rows,
            storage_rows: self.storage_rows - earlier.storage_rows,
            ios: self.ios - earlier.ios,
            storage_bytes: self.storage_bytes - earlier.storage_bytes,
            storage_bytes_on_link: self.storage_bytes_on_link - earlier.storage_bytes_on_link,
            storage_distinct_bytes: self.storage_distinct_bytes
                - earlier.storage_distinct_bytes,
            cold_pages: self.cold_pages - earlier.cold_pages,
            ..*self
        }
    }
}

/// Placement metadata + tier machinery for one feature table with an NVMe
/// cold store underneath.
#[derive(Debug)]
pub struct NvmeStore {
    /// GPU hot tier over the whole table (global row ids, like the
    /// sharded store's per-GPU tiers).
    cache: TieredCache,
    /// Per-row cold-store slot: `u32::MAX` marks a host-resident row;
    /// spilled rows get consecutive slots in id order, so rows adjacent
    /// in the table stay adjacent on disk and their block reads coalesce.
    slot: Vec<u32>,
    row_bytes: u64,
    host_resident_rows: usize,
    spilled_rows: usize,
    /// Cumulative counters (gauges derive from `cache` + placement).
    host_rows: u64,
    storage_rows: u64,
    ios: u64,
    storage_bytes: u64,
    storage_bytes_on_link: u64,
    storage_distinct_bytes: u64,
    cold_pages: u64,
}

const HOST_RESIDENT: u32 = u32::MAX;

impl NvmeStore {
    /// Build placement + tiers for a `rows`-row table of `row_bytes`-byte
    /// rows: the first `host_frac · rows` entries of the ranking stay
    /// host-resident (id order when no ranking is supplied), the rest
    /// spill to packed cold-store slots; the GPU hot tier sits on top with
    /// the unchanged [`TieredCache`] capacity rules.
    pub fn new(
        rows: usize,
        row_bytes: u64,
        sys: &SystemProfile,
        cfg: &NvmeStoreConfig,
    ) -> NvmeStore {
        let cache = TieredCache::new(rows, row_bytes, sys, &cfg.tier);
        let host_cap = (cfg.host_frac.clamp(0.0, 1.0) * rows as f64).floor() as usize;
        // Ranked prefix with id-order fallback (shared placement helper),
        // so `host_frac` always bounds the host/storage split.
        let host =
            placement::ranked_prefix_mask(rows, host_cap, cfg.tier.ranking.as_deref());
        let marked = host.iter().filter(|&&h| h).count();
        let mut slot = vec![HOST_RESIDENT; rows];
        let mut next = 0u32;
        for (r, s) in slot.iter_mut().enumerate() {
            if !host[r] {
                *s = next;
                next += 1;
            }
        }
        NvmeStore {
            cache,
            slot,
            row_bytes,
            host_resident_rows: marked,
            spilled_rows: rows - marked,
            host_rows: 0,
            storage_rows: 0,
            ios: 0,
            storage_bytes: 0,
            storage_bytes_on_link: 0,
            storage_distinct_bytes: 0,
            cold_pages: 0,
        }
    }

    /// Whether a row lives in host memory (vs the NVMe store).  The GPU
    /// hot tier is orthogonal — a spilled row can still be cached hot.
    pub fn is_host_resident(&self, row: u32) -> bool {
        self.slot[row as usize] == HOST_RESIDENT
    }

    /// Whether `row` currently sits in the GPU hot tier — the read-only
    /// pre-step residency view [`NvmeStore::gather_cost`] classifies
    /// against before recording.  The push-down classifier
    /// (`FeatureStore::pushdown_cost`, DESIGN.md §14) uses it to replicate
    /// that classification without mutating tier state.
    pub fn is_gpu_hot(&self, row: u32) -> bool {
        self.cache.is_hot(row)
    }

    /// Cold-store slot of `row`, or `None` when it is host-resident — the
    /// read-only placement view the push-down classifier prices storage
    /// block IOs from (same slots [`NvmeStore::gather_cost`] feeds
    /// [`count_block_ios`]).
    pub fn cold_slot(&self, row: u32) -> Option<u32> {
        let s = self.slot[row as usize];
        if s == HOST_RESIDENT {
            None
        } else {
            Some(s)
        }
    }

    pub fn host_resident_rows(&self) -> usize {
        self.host_resident_rows
    }

    pub fn spilled_rows(&self) -> usize {
        self.spilled_rows
    }

    /// Snapshot of counters + gauges.
    pub fn stats(&self) -> NvmeStats {
        NvmeStats {
            tier: self.cache.stats(),
            host_rows: self.host_rows,
            storage_rows: self.storage_rows,
            ios: self.ios,
            storage_bytes: self.storage_bytes,
            storage_bytes_on_link: self.storage_bytes_on_link,
            storage_distinct_bytes: self.storage_distinct_bytes,
            cold_pages: self.cold_pages,
            host_resident_rows: self.host_resident_rows,
            spilled_rows: self.spilled_rows,
        }
    }

    /// Pin the pages covering `idx` in the GPU hot tier; pair with
    /// [`NvmeStore::unpin_rows`].
    pub fn pin_rows(&mut self, idx: &[u32]) {
        self.cache.pin_rows(idx);
    }

    /// Release the pins [`NvmeStore::pin_rows`] took.
    pub fn unpin_rows(&mut self, idx: &[u32]) {
        self.cache.unpin_rows(idx);
    }

    /// Account one gather step and return its simulated cost.
    ///
    /// The hot tier splits off its hits first (unchanged [`TieredCache`]
    /// accounting, promotions included); the cold remainder partitions by
    /// residency into a host zero-copy stream (order preserved — it is
    /// the warp request sequence) and a storage block-read set.  One
    /// gather kernel serves all tiers, and the two launch-free link
    /// occupancies serialize on the shared PCIe root:
    ///
    /// ```text
    /// time = kernel_launch + host_link_time + storage_link_time
    /// ```
    ///
    /// Under the default gather deduplication (DESIGN.md §10) `idx` is
    /// the batch's compacted unique stream: [`count_block_ios`] already
    /// coalesced duplicate rows into shared blocks *within* one gather,
    /// but compaction removes the duplicates from the hot-tier and host
    /// accounting too, and shrinks the host zero-copy stream the same
    /// way it does for the single-tier modes.  `--no-dedup` restores the
    /// per-occurrence stream.
    pub fn gather_cost(
        &mut self,
        idx: &[u32],
        feat_elems: u64,
        sys: &SystemProfile,
    ) -> TransferCost {
        let useful = idx.len() as u64 * self.row_bytes;
        let cold = self.cache.record(idx);
        if cold.is_empty() {
            // Entire batch in the GPU hot tier: device-memory gather,
            // kernel launch only — identical to the tiered fast path.
            return TransferCost {
                time_s: sys.kernel_launch_s,
                bytes_on_link: 0,
                useful_bytes: useful,
                requests: 0,
                cpu_time_s: 0.0,
                split: PathSplit {
                    local_bytes: useful,
                    ..PathSplit::default()
                },
            };
        }
        // Distinct cold pages this gather touched (the page-granular read
        // set; aligns 1:1 with storage block IOs when a page is a block).
        let pr = self.cache.page_rows().max(1) as u32;
        let mut pages: Vec<u32> = cold.iter().map(|&r| r / pr).collect();
        pages.sort_unstable();
        pages.dedup();
        self.cold_pages += pages.len() as u64;

        let mut host_stream = Vec::new();
        let mut storage_slots = Vec::new();
        for &r in &cold {
            let s = self.slot[r as usize];
            if s == HOST_RESIDENT {
                host_stream.push(r);
            } else {
                storage_slots.push(s);
            }
        }

        let mut time_s = sys.kernel_launch_s;
        let mut bytes_on_link = 0u64;
        let mut requests = 0u64;
        let mut split = PathSplit::default();

        if !host_stream.is_empty() {
            // Same arithmetic as the tiered cold path (aligned zero-copy),
            // so `host_frac = 1` reproduces `Tiered` bit-exactly; the
            // storage precision is recovered from the constructor's row
            // width so fp16/int8 rows narrow the host stream too.
            let model = WarpModel::for_row_layout(self.row_bytes, feat_elems);
            let shifted = model.shift_applies(feat_elems);
            let c = PcieLink::new(sys)
                .direct_gather(&count_requests(&host_stream, feat_elems, model, shifted));
            time_s += c.split.host_time_s;
            bytes_on_link += c.bytes_on_link;
            requests += c.requests;
            split.host_bytes = c.split.host_bytes;
            split.host_bytes_on_link = c.split.host_bytes_on_link;
            split.host_time_s = c.split.host_time_s;
        }
        if !storage_slots.is_empty() {
            let traffic = count_block_ios(&storage_slots, self.row_bytes, sys.nvme.block_bytes);
            let c = NvmeLink::new(sys).read(&traffic);
            time_s += c.split.storage_time_s;
            bytes_on_link += c.bytes_on_link;
            requests += c.requests;
            split.storage_bytes = c.split.storage_bytes;
            split.storage_bytes_on_link = c.split.storage_bytes_on_link;
            split.storage_time_s = c.split.storage_time_s;
            self.ios += traffic.ios;
            self.storage_bytes += traffic.useful_bytes;
            self.storage_bytes_on_link += traffic.bytes_on_link;
            self.storage_distinct_bytes += traffic.distinct_bytes;
        }
        self.host_rows += host_stream.len() as u64;
        self.storage_rows += storage_slots.len() as u64;
        split.local_bytes = useful - split.host_bytes - split.storage_bytes;

        TransferCost {
            time_s,
            bytes_on_link,
            useful_bytes: useful,
            requests,
            cpu_time_s: 0.0,
            split,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    fn cfg(host_frac: f64, hot_frac: f64, ranking: Option<Vec<u32>>) -> NvmeStoreConfig {
        NvmeStoreConfig {
            host_frac,
            tier: TierConfig {
                hot_frac,
                promote: false,
                ranking,
                ..TierConfig::default()
            },
        }
    }

    #[test]
    fn ranking_prefix_stays_host_resident() {
        let ranking = vec![7u32, 3, 9, 1];
        let st = NvmeStore::new(10, 64, &sys(), &cfg(0.2, 0.0, Some(ranking)));
        assert_eq!(st.host_resident_rows(), 2);
        assert_eq!(st.spilled_rows(), 8);
        assert!(st.is_host_resident(7) && st.is_host_resident(3));
        assert!(!st.is_host_resident(9) && !st.is_host_resident(1));
    }

    #[test]
    fn missing_ranking_falls_back_to_id_order() {
        let st = NvmeStore::new(10, 64, &sys(), &cfg(0.3, 0.0, None));
        assert_eq!(st.host_resident_rows(), 3);
        assert!(st.is_host_resident(0) && st.is_host_resident(2));
        assert!(!st.is_host_resident(3));
    }

    #[test]
    fn spilled_slots_are_packed_in_id_order() {
        // host_frac 0: every row spills; slots must equal row ids.
        let st = NvmeStore::new(8, 64, &sys(), &cfg(0.0, 0.0, None));
        for r in 0..8u32 {
            assert_eq!(st.slot[r as usize], r);
        }
        // With rows 0..2 host-resident, rows 3.. pack from slot 0.
        let st = NvmeStore::new(8, 64, &sys(), &cfg(0.375, 0.0, None));
        assert_eq!(st.slot[3], 0);
        assert_eq!(st.slot[7], 4);
    }

    #[test]
    fn host_frac_endpoints_cover_everything_or_nothing() {
        let all_host = NvmeStore::new(100, 64, &sys(), &cfg(1.0, 0.0, None));
        assert_eq!(all_host.spilled_rows(), 0);
        let none_host = NvmeStore::new(100, 64, &sys(), &cfg(0.0, 0.0, None));
        assert_eq!(none_host.host_resident_rows(), 0);
        assert_eq!(none_host.spilled_rows(), 100);
    }

    #[test]
    fn rows_conserve_across_the_three_tiers() {
        let ranking: Vec<u32> = (0..200).collect();
        let mut st = NvmeStore::new(200, 64, &sys(), &cfg(0.5, 0.2, Some(ranking)));
        let idx: Vec<u32> = (0..300u32).map(|i| i * 7 % 200).collect();
        let c = st.gather_cost(&idx, 16, &sys());
        let s = st.stats();
        assert_eq!(s.rows_served(), 300);
        assert!(s.tier.hits > 0 && s.host_rows > 0 && s.storage_rows > 0);
        assert_eq!(
            c.split.local_bytes + c.split.host_bytes + c.split.storage_bytes,
            c.useful_bytes
        );
        assert!(s.amplification() >= 1.0);
    }

    #[test]
    fn compacted_stream_cuts_host_bytes_and_never_rereads_blocks() {
        // Duplicated vs compacted stream on fresh identical stores: the
        // storage tier already reads each block once per gather (the
        // count_block_ios coalescing), so the strict win comes from the
        // host zero-copy stream — and the combined link bytes must drop.
        let ranking: Vec<u32> = (0..200).collect();
        let duplicated: Vec<u32> = (0..400u32).map(|i| i * 7 % 100).collect();
        let plan = crate::sampler::compact::GatherPlan::build(&duplicated);
        let mut dup_store =
            NvmeStore::new(200, 516, &sys(), &cfg(0.25, 0.0, Some(ranking.clone())));
        let mut ded_store = NvmeStore::new(200, 516, &sys(), &cfg(0.25, 0.0, Some(ranking)));
        let c_dup = dup_store.gather_cost(&duplicated, 129, &sys());
        let c_ded = ded_store.gather_cost(plan.unique_nodes(), 129, &sys());
        assert!(
            c_ded.bytes_on_link < c_dup.bytes_on_link,
            "dedup {} !< naive {}",
            c_ded.bytes_on_link,
            c_dup.bytes_on_link
        );
        assert!(c_ded.time_s <= c_dup.time_s);
        // Both tiers see traffic, and the dedup'd storage reads stay
        // block-deduplicated (ios identical: same distinct blocks).
        assert_eq!(dup_store.stats().ios, ded_store.stats().ios);
        assert_eq!(ded_store.stats().rows_served(), 100);
    }

    #[test]
    fn fully_hot_batch_costs_kernel_launch_only() {
        let ranking: Vec<u32> = (0..50).collect();
        let mut st = NvmeStore::new(50, 64, &sys(), &cfg(0.0, 1.0, Some(ranking)));
        let idx: Vec<u32> = (0..50).collect();
        let c = st.gather_cost(&idx, 16, &sys());
        assert_eq!(c.time_s, sys().kernel_launch_s);
        assert_eq!(c.bytes_on_link, 0);
        assert_eq!(st.stats().storage_rows, 0);
    }

    #[test]
    fn storage_time_serializes_after_host_time() {
        // Half the cold rows on storage: step time must carry both link
        // occupancies on top of the one launch.
        let ranking: Vec<u32> = (0..100).collect();
        let mut st = NvmeStore::new(100, 516, &sys(), &cfg(0.5, 0.0, Some(ranking)));
        let idx: Vec<u32> = (0..100).collect();
        let c = st.gather_cost(&idx, 129, &sys());
        assert!(c.split.host_time_s > 0.0);
        assert!(c.split.storage_time_s > 0.0);
        let want = sys().kernel_launch_s + c.split.host_time_s + c.split.storage_time_s;
        assert!((c.time_s - want).abs() < 1e-15);
    }

    #[test]
    fn page_reads_line_up_with_block_ios() {
        // 512 B rows at 8 rows/page: one cache page == one 4096 B NVMe
        // block.  With host_frac 0 every cold row is storage-resident and
        // slots equal row ids, so the distinct cold pages of each gather
        // must line up 1:1 with its block IOs.
        assert_eq!(sys().nvme.block_bytes, 512 * 8);
        let mut c = cfg(0.0, 0.25, Some((0..128).collect()));
        c.tier.page_rows = 8;
        let mut st = NvmeStore::new(128, 512, &sys(), &c);
        let idx: Vec<u32> = (0..300u32).map(|i| i * 11 % 128).collect();
        st.gather_cost(&idx, 128, &sys());
        st.gather_cost(&idx, 128, &sys());
        let s = st.stats();
        assert!(s.ios > 0);
        assert_eq!(s.ios, s.cold_pages, "cold pages must line up 1:1 with block IOs");
    }

    #[test]
    fn pins_forward_to_the_gpu_tier_and_balance() {
        let mut st = NvmeStore::new(100, 64, &sys(), &cfg(0.5, 0.2, Some((0..100).collect())));
        st.pin_rows(&[0, 1, 50, 99]);
        assert!(st.stats().tier.pins > 0);
        st.unpin_rows(&[0, 1, 50, 99]);
        let t = st.stats().tier;
        assert_eq!(t.pins, t.unpins);
    }

    #[test]
    fn stats_since_gives_epoch_deltas() {
        let mut st = NvmeStore::new(100, 64, &sys(), &cfg(0.5, 0.0, None));
        st.gather_cost(&(0..100u32).collect::<Vec<_>>(), 16, &sys());
        let snap = st.stats();
        st.gather_cost(&(0..50u32).collect::<Vec<_>>(), 16, &sys());
        let d = st.stats().since(&snap);
        assert_eq!(d.host_rows + d.storage_rows + d.tier.hits, 50);
        assert!(d.ios > 0);
    }
}

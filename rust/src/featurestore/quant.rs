//! Quantized cold-tier storage formats (`--precision`, DESIGN.md §13).
//!
//! The Data Tiering follow-up (arXiv:2111.05894) observes that after
//! placement has done its work, the remaining lever on the bottleneck
//! link is the *row width itself*: storing cold features as fp16 or int8
//! halves or quarters every byte that crosses PCIe/NVLink/NVMe, at a
//! bounded numeric cost.  This module owns the two storage formats:
//!
//! * **fp16** — IEEE 754 binary16, round-to-nearest-even, implemented by
//!   hand on the bit patterns (no `half` crate in the offline build).
//!   Exact for every value with ≤ 11 significand bits inside the normal
//!   range `[2⁻¹⁴, 65504]`; relative error ≤ 2⁻¹¹ otherwise.
//! * **int8** — affine per-row quantization: `q = round((x − zp) / scale)`
//!   with `zp = row_min` and `scale = (row_max − row_min) / 255`, both
//!   computed **once at table build**.  Element error ≤ `scale / 2`
//!   (plus f32 arithmetic epsilon); a constant row (`scale = 0`) is
//!   stored exactly.
//!
//! The repo's core invariant — bitwise-identical numerics across all
//! eight access modes — survives by construction: [`quantize_table`]
//! round-trips the whole synthetic table through the storage format
//! *before* any mode sees it, so every mode gathers the same
//! already-dequantized values.  Only the fp32 *reference* moves (within
//! the bounds above), which is where the tolerance-based comparator of
//! `util::approx` takes over from `assert_eq!` on bits
//! (`tests/quant_properties.rs`).  `Precision::Fp32` is the identity
//! round-trip: bit-exact, the newest link of the degeneracy chain.
//!
//! The int8 side table (one `(zero_point, scale)` f32 pair per row, 8 B)
//! lives in GPU memory next to the dequant kernel and is *not* counted
//! against the link budget — it crosses once at load, is ≪ 1% of the
//! table for any realistic `dim`, and never moves per-gather.
//!
//! ```
//! use ptdirect::config::Precision;
//! use ptdirect::featurestore::quant::{self, quantize_table};
//!
//! let mut rows = vec![1.5f32, -0.25, 1024.0, 0.1]; // one 4-wide row
//! let before = rows.clone();
//! quantize_table(&mut rows, 4, Precision::Fp16);
//! assert_eq!(&rows[..3], &before[..3]); // ≤ 11-bit values are exact
//! assert!((rows[3] - 0.1).abs() < 1e-4); // 0.1 rounds to the nearest half
//! assert_eq!(quant::f16_round_trip(f32::INFINITY), f32::INFINITY);
//! ```

use crate::config::Precision;

/// Convert an `f32` to IEEE 754 binary16 bits, round-to-nearest-even.
///
/// Overflow saturates to ±infinity (binary16 max finite is 65504); NaN
/// maps to a quiet half NaN; values below the subnormal floor flush to
/// signed zero.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Infinity keeps a zero mantissa; NaN keeps a nonzero one.
        let payload = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | payload;
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: keep 10 mantissa bits, round-to-nearest-even on
        // the 13 dropped bits.
        let mut m = man >> 13;
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // Mantissa carried out: bump the exponent (may reach inf).
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e >= -24 && exp != 0 {
        // Subnormal half: value = round(|x| × 2²⁴) units of 2⁻²⁴.  The
        // implicit bit joins the mantissa and the whole thing shifts
        // right by (−1 − e), again rounding to nearest even.
        let full = man | 0x0080_0000;
        let shift = (-1 - e) as u32;
        let mut m = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        // A carry into bit 10 lands exactly on the smallest normal —
        // the bit pattern is already correct.
        return sign | (m as u16);
    }
    sign // underflow → signed zero
}

/// Convert IEEE 754 binary16 bits back to an exactly-representable `f32`.
///
/// Every finite binary16 value is exactly representable in binary32, so
/// this direction is lossless — the pair of conversions is the storage
/// round-trip [`f16_round_trip`] applies.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 31 {
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half (value = man × 2⁻²⁴) normalizes in f32:
            // top set bit p gives exponent p − 24.
            let p = 31 - man.leading_zeros();
            let e32 = p + 103; // (p − 24) + 127
            let m32 = (man << (23 - p)) & 0x007F_FFFF;
            sign | (e32 << 23) | m32
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13) // 112 = 127 − 15
    };
    f32::from_bits(bits)
}

/// The fp16 storage round-trip: what a gathered element looks like after
/// living in a half-precision cold tier.
pub fn f16_round_trip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Per-row affine int8 parameters: `stored = round((x − zero_point) /
/// scale)`, `dequant = zero_point + stored × scale`.
///
/// `scale = 0` marks a constant row (every element equals
/// `zero_point`), which dequantizes exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Int8RowParams {
    pub zero_point: f32,
    pub scale: f32,
}

/// Compute the affine parameters of one row: `zero_point = min`,
/// `scale = (max − min) / 255` (the full unsigned-8-bit range).
pub fn int8_row_params(row: &[f32]) -> Int8RowParams {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in row {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !(lo.is_finite() && hi.is_finite()) || lo >= hi {
        return Int8RowParams {
            zero_point: if lo.is_finite() { lo } else { 0.0 },
            scale: 0.0,
        };
    }
    Int8RowParams {
        zero_point: lo,
        scale: (hi - lo) / 255.0,
    }
}

/// The int8 storage round-trip of one element under row parameters `p`.
pub fn int8_round_trip(x: f32, p: Int8RowParams) -> f32 {
    if p.scale == 0.0 {
        return p.zero_point;
    }
    let q = ((x - p.zero_point) / p.scale).round().clamp(0.0, 255.0);
    p.zero_point + q * p.scale
}

/// Round-trip a whole feature table (`rows × dim`, row-major) through
/// the storage format of `precision`, in place — the one call
/// `FeatureStore::build_inner` makes before any access mode sees the
/// values.  `Fp32` is the identity (bit-exact by construction).
pub fn quantize_table(data: &mut [f32], dim: usize, precision: Precision) {
    match precision {
        Precision::Fp32 => {}
        Precision::Fp16 => {
            for x in data.iter_mut() {
                *x = f16_round_trip(*x);
            }
        }
        Precision::Int8 => {
            if dim == 0 {
                return;
            }
            for row in data.chunks_mut(dim) {
                let p = int8_row_params(row);
                if p.scale == 0.0 {
                    continue; // constant row stored exactly
                }
                for x in row.iter_mut() {
                    *x = int8_round_trip(*x, p);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_exact_for_representable_values() {
        // ≤ 11 significand bits inside the normal range round-trip
        // bit-exactly.
        for &x in &[
            0.0f32, -0.0, 1.0, -1.0, 1.5, 0.25, -0.375, 2048.0, 65504.0, 6.1035156e-5,
            -3.140625, 0.0009765625,
        ] {
            let y = f16_round_trip(x);
            assert_eq!(x.to_bits(), y.to_bits(), "x={x}");
        }
    }

    #[test]
    fn fp16_relative_error_bounded_for_normals() {
        // Pseudo-random normal-range values: relative error ≤ 2⁻¹¹.
        let mut state = 0x9E37_79B9u32;
        for _ in 0..2000 {
            state = state.wrapping_mul(1664525).wrapping_add(1013904223);
            let mag = (state >> 8) as f32 / (1 << 24) as f32; // [0, 1)
            let x = (mag * 2000.0 - 1000.0) + 0.001; // avoid exact zero
            let y = f16_round_trip(x);
            let rel = ((y - x) / x).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn fp16_idempotent() {
        // A value already on the fp16 grid stays put: round-tripping
        // twice equals once (what repeated load cycles would see).
        let mut state = 0xB5297A4Du32;
        for _ in 0..500 {
            state = state.wrapping_mul(1664525).wrapping_add(1013904223);
            let x = f32::from_bits(0x3F00_0000 | (state & 0x007F_FFFF)); // [0.5, 1)
            let once = f16_round_trip(x);
            let twice = f16_round_trip(once);
            assert_eq!(once.to_bits(), twice.to_bits(), "x={x}");
        }
    }

    #[test]
    fn fp16_specials() {
        assert_eq!(f16_round_trip(f32::INFINITY), f32::INFINITY);
        assert_eq!(f16_round_trip(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(f16_round_trip(f32::NAN).is_nan());
        // Overflow saturates to infinity at the binary16 boundary.
        assert_eq!(f16_round_trip(65520.0), f32::INFINITY);
        assert_eq!(f16_round_trip(1e38), f32::INFINITY);
        assert_eq!(f16_round_trip(-1e38), f32::NEG_INFINITY);
        // Deep underflow flushes to signed zero.
        assert_eq!(f16_round_trip(1e-30).to_bits(), 0.0f32.to_bits());
        assert_eq!(f16_round_trip(-1e-30).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn fp16_subnormals_round_trip_in_units_of_2_pow_minus_24() {
        // The smallest half subnormal and multiples of it are exact.
        let ulp = f32::from_bits(0x3380_0000); // 2⁻²⁴
        for k in [1u32, 2, 3, 511, 1023] {
            let x = k as f32 * ulp;
            assert_eq!(f16_round_trip(x), x, "k={k}");
        }
        // Half of the smallest subnormal ties to even → zero.
        assert_eq!(f16_round_trip(ulp * 0.5), 0.0);
        // 1.5 ulp rounds up to 2 ulp (nearest even).
        assert_eq!(f16_round_trip(ulp * 1.5), ulp * 2.0);
    }

    #[test]
    fn fp16_round_to_nearest_even_ties() {
        // 1 + 2⁻¹¹ sits exactly between 1.0 and 1 + 2⁻¹⁰: ties to even
        // keeps the even mantissa (1.0).
        let tie = f32::from_bits(0x3F80_1000);
        assert_eq!(f16_round_trip(tie), 1.0);
        // 1 + 3·2⁻¹¹ ties between odd/even mantissas → rounds up.
        let tie_up = f32::from_bits(0x3F80_3000);
        assert_eq!(f16_round_trip(tie_up), 1.0 + 2.0 / 1024.0);
    }

    #[test]
    fn int8_error_within_half_scale() {
        let mut state = 0xDEADBEEFu32;
        let mut row = Vec::with_capacity(64);
        for _ in 0..64 {
            state = state.wrapping_mul(1664525).wrapping_add(1013904223);
            row.push((state >> 8) as f32 / (1 << 20) as f32 - 8.0);
        }
        let p = int8_row_params(&row);
        assert!(p.scale > 0.0);
        for &x in &row {
            let y = int8_round_trip(x, p);
            assert!(
                (y - x).abs() <= p.scale * 0.5 + p.scale * 1e-5,
                "x={x} y={y} scale={}",
                p.scale
            );
        }
    }

    #[test]
    fn int8_endpoints_exact_and_constant_rows_lossless() {
        let row = [2.0f32, 7.0, 4.5, 3.25];
        let p = int8_row_params(&row);
        assert_eq!(int8_round_trip(2.0, p), 2.0, "row min is the zero point");
        // Constant rows have scale 0 and dequantize exactly.
        let flat = [3.75f32; 16];
        let pf = int8_row_params(&flat);
        assert_eq!(pf.scale, 0.0);
        assert_eq!(int8_round_trip(3.75, pf), 3.75);
        let mut data = flat.to_vec();
        quantize_table(&mut data, 16, Precision::Int8);
        assert!(data.iter().all(|&x| x == 3.75));
    }

    #[test]
    fn quantize_table_fp32_is_identity() {
        let mut data: Vec<f32> = (0..256).map(|i| (i as f32).sin()).collect();
        let before = data.clone();
        quantize_table(&mut data, 16, Precision::Fp32);
        for (a, b) in data.iter().zip(&before) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn quantize_table_is_per_row_for_int8() {
        // Two rows with very different ranges: each gets its own scale,
        // so the small-range row keeps fine resolution.
        let mut data = vec![0.0f32, 0.001, 0.002, 0.003, 0.0, 250.0, 500.0, 1000.0];
        quantize_table(&mut data, 4, Precision::Int8);
        // Row 0 scale ≈ 0.003/255: error ≤ 6e-6.
        assert!((data[1] - 0.001).abs() < 1e-5);
        // Row 1 scale ≈ 1000/255 ≈ 3.9: error ≤ ~2.
        assert!((data[5] - 250.0).abs() <= 2.0);
    }

    #[test]
    fn quantize_table_idempotent_for_both_formats() {
        // Round-tripping an already-quantized table changes nothing —
        // the stored grid is a fixed point of the storage map.
        let base: Vec<f32> = (0..128).map(|i| (i as f32 * 0.7).cos() * 3.0).collect();
        for prec in [Precision::Fp16, Precision::Int8] {
            let mut once = base.clone();
            quantize_table(&mut once, 8, prec);
            let mut twice = once.clone();
            quantize_table(&mut twice, 8, prec);
            for (a, b) in once.iter().zip(&twice) {
                // int8 re-derives params from the quantized row; the grid
                // endpoints (min/max) are preserved, so params — and with
                // them every grid point — are identical.
                assert_eq!(a.to_bits(), b.to_bits(), "{prec:?}");
            }
        }
    }
}

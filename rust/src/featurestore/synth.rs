//! Deterministic synthetic node features with a recoverable label signal.

use crate::sampler::neighbor::NeighborSampler;
use crate::util::rng::Rng;

/// Feature synthesizer: row `v` = signal(label(v)) + 0.05·noise(v).
///
/// The signal occupies `min(classes, dim)` dimensions as a one-hot of the
/// node's label, so a one-layer model can already separate classes given
/// clean aggregation — which makes the end-to-end loss curve a meaningful
/// integration check of gather + aggregation + training.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticFeatures {
    pub dim: usize,
    pub classes: u32,
    pub seed: u64,
}

impl SyntheticFeatures {
    pub fn new(dim: usize, classes: u32, seed: u64) -> Self {
        SyntheticFeatures { dim, classes, seed }
    }

    #[inline]
    pub fn label(&self, node: u32) -> i32 {
        NeighborSampler::label_of(node, self.classes)
    }

    /// Fill one feature row (len == `dim`).
    pub fn fill_row(&self, node: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let mut rng = Rng::new(self.seed ^ (node as u64).wrapping_mul(0xA076_1D64_78BD_642F));
        for v in out.iter_mut() {
            *v = 0.05 * (rng.gen_f64() as f32 * 2.0 - 1.0);
        }
        let label = self.label(node) as usize;
        if label < self.dim {
            out[label] += 1.0;
        }
    }

    /// Materialize the full `[rows, dim]` table.
    pub fn build_table(&self, rows: usize) -> Vec<f32> {
        let mut data = vec![0f32; rows * self.dim];
        for (v, chunk) in data.chunks_exact_mut(self.dim).enumerate() {
            self.fill_row(v as u32, chunk);
        }
        data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_deterministic() {
        let s = SyntheticFeatures::new(32, 8, 7);
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        s.fill_row(999, &mut a);
        s.fill_row(999, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn label_signal_is_dominant_dimension() {
        let s = SyntheticFeatures::new(16, 8, 3);
        for node in 0..200u32 {
            let mut row = vec![0f32; 16];
            s.fill_row(node, &mut row);
            let label = s.label(node) as usize;
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, label, "node {node}");
        }
    }

    #[test]
    fn table_layout_row_major() {
        let s = SyntheticFeatures::new(4, 2, 1);
        let table = s.build_table(3);
        let mut row1 = vec![0f32; 4];
        s.fill_row(1, &mut row1);
        assert_eq!(&table[4..8], &row1[..]);
    }
}

//! `ptdirect` — leader binary for the PyTorch-Direct reproduction.

fn main() {
    ptdirect::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let argv = if argv.is_empty() {
        vec!["help".to_string()]
    } else {
        argv
    };
    std::process::exit(ptdirect::cli::run(&argv));
}

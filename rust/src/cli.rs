//! Command-line interface (clap is not vendored; parsing is hand-rolled).
//!
//! ```text
//! ptdirect train      [--dataset D] [--arch A] [--mode M] [--system S]
//!                     [--epochs N] [--steps N] [--scale K] [--seed S]
//!                     [--config run.toml] [--skip-train]
//! ptdirect microbench [--system S] [--n N] [--bytes B]
//! ptdirect alignment  [--system S]
//! ptdirect datasets
//! ptdirect selfcheck  [--artifacts DIR]
//! ```

use std::collections::BTreeMap;

use crate::config::{
    AccessMode, Backend, FetchStrategy, RunConfig, ShardPolicy, SystemProfile, LINK_KNOBS,
};
use crate::coordinator::microbench::{fig6_grid, fig7_sizes, run_cell};
use crate::coordinator::report::{
    critical_path_summary, latency_line, ms, pct, ratio, shard_table, Table,
};
use crate::coordinator::Trainer;
use crate::error::{Error, Result};
use crate::graph::datasets::DATASETS;
use crate::runtime::Manifest;
use crate::util::bytes::human_bytes;
use crate::util::rng::Rng;

/// Parsed command line: subcommand + `--key value` / `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it
            .next()
            .cloned()
            .ok_or_else(|| Error::Config("missing subcommand (try `help`)".into()))?;
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --option, got `{a}`")))?;
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.options.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => args.flags.push(key.to_string()),
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| Error::Config(format!("--{key} expects an integer")))
            })
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| Error::Config(format!("--{key} expects a number")))
            })
            .transpose()
    }
}

/// Build a RunConfig from `--config` + CLI overrides.
pub fn run_config_from(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.into();
    }
    if let Some(a) = args.get("arch") {
        cfg.arch = a.into();
    }
    if let Some(m) = args.get("mode") {
        cfg.mode =
            AccessMode::parse(m).ok_or_else(|| Error::Config(format!("unknown mode `{m}`")))?;
    }
    if let Some(s) = args.get("system") {
        cfg.system = SystemProfile::by_name(s)
            .ok_or_else(|| Error::Config(format!("unknown system `{s}`")))?;
    }
    if let Some(e) = args.get_u64("epochs")? {
        cfg.epochs = e as u32;
    }
    if let Some(s) = args.get_u64("steps")? {
        cfg.steps_per_epoch = s as u32;
    }
    if let Some(s) = args.get_u64("scale")? {
        cfg.scale = s as u32;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if args.flag("skip-train") {
        cfg.skip_train = true;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)
            .ok_or_else(|| Error::Config(format!("unknown backend `{b}`")))?;
    }
    if let Some(f) = args.get_f64("hot-frac")? {
        cfg.hot_frac = f;
    }
    if let Some(f) = args.get_f64("gpu-reserve")? {
        cfg.gpu_reserve_frac = f;
    }
    if args.flag("no-promote") {
        cfg.tier_promote = false;
    }
    if let Some(p) = args.get_u64("page-rows")? {
        cfg.page_rows = usize::try_from(p)
            .map_err(|_| Error::Config(format!("--page-rows {p} out of range")))?;
    }
    if let Some(e) = args.get("eviction") {
        cfg.eviction = crate::config::EvictionPolicy::parse(e)
            .ok_or_else(|| Error::Config(format!("unknown eviction policy `{e}`")))?;
    }
    if let Some(n) = args.get_u64("num-gpus")? {
        // Checked conversion: a wrapping `as` cast could smuggle huge
        // values into the valid [1, 64] window.
        cfg.num_gpus = u32::try_from(n)
            .map_err(|_| Error::Config(format!("--num-gpus {n} out of range")))?;
    }
    if let Some(p) = args.get("shard-policy") {
        cfg.shard_policy = ShardPolicy::parse(p)
            .ok_or_else(|| Error::Config(format!("unknown shard policy `{p}`")))?;
    }
    if let Some(f) = args.get_f64("host-frac")? {
        cfg.host_frac = f;
    }
    // Link-constant overrides: one walk over the same LINK_KNOBS table
    // the TOML path uses.  Adds `--nvlink-gb-per-s` and the `--net-*`
    // flags for free — the per-knob arms this replaces had silently
    // missed the NVLink one.
    for k in LINK_KNOBS {
        if let Some(v) = args.get_f64(k.flag.trim_start_matches("--"))? {
            if !(v.is_finite() && v > 0.0) {
                return Err(Error::Config(format!(
                    "{} must be positive and finite, got {v}",
                    k.flag
                )));
            }
            (k.set)(&mut cfg, v)?;
        }
    }
    if let Some(n) = args.get_u64("num-hosts")? {
        // Checked conversion; the [1, 64] window (and the sharded-mode
        // requirement) lives in `RunConfig::validate` below.
        cfg.num_hosts = u32::try_from(n)
            .map_err(|_| Error::Config(format!("--num-hosts {n} out of range")))?;
    }
    if let Some(f) = args.get("fetch-strategy") {
        cfg.fetch_strategy = FetchStrategy::parse(f)
            .ok_or_else(|| Error::Config(format!("unknown fetch strategy `{f}`")))?;
    }
    if let Some(n) = args.get_u64("prefetch-depth")? {
        // Checked conversion: a wrapping `as` cast could smuggle huge
        // values past the [0, 1024] validation window.
        cfg.prefetch_depth = u32::try_from(n)
            .map_err(|_| Error::Config(format!("--prefetch-depth {n} out of range")))?;
    }
    if args.flag("no-overlap") {
        cfg.no_overlap = true;
    }
    // `--dedup` re-enables after a TOML `dedup = false`; `--no-dedup`
    // wins when both are given (the regression-anchor escape hatch).
    if args.flag("dedup") {
        cfg.dedup = true;
    }
    if args.flag("no-dedup") {
        cfg.dedup = false;
    }
    if let Some(c) = args.get_u64("classes")? {
        // Checked conversion only; the [1, 2^20] window (and the
        // modulo-by-zero rejection of 0) lives once in
        // `RunConfig::validate`, which runs below.
        cfg.classes = Some(
            u32::try_from(c)
                .map_err(|_| Error::Config(format!("--classes {c} out of range")))?,
        );
    }
    if let Some(q) = args.get_u64("queue-depth")? {
        // Checked conversion; the [1, 65536] window is enforced by
        // `RunConfig::validate`, so absurd values error instead of
        // reaching the queue allocator.
        cfg.queue_depth = usize::try_from(q)
            .map_err(|_| Error::Config(format!("--queue-depth {q} out of range")))?;
    }
    if let Some(w) = args.get_u64("sampler-workers")? {
        cfg.sampler_workers = usize::try_from(w)
            .map_err(|_| Error::Config(format!("--sampler-workers {w} out of range")))?;
    }
    if let Some(n) = args.get_u64("requests")? {
        cfg.serve_requests = n;
    }
    if let Some(r) = args.get_f64("arrival-rps")? {
        // Finiteness + sign live in `RunConfig::validate` below; this
        // keeps the single-home rule (one window, one place).
        cfg.arrival_rps = r;
    }
    if let Some(c) = args.get_u64("clients")? {
        cfg.clients = u32::try_from(c)
            .map_err(|_| Error::Config(format!("--clients {c} out of range")))?;
    }
    if let Some(d) = args.get_u64("admit-depth")? {
        cfg.admit_depth = usize::try_from(d)
            .map_err(|_| Error::Config(format!("--admit-depth {d} out of range")))?;
    }
    // `--coalesce` re-enables after a TOML `coalesce = false`;
    // `--no-coalesce` wins when both are given (mirrors --dedup).
    if args.flag("coalesce") {
        cfg.coalesce = true;
    }
    if args.flag("no-coalesce") {
        cfg.coalesce = false;
    }
    if let Some(l) = args.get_u64("coalesce-limit")? {
        cfg.coalesce_limit = usize::try_from(l)
            .map_err(|_| Error::Config(format!("--coalesce-limit {l} out of range")))?;
    }
    if let Some(p) = args.get("precision") {
        cfg.precision = crate::config::Precision::parse(p)
            .ok_or_else(|| Error::Config(format!("unknown precision `{p}`")))?;
    }
    // `--aggregate-pushdown` re-enables after a TOML
    // `aggregate_pushdown = false`; `--no-pushdown` wins when both are
    // given (the bit-exact pre-pushdown regression anchor).
    if args.flag("aggregate-pushdown") {
        cfg.aggregate_pushdown = true;
    }
    if args.flag("no-pushdown") {
        cfg.aggregate_pushdown = false;
    }
    // `--system` replaced the whole profile above; restore the TOML's (and
    // the CLI's) link overrides (NVLink/NVMe/network — every LINK_KNOBS
    // entry) on top of the selected profile.
    cfg.apply_link_overrides();
    cfg.validate()?;
    Ok(cfg)
}

pub const HELP: &str = "\
ptdirect — PyTorch-Direct reproduction (rust + JAX + Pallas)

USAGE: ptdirect <COMMAND> [OPTIONS]

COMMANDS:
  train        run GNN training epochs (end-to-end through PJRT)
  infer        serve forward-only batches (latency + accuracy; --batches N)
  serve        online inference under an arrival stream (tail latency, goodput)
  microbench   paper Fig. 6 gather microbenchmark
  alignment    paper Fig. 7 memory-alignment sweep
  datasets     paper Table 4 dataset presets
  selfcheck    verify artifacts + runtime round-trip
  help         this text

COMMON OPTIONS:
  --dataset reddit|product|twit|sk|paper|wiki   (default product)
  --arch sage|gat                               (default sage)
  --mode py|pyd|pyd-naive|uvm|gpu|tiered|sharded|nvme (default pyd)
  --system system1|system2|system3              (default system1)
  --backend auto|pjrt|native                    (default auto)
  --epochs N --steps N --scale K --seed S
  --classes C   override the preset's synthetic label count (>= 1)
  --config run.toml --artifacts DIR --skip-train

GATHER DEDUPLICATION (all modes):
  Each mini-batch's requested node set is compacted to its unique rows
  before the feature gather: every store fetches each distinct row once
  and a cheap device-side scatter rebuilds the requested layout, so the
  transfer (PCIe/NVLink/NVMe alike) shrinks by the batch's duplication
  factor while losses stay bitwise identical.  On by default.
  --dedup      enable minibatch gather deduplication (default)
  --no-dedup   fetch the duplicated stream as-is (bit-exact legacy
               accounting — the regression anchor)
  Per-epoch reporting gains a dedup line: requested vs unique rows, the
  dedup ratio, and the useful payload saved (an upper bound on link-byte
  savings: duplicates a hot tier served never crossed a link anyway).

TIERED ACCESS MODE (--mode tiered):
  A degree-ranked hot set of feature rows is pinned in (simulated) GPU
  memory and served at device speed — kernel launch only, like gpu mode —
  while the remaining cold rows go through the pyd zero-copy PCIe path.
  Capacity is the GPU memory left after --gpu-reserve, capped by
  --hot-frac; an online eviction policy (--eviction, default lfu) promotes
  frequently-missed pages, so repeated epochs warm the cache.  Residency is
  tracked per fixed-size page of --page-rows rows through one shared paged
  cache (DESIGN.md §12); in-flight gathers pin their pages.  This follows
  the Data Tiering follow-up paper (arXiv:2111.05894) to PyTorch-Direct.
  --hot-frac F      target hot fraction of the feature rows, 0..1 (0.25)
  --gpu-reserve F   GPU-memory fraction reserved for model/activations (0.5)
  --no-promote      disable online promotion (static placement)
  --page-rows N     feature rows per cache page, 1..65536 (1; 1 is
                    row-granular and bit-exact to the pre-page cache)
  --eviction P      static|lfu|lru|clock page eviction policy (lfu);
                    static freezes the degree-ranked preseed
  The tier flags apply to sharded (per-GPU tiers) and nvme (GPU tier) too.
  Per-epoch reporting gains tier columns: hit rate, hot bytes, promotions,
  pages, and pin counters.

SHARDED ACCESS MODE (--mode sharded):
  The feature table is partitioned across N simulated GPUs; each GPU pins
  the hottest rows of its own shard (the tiered machinery, per GPU — the
  tier flags above all apply, with --hot-frac scaled per shard), reads
  peer-owned hot rows over NVLink, and falls back to the host zero-copy
  path for rows cold everywhere.  --num-gpus 1 reproduces tiered mode
  bit-exactly.  After the multi-GPU follow-up (arXiv:2103.03330).
  --num-gpus N         simulated GPUs, 1..64 (default 1)
  --shard-policy P     hash|degree|contig row placement (default hash):
                       hash   = uniform random shards,
                       degree = round-robin over the degree ranking
                                (spreads hot rows evenly),
                       contig = contiguous id ranges (cheapest metadata,
                                skew-prone on id-correlated graphs)
  --nvlink-gb-per-s B  override NVLink peer bandwidth, GB/s
  Per-epoch reporting gains a per-GPU table: local/peer/host row, byte and
  time splits, plus the load-imbalance factor (slowest GPU over mean).

MULTI-HOST NETWORK TIER (--mode sharded; DESIGN.md §15):
  The feature table is first partitioned across N hosts — the same
  placement policies as --shard-policy, applied at host granularity —
  and the trainer models host 0, whose minibatches inevitably touch
  rows homed on other hosts.  --num-hosts 1 (the default) reproduces
  every single-host sharded report bit-exactly.  Foreign-homed rows are
  priced per --fetch-strategy over an Ethernet/InfiniBand link model
  (max of a bandwidth term and a per-message latency term; one batched
  RPC per remote host per GPU), and the overlap engine schedules the
  network as its own resource lane.
  --num-hosts N        hosts the table is partitioned across, 1..64 (1)
  --fetch-strategy S   remote|local handling of foreign-homed rows:
                       remote = fetch over the network at gather time
                                (DistDGL-style remote pulls),
                       local  = replicate the halo into the local tiers
                                (zero steady-state network bytes; the
                                mirrored rows are reported as halo)
  --net-gb-per-s B     override inter-host network bandwidth, GB/s
  --net-latency-us U   override per-message network latency, microseconds
  Per-epoch reporting gains remote/halo row counters plus network byte
  and time columns in the shard table.

OVERLAP ENGINE (all modes):
  Each epoch is scheduled twice: the additive serial breakdown (sample +
  feature-copy + train + other) and a discrete-event pipelined timeline
  where every step's sample -> gather -> transfer -> train DAG runs on
  stateful shared resources (CPU sampler lanes, the PCIe link, NVLink,
  the NVMe queue, the GPU) under a bounded prefetch window.  The per-epoch
  report shows both totals plus which resource bound the critical path,
  and the measured pipeline's queue backpressure next to them.
  --prefetch-depth N   steps in flight ahead of training, 0..1024 (2);
                       0 = serial (bit-exact legacy accounting),
                       1 = windowed but still serial, >= 2 overlaps
  --no-overlap         force the serial timeline (same as depth 0)
  --queue-depth N      measured pipeline's bounded-queue capacity (4)
  --sampler-workers N  simulated CPU sampler lanes (1)

ONLINE SERVING (serve; all access modes):
  A request-driven serving engine on top of the overlap engine's
  discrete-event resources: inference requests arrive over simulated
  time, pass a bounded admission queue (arrivals that find it full are
  rejected and counted as goodput loss), and concurrent queued requests
  coalesce into one minibatch whose gather dedups *across* requests —
  each request's scattered feature block stays bitwise identical to
  serving it alone.  Reports p50/p95/p99/p999 latency, goodput, queue
  depth, rejection rate, and which resource bound the run.
  --requests N        total requests to offer (64)
  --arrival-rps R     open-loop Poisson arrival rate; 0 = closed loop (0)
  --clients N         closed-loop concurrent clients, 1..65536 (1);
                      a single client reproduces the `infer` command's
                      simulated breakdown bit-exactly
  --admit-depth D     admission queue capacity, 1..65536 (32)
  --coalesce          merge queued requests into one batch (default)
  --no-coalesce       dispatch one request per batch
  --coalesce-limit K  max requests per coalesced batch, 1..65536 (8)

PRECISION TIERS (all modes):
  Cold/host/NVMe tiers can store feature rows in reduced precision
  (the Data Tiering follow-up, arXiv:2111.05894): fp16 halves and int8
  quarters every byte that crosses PCIe/NVLink/NVMe — link bytes, NVMe
  block IOs, cache page bytes and coalesced serving payloads all price
  the narrowed row.  int8 uses per-row scale+zero-point affine
  quantization computed once at load (the 8 B/row side table crosses
  once and is not charged per gather).  The whole table is round-tripped
  through the storage format at build time, so all eight access modes
  stay bitwise identical to *each other* at any precision; only the
  fp32 reference moves, within the bands DESIGN.md §13 documents.
  --precision fp32|fp16|int8   cold-tier storage precision (fp32);
                               fp32 is a bit-exact no-op — the
                               degeneracy-chain anchor

AGGREGATION PUSH-DOWN (all modes; default off):
  Each tier reduces the neighbor rows it already holds into per-
  destination partial sums *near the data* (after GNNear,
  arXiv:2111.00680) and ships one partial-aggregate row plus a 4-byte
  neighbor count per destination instead of every raw neighbor row, so
  link traffic shrinks by roughly the fanout.  The destination (self)
  rows still pay the mode's normal per-row price — and still dedup, so
  push-down composes multiplicatively with --dedup.  The reduction is
  computed once from the gathered block in a pinned canonical order
  (ascending neighbor id per destination), so losses are bitwise
  identical with the knob on or off, in all eight access modes at every
  --precision.
  --aggregate-pushdown  price near-memory aggregation push-down
  --no-pushdown         ship raw neighbor rows (default; bit-exact
                        pre-pushdown accounting — the regression anchor)
  Per-epoch reporting gains a pushdown line: raw vs shipped link bytes,
  the traffic-reduction factor, and the near-memory FLOPs the tiers
  performed (charged at the profile's near-memory compute rate, and as
  power draw against its near-memory budget).

NVME STORAGE MODE (--mode nvme):
  For feature tables bigger than host memory (GIDS, arXiv:2306.16384):
  host memory holds only the hottest --host-frac of the rows (by degree
  ranking); the rest spill to a simulated NVMe cold store read by
  GPU-initiated 4 KiB block commands (no CPU on the path).  The GPU hot
  tier sits on top — all tiered flags apply.  --host-frac 1 reproduces
  tiered mode bit-exactly; adjacent spilled rows coalesce into shared
  blocks, and the per-epoch report shows the I/O amplification.
  --host-frac F          fraction of rows host memory holds, 0..1 (0.5)
  --nvme-gb-per-s B      override SSD read bandwidth, GB/s
  --nvme-iops N          override SSD IOPS ceiling
  --nvme-queue-depth Q   override outstanding-command budget, >= 1
  Per-epoch reporting gains nvme columns: GPU/host/storage row split,
  block reads (IOs), I/O amplification, and SSD utilization.
";

/// Entry point used by main.rs (returns process exit code).
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "microbench" => cmd_microbench(&args),
        "alignment" => cmd_alignment(&args),
        "datasets" => cmd_datasets(),
        "selfcheck" => cmd_selfcheck(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command `{other}` (try help)"))),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = run_config_from(args)?;
    log::info!(
        "train: {} {} mode={} system={} epochs={}",
        cfg.arch,
        cfg.dataset,
        cfg.mode.label(),
        cfg.system.name,
        cfg.epochs
    );
    let mut trainer = Trainer::new(cfg.clone())?;
    for epoch in 0..cfg.epochs {
        let r = trainer.run_epoch()?;
        let b = &r.breakdown_sim;
        println!(
            "epoch {epoch}: steps={} loss {:.4} -> {:.4} acc {:.3} | sim: sample {} ms, \
             feature-copy {} ms, train {} ms, other {} ms | {:.0} W ({} cpu)",
            r.steps,
            r.losses.first().copied().unwrap_or(0.0),
            r.final_loss(),
            r.accs.last().copied().unwrap_or(0.0),
            ms(b.sample_s),
            ms(b.transfer_s),
            ms(b.train_s),
            ms(b.other_s),
            r.power.watts,
            pct(r.power.cpu_util),
        );
        if r.dedup.enabled {
            // "payload saved" is the requested-row reduction in useful
            // bytes — an upper bound on link-byte savings (duplicates
            // served by a hot tier never crossed a link to begin with).
            println!(
                "  dedup: {} requested -> {} unique rows ({}), {} useful payload saved",
                r.dedup.requested_rows,
                r.dedup.unique_rows,
                ratio(r.dedup.ratio()),
                human_bytes(r.dedup.bytes_saved),
            );
        }
        if r.pushdown.enabled {
            let p = &r.pushdown;
            println!(
                "  pushdown: link {} raw -> {} shipped ({} reduction), {} neighbor rows -> \
                 {} aggregate rows for {} dsts, near-mem {:.1} MFLOP ({} ms)",
                human_bytes(p.raw_bytes_on_link),
                human_bytes(p.pushed_bytes_on_link),
                ratio(p.reduction()),
                p.neighbor_rows,
                p.agg_rows,
                p.dst_rows,
                p.near_mem_flops as f64 / 1e6,
                ms(p.near_mem_s),
            );
        }
        if let Some(tier) = &r.tier {
            println!(
                "  tier: hit rate {} ({} hits / {} misses), hot {} / cap {}, \
                 {} promotions, {} evictions, {}/{} pages x{} rows, \
                 {} pins ({} blocked)",
                pct(tier.hit_rate()),
                tier.hits,
                tier.misses,
                human_bytes(tier.hot_bytes),
                human_bytes(tier.capacity_bytes),
                tier.promotions,
                tier.evictions,
                tier.resident_pages,
                tier.capacity_pages,
                tier.page_rows,
                tier.pins,
                tier.pin_blocked,
            );
        }
        if let Some(nvme) = &r.nvme {
            println!(
                "  nvme: hit rate {} ({} gpu / {} host / {} storage rows), \
                 {} IOs, {} on link, amp {:.2}x, spilled {} rows, ssd {}",
                pct(nvme.hit_rate()),
                nvme.tier.hits,
                nvme.host_rows,
                nvme.storage_rows,
                nvme.ios,
                human_bytes(nvme.storage_bytes_on_link),
                nvme.amplification(),
                nvme.spilled_rows,
                pct(r.power.storage_util),
            );
        }
        if let Some(shard) = &r.shard {
            let totals = shard.totals();
            println!(
                "  shard: {} local / {} peer / {} host / {} remote rows ({} halo), \
                 peer {} host {} net {}, imbalance {:.2}x",
                totals.local_rows,
                totals.peer_rows,
                totals.host_rows,
                totals.remote_rows,
                totals.halo_rows,
                human_bytes(totals.peer_bytes),
                human_bytes(totals.host_bytes),
                human_bytes(totals.remote_bytes),
                shard.load_imbalance(),
            );
            shard_table(shard).print();
        }
        let o = &r.overlap;
        println!(
            "  overlap: serial {} ms -> overlapped {} ms ({} at depth {}) | critical path: {}",
            ms(o.serial_s),
            ms(o.overlapped_s),
            ratio(o.speedup()),
            o.prefetch_depth,
            critical_path_summary(o),
        );
        let m = &r.breakdown_measured;
        let p = &r.pipeline;
        println!(
            "  measured-here: sample {} ms, gather {} ms, train {} ms, other {} ms \
             (pipelined wall {} ms; waits q1 push/pop {}/{} ms, q2 push/pop {}/{} ms)",
            ms(m.sample_s),
            ms(m.transfer_s),
            ms(m.train_s),
            ms(m.other_s),
            ms(p.wall_s),
            ms(p.q1_push_wait_s),
            ms(p.q1_pop_wait_s),
            ms(p.q2_push_wait_s),
            ms(p.q2_pop_wait_s),
        );
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let cfg = run_config_from(args)?;
    let n_batches = args.get_u64("batches")?.unwrap_or(32);
    log::info!(
        "infer: {} {} mode={} system={} batches={n_batches}",
        cfg.arch,
        cfg.dataset,
        cfg.mode.label(),
        cfg.system.name
    );
    let mut runner = crate::coordinator::InferenceRunner::new(cfg)?;
    let r = runner.run(n_batches)?;
    println!(
        "served {} batches: accuracy {:.3} (untrained params -> ~chance)",
        r.batches, r.accuracy
    );
    println!(
        "measured exec latency: p50 {} ms, p99 {} ms | simulated batch latency: p50 {} ms \
         (sample {} + copy {} + fwd {} ms totals)",
        ms(r.exec_latency.median()),
        ms(r.exec_latency.percentile(0.99)),
        ms(r.sim_latency.median()),
        ms(r.breakdown_sim.sample_s),
        ms(r.breakdown_sim.transfer_s),
        ms(r.breakdown_sim.train_s),
    );
    if r.pushdown.enabled {
        let p = &r.pushdown;
        println!(
            "pushdown: link {} raw -> {} shipped ({} reduction), near-mem {:.1} MFLOP",
            human_bytes(p.raw_bytes_on_link),
            human_bytes(p.pushed_bytes_on_link),
            ratio(p.reduction()),
            p.near_mem_flops as f64 / 1e6,
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = run_config_from(args)?;
    log::info!(
        "serve: {} {} mode={} system={} requests={} {}",
        cfg.arch,
        cfg.dataset,
        cfg.mode.label(),
        cfg.system.name,
        cfg.serve_requests,
        if cfg.arrival_rps > 0.0 {
            format!("open-loop {} rps", cfg.arrival_rps)
        } else {
            format!("closed-loop {} clients", cfg.clients)
        },
    );
    let mut engine = crate::coordinator::ServingEngine::new(cfg)?;
    let r = engine.run()?;
    println!(
        "served {} of {} offered requests in {} batches ({} coalesced/batch), \
         rejected {} ({}), makespan {} ms, goodput {:.1} rps",
        r.completed,
        r.offered,
        r.batches,
        ratio(r.coalesce_factor()),
        r.rejected,
        pct(r.rejection_rate()),
        ms(r.makespan_s),
        r.goodput_rps(),
    );
    println!("latency: {}", latency_line(&r.latency));
    println!(
        "queue depth: mean {:.1}, max {} | gather dedup {} ({} requested -> {} unique rows)",
        r.queue_depth.mean(),
        r.max_queue_depth,
        ratio(r.dedup_ratio()),
        r.requested_rows,
        r.unique_rows,
    );
    let b = &r.breakdown_sim;
    println!(
        "sim totals: sample {} ms, feature-copy {} ms, execute {} ms | bound by {}",
        ms(b.sample_s),
        ms(b.transfer_s),
        ms(b.train_s),
        r.bound_by.label(),
    );
    if r.pushdown.enabled {
        let p = &r.pushdown;
        println!(
            "pushdown: link {} raw -> {} shipped ({} reduction, per-request aggregates), \
             near-mem {:.1} MFLOP",
            human_bytes(p.raw_bytes_on_link),
            human_bytes(p.pushed_bytes_on_link),
            ratio(p.reduction()),
            p.near_mem_flops as f64 / 1e6,
        );
    }
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    let sys = match args.get("system") {
        Some(s) => vec![SystemProfile::by_name(s)
            .ok_or_else(|| Error::Config(format!("unknown system `{s}`")))?],
        None => SystemProfile::all(),
    };
    let mut rng = Rng::new(args.get_u64("seed")?.unwrap_or(7));
    let (ns, sizes) = match (args.get_u64("n")?, args.get_u64("bytes")?) {
        (Some(n), Some(b)) => (vec![n], vec![b]),
        _ => fig6_grid(),
    };
    for sys in sys {
        let mut t = Table::new(
            &format!("Fig. 6 microbenchmark — {} ({})", sys.name, sys.gpu_name),
            &["N", "feat", "ideal ms", "Py ms", "PyD ms", "Py/ideal", "PyD/ideal", "PyD speedup"],
        );
        for &n in &ns {
            for &s in &sizes {
                let c = run_cell(&sys, n, s, &mut rng);
                t.row(&[
                    format!("{}K", n >> 10),
                    human_bytes(s),
                    ms(c.ideal_s),
                    ms(c.py_s),
                    ms(c.pyd_s),
                    ratio(c.py_slowdown()),
                    ratio(c.pyd_slowdown()),
                    ratio(c.pyd_speedup_over_py()),
                ]);
            }
        }
        t.print();
    }
    Ok(())
}

fn cmd_alignment(args: &Args) -> Result<()> {
    let sys = match args.get("system") {
        Some(s) => SystemProfile::by_name(s)
            .ok_or_else(|| Error::Config(format!("unknown system `{s}`")))?,
        None => SystemProfile::system1(),
    };
    let mut rng = Rng::new(5);
    let mut t = Table::new(
        &format!("Fig. 7 alignment sweep — {}", sys.name),
        &["feat bytes", "Py ms", "PyD naive ms", "PyD opt ms", "naive speedup", "opt speedup"],
    );
    for s in fig7_sizes() {
        let c = run_cell(&sys, 64 << 10, s, &mut rng);
        t.row(&[
            s.to_string(),
            ms(c.py_s),
            ms(c.pyd_naive_s),
            ms(c.pyd_s),
            ratio(c.py_s / c.pyd_naive_s),
            ratio(c.py_s / c.pyd_s),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(
        "Table 4 datasets",
        &["abbv", "dataset", "#feat", "size", "#node", "#edge", "avg deg"],
    );
    for d in DATASETS {
        t.row(&[
            d.abbv.into(),
            d.full_name.into(),
            d.feat_dim.to_string(),
            human_bytes(d.feature_bytes()),
            format!("{:.1}M", d.nodes as f64 / 1e6),
            format!("{:.1}M", d.edges as f64 / 1e6),
            format!("{:.1}", d.edges as f64 / d.nodes as f64),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let manifest = Manifest::load(&dir)?;
    println!("manifest: {} artifacts", manifest.artifacts.len());
    let runtime = crate::runtime::Runtime::cpu()?;
    println!("pjrt platform: {}", runtime.platform());
    // Round-trip the gather artifact against the rust-side gather.
    let spec = manifest.get("gather_aligned")?;
    let loaded = runtime.load(&dir, spec)?;
    let rows = spec.inputs[0].dims[0];
    let feat = spec.inputs[0].dims[1];
    let batch = spec.inputs[1].dims[0];
    let mut rng = Rng::new(11);
    let table: Vec<f32> = (0..rows * feat).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    let idx: Vec<i32> = (0..batch).map(|_| rng.gen_range(rows as u64) as i32).collect();
    let lit_t = crate::runtime::client::literal_f32(&table, &[rows, feat])?;
    let lit_i = crate::runtime::client::literal_i32(&idx, &[batch])?;
    let outs = loaded.execute(&[&lit_t, &lit_i])?;
    let got = outs[0].to_vec::<f32>().map_err(Error::from)?;
    let idx_u: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
    let mut want = vec![0f32; batch * feat];
    crate::tensor::indexing::gather_rows_into(&table, feat, &idx_u, &mut want);
    if got != want {
        return Err(Error::Runtime("gather artifact mismatch vs rust gather".into()));
    }
    println!("gather artifact: OK ({} rows x {} feats, bit-exact)", batch, feat);
    println!("selfcheck: OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::parse(&sv(&["train", "--dataset", "reddit", "--skip-train", "--epochs", "2"]))
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dataset"), Some("reddit"));
        assert!(a.flag("skip-train"));
        assert_eq!(a.get_u64("epochs").unwrap(), Some(2));
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(&sv(&["train", "oops"])).is_err());
        assert!(Args::parse(&sv(&[])).is_err());
    }

    #[test]
    fn run_config_overrides() {
        let a = Args::parse(&sv(&[
            "train", "--dataset", "wiki", "--arch", "gat", "--mode", "py", "--system", "system3",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.dataset, "wiki");
        assert_eq!(cfg.arch, "gat");
        assert_eq!(cfg.mode, AccessMode::CpuGather);
        assert_eq!(cfg.system.name, "System3");
    }

    #[test]
    fn bad_values_error() {
        let a = Args::parse(&sv(&["train", "--mode", "hyperdrive"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--epochs", "two"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn datasets_command_runs() {
        cmd_datasets().unwrap();
    }

    #[test]
    fn tiered_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "tiered",
            "--backend",
            "native",
            "--hot-frac",
            "0.4",
            "--gpu-reserve",
            "0.3",
            "--no-promote",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.mode, AccessMode::Tiered);
        assert_eq!(cfg.backend, Backend::Native);
        assert!((cfg.hot_frac - 0.4).abs() < 1e-12);
        assert!((cfg.gpu_reserve_frac - 0.3).abs() < 1e-12);
        assert!(!cfg.tier_promote);
    }

    #[test]
    fn tiered_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--hot-frac", "2.0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--hot-frac", "lots"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--backend", "quantum"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn page_cache_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "tiered",
            "--page-rows",
            "16",
            "--eviction",
            "clock",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.page_rows, 16);
        assert_eq!(cfg.eviction, crate::config::EvictionPolicy::Clock);
        // Defaults are the bit-exact anchor knobs.
        let d = run_config_from(&Args::parse(&sv(&["train"])).unwrap()).unwrap();
        assert_eq!(d.page_rows, 1);
        assert_eq!(d.eviction, crate::config::EvictionPolicy::Lfu);
    }

    #[test]
    fn page_cache_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--page-rows", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--page-rows", "100000"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--page-rows", "many"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--eviction", "fifo"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn help_documents_tiered_mode() {
        assert!(HELP.contains("tiered"));
        assert!(HELP.contains("--hot-frac"));
        assert!(HELP.contains("--gpu-reserve"));
        assert!(HELP.contains("--backend"));
        assert!(HELP.contains("--page-rows"));
        assert!(HELP.contains("--eviction"));
    }

    #[test]
    fn sharded_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "sharded",
            "--num-gpus",
            "4",
            "--shard-policy",
            "degree",
            "--hot-frac",
            "0.3",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.mode, AccessMode::Sharded);
        assert_eq!(cfg.num_gpus, 4);
        assert_eq!(cfg.shard_policy, ShardPolicy::Degree);
        assert!((cfg.hot_frac - 0.3).abs() < 1e-12);
    }

    #[test]
    fn sharded_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--num-gpus", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--num-gpus", "100"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        let a = Args::parse(&sv(&["train", "--num-gpus", "4294967297"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--shard-policy", "modulo"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn system_override_keeps_toml_nvlink_bandwidth() {
        // --system replaces the whole profile after TOML loading; the
        // nvlink_gb_per_s override must survive onto the new profile.
        // Per-process dir: a fixed /tmp path collides across users on
        // shared machines.
        let dir = std::env::temp_dir()
            .join(format!("ptdirect_nvlink_override_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(&path, "[run]\nmode = \"sharded\"\nnvlink_gb_per_s = 100.0\n").unwrap();
        let a = Args::parse(&sv(&[
            "train",
            "--config",
            path.to_str().unwrap(),
            "--system",
            "system2",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(cfg.system.name, "System2");
        assert!((cfg.system.nvlink.peak_bw - 100e9).abs() < 1.0);
    }

    #[test]
    fn help_documents_sharded_mode() {
        assert!(HELP.contains("sharded"));
        assert!(HELP.contains("--num-gpus"));
        assert!(HELP.contains("--shard-policy"));
        assert!(HELP.contains("hash|degree|contig"));
    }

    #[test]
    fn overlap_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--prefetch-depth",
            "6",
            "--queue-depth",
            "8",
            "--sampler-workers",
            "2",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.prefetch_depth, 6);
        assert_eq!(cfg.queue_depth, 8);
        assert_eq!(cfg.sampler_workers, 2);
        assert_eq!(cfg.effective_prefetch_depth(), 6);

        let a = Args::parse(&sv(&["train", "--prefetch-depth", "4", "--no-overlap"])).unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert!(cfg.no_overlap);
        assert_eq!(cfg.effective_prefetch_depth(), 0);
    }

    #[test]
    fn overlap_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--prefetch-depth", "4096"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // 2^32 + 2 must not wrap into the valid window via `as` truncation.
        let a = Args::parse(&sv(&["train", "--prefetch-depth", "4294967298"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--queue-depth", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // Absurd sizes must error at config time, not abort in the
        // queue/lane allocators.
        let a = Args::parse(&sv(&["train", "--queue-depth", "18446744073709551615"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--sampler-workers", "1000000000000"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn help_documents_the_overlap_engine() {
        assert!(HELP.contains("--prefetch-depth"));
        assert!(HELP.contains("--no-overlap"));
        assert!(HELP.contains("--queue-depth"));
        assert!(HELP.contains("--sampler-workers"));
        assert!(HELP.contains("critical path"));
    }

    #[test]
    fn dedup_cli_flags() {
        let cfg = run_config_from(&Args::parse(&sv(&["train"])).unwrap()).unwrap();
        assert!(cfg.dedup, "dedup must default on");
        let a = Args::parse(&sv(&["train", "--no-dedup"])).unwrap();
        assert!(!run_config_from(&a).unwrap().dedup);
        // --no-dedup wins over --dedup (the regression-anchor escape hatch).
        let a = Args::parse(&sv(&["train", "--dedup", "--no-dedup"])).unwrap();
        assert!(!run_config_from(&a).unwrap().dedup);
    }

    #[test]
    fn dedup_cli_overrides_toml() {
        let dir = std::env::temp_dir()
            .join(format!("ptdirect_dedup_override_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(&path, "[run]\ndedup = false\n").unwrap();
        let a =
            Args::parse(&sv(&["train", "--config", path.to_str().unwrap(), "--dedup"])).unwrap();
        let cfg = run_config_from(&a).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert!(cfg.dedup, "--dedup must re-enable after TOML dedup=false");
    }

    #[test]
    fn classes_cli_validates() {
        let a = Args::parse(&sv(&["train", "--classes", "12"])).unwrap();
        assert_eq!(run_config_from(&a).unwrap().classes, Some(12));
        let a = Args::parse(&sv(&["train", "--classes", "0"])).unwrap();
        let err = run_config_from(&a).unwrap_err();
        assert!(err.to_string().contains("classes must be >= 1"), "{err}");
        // 2^32 must not wrap into the valid window via `as` truncation.
        let a = Args::parse(&sv(&["train", "--classes", "4294967296"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn help_documents_dedup_and_classes() {
        assert!(HELP.contains("--dedup"));
        assert!(HELP.contains("--no-dedup"));
        assert!(HELP.contains("--classes"));
    }

    #[test]
    fn serving_cli_overrides() {
        let a = Args::parse(&sv(&[
            "serve",
            "--requests",
            "128",
            "--arrival-rps",
            "500",
            "--admit-depth",
            "16",
            "--no-coalesce",
            "--coalesce-limit",
            "4",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.serve_requests, 128);
        assert!((cfg.arrival_rps - 500.0).abs() < 1e-12);
        assert_eq!(cfg.admit_depth, 16);
        assert!(!cfg.coalesce);
        assert_eq!(cfg.coalesce_limit, 4);

        let a = Args::parse(&sv(&["serve", "--clients", "8"])).unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.clients, 8);
        assert!(cfg.coalesce, "coalescing must default on");
        // --no-coalesce wins over --coalesce (mirrors --dedup).
        let a = Args::parse(&sv(&["serve", "--coalesce", "--no-coalesce"])).unwrap();
        assert!(!run_config_from(&a).unwrap().coalesce);
    }

    #[test]
    fn serving_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["serve", "--arrival-rps", "-3"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["serve", "--arrival-rps", "nan"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["serve", "--clients", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["serve", "--admit-depth", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["serve", "--coalesce-limit", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        let a = Args::parse(&sv(&["serve", "--clients", "4294967297"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // closed loop: more clients than queue slots can never all fit.
        let a = Args::parse(&sv(&["serve", "--clients", "64", "--admit-depth", "8"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn help_documents_serving() {
        assert!(HELP.contains("serve"));
        assert!(HELP.contains("--requests"));
        assert!(HELP.contains("--arrival-rps"));
        assert!(HELP.contains("--clients"));
        assert!(HELP.contains("--admit-depth"));
        assert!(HELP.contains("--no-coalesce"));
        assert!(HELP.contains("--coalesce-limit"));
    }

    #[test]
    fn nvme_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "nvme",
            "--host-frac",
            "0.3",
            "--hot-frac",
            "0.1",
            "--nvme-gb-per-s",
            "7.0",
            "--nvme-iops",
            "1000000",
            "--nvme-queue-depth",
            "64",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.mode, AccessMode::Nvme);
        assert!((cfg.host_frac - 0.3).abs() < 1e-12);
        assert!((cfg.hot_frac - 0.1).abs() < 1e-12);
        assert!((cfg.system.nvme.peak_bw - 7e9).abs() < 1.0);
        assert!((cfg.system.nvme.iops - 1e6).abs() < 1e-6);
        assert_eq!(cfg.system.nvme.queue_depth, 64);
    }

    #[test]
    fn nvme_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--host-frac", "1.5"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--nvme-gb-per-s", "-2"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--nvme-iops", "nan"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--nvme-queue-depth", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // 2^32 + 1 must not wrap into the valid window via `as` truncation.
        let a = Args::parse(&sv(&["train", "--nvme-queue-depth", "4294967297"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn system_override_keeps_cli_nvme_constants() {
        // --system replaces the whole profile; CLI nvme overrides must be
        // re-applied on top of the newly selected profile.
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "nvme",
            "--nvme-gb-per-s",
            "12.5",
            "--system",
            "system3",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.system.name, "System3");
        assert!((cfg.system.nvme.peak_bw - 12.5e9).abs() < 1.0);
    }

    #[test]
    fn precision_cli_overrides() {
        let a = Args::parse(&sv(&["train", "--precision", "fp16"])).unwrap();
        assert_eq!(run_config_from(&a).unwrap().precision, crate::config::Precision::Fp16);
        let a = Args::parse(&sv(&["train", "--precision", "int8"])).unwrap();
        assert_eq!(run_config_from(&a).unwrap().precision, crate::config::Precision::Int8);
        // Default is the bit-exact anchor.
        let d = run_config_from(&Args::parse(&sv(&["train"])).unwrap()).unwrap();
        assert_eq!(d.precision, crate::config::Precision::Fp32);
    }

    #[test]
    fn precision_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--precision", "fp64"])).unwrap();
        let err = run_config_from(&a).unwrap_err();
        assert!(err.to_string().contains("unknown precision"), "{err}");
        let a = Args::parse(&sv(&["train", "--precision", "int4"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn precision_cli_overrides_toml() {
        let dir = std::env::temp_dir()
            .join(format!("ptdirect_precision_override_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(&path, "[run]\nprecision = \"int8\"\n").unwrap();
        let a = Args::parse(&sv(&[
            "train",
            "--config",
            path.to_str().unwrap(),
            "--precision",
            "fp16",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(cfg.precision, crate::config::Precision::Fp16);
    }

    #[test]
    fn help_documents_precision() {
        assert!(HELP.contains("--precision fp32|fp16|int8"));
        assert!(HELP.contains("scale+zero-point"));
    }

    #[test]
    fn pushdown_cli_flags() {
        let cfg = run_config_from(&Args::parse(&sv(&["train"])).unwrap()).unwrap();
        assert!(!cfg.aggregate_pushdown, "pushdown must default off");
        let a = Args::parse(&sv(&["train", "--aggregate-pushdown"])).unwrap();
        assert!(run_config_from(&a).unwrap().aggregate_pushdown);
        // --no-pushdown wins over --aggregate-pushdown (the regression
        // anchor escape hatch, mirroring --no-dedup).
        let a = Args::parse(&sv(&["train", "--aggregate-pushdown", "--no-pushdown"])).unwrap();
        assert!(!run_config_from(&a).unwrap().aggregate_pushdown);
    }

    #[test]
    fn pushdown_cli_overrides_toml() {
        let dir = std::env::temp_dir()
            .join(format!("ptdirect_pushdown_override_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(&path, "[run]\naggregate_pushdown = true\n").unwrap();
        let a = Args::parse(&sv(&[
            "train",
            "--config",
            path.to_str().unwrap(),
            "--no-pushdown",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert!(!cfg.aggregate_pushdown, "--no-pushdown must override TOML");
    }

    #[test]
    fn help_documents_pushdown() {
        assert!(HELP.contains("--aggregate-pushdown"));
        assert!(HELP.contains("--no-pushdown"));
        assert!(HELP.contains("AGGREGATION PUSH-DOWN"));
    }

    #[test]
    fn multi_host_cli_overrides() {
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "sharded",
            "--num-hosts",
            "4",
            "--fetch-strategy",
            "local",
            "--net-gb-per-s",
            "50",
            "--net-latency-us",
            "5",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.num_hosts, 4);
        assert_eq!(cfg.fetch_strategy, FetchStrategy::PartitionLocal);
        assert!((cfg.system.net.peak_bw - 50e9).abs() < 1.0);
        assert!((cfg.system.net.latency_s - 5e-6).abs() < 1e-12);
        // Defaults are the single-host anchor.
        let d = run_config_from(&Args::parse(&sv(&["train"])).unwrap()).unwrap();
        assert_eq!(d.num_hosts, 1);
        assert_eq!(d.fetch_strategy, FetchStrategy::RemoteFetch);
    }

    #[test]
    fn multi_host_cli_rejects_bad_values() {
        let a = Args::parse(&sv(&["train", "--mode", "sharded", "--num-hosts", "0"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--mode", "sharded", "--num-hosts", "65"])).unwrap();
        assert!(run_config_from(&a).is_err());
        // hosts > 1 needs the sharded store's host-owner map.
        let a = Args::parse(&sv(&["train", "--mode", "tiered", "--num-hosts", "2"])).unwrap();
        let err = run_config_from(&a).unwrap_err();
        assert!(err.to_string().contains("sharded"), "{err}");
        let a = Args::parse(&sv(&["train", "--fetch-strategy", "teleport"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--net-gb-per-s", "-1"])).unwrap();
        assert!(run_config_from(&a).is_err());
        let a = Args::parse(&sv(&["train", "--net-latency-us", "nan"])).unwrap();
        assert!(run_config_from(&a).is_err());
    }

    #[test]
    fn nvlink_cli_flag_reaches_the_profile() {
        // The table-driven knob walk adds the long-missing CLI arm for
        // the NVLink override (previously TOML-only).
        let a = Args::parse(&sv(&[
            "train",
            "--mode",
            "sharded",
            "--nvlink-gb-per-s",
            "100",
            "--system",
            "system2",
        ]))
        .unwrap();
        let cfg = run_config_from(&a).unwrap();
        assert_eq!(cfg.system.name, "System2");
        assert!((cfg.system.nvlink.peak_bw - 100e9).abs() < 1.0);
    }

    #[test]
    fn help_documents_the_multi_host_tier() {
        assert!(HELP.contains("MULTI-HOST NETWORK TIER"));
        assert!(HELP.contains("--num-hosts"));
        assert!(HELP.contains("--fetch-strategy"));
        assert!(HELP.contains("--net-gb-per-s"));
        assert!(HELP.contains("--net-latency-us"));
        assert!(HELP.contains("--nvlink-gb-per-s"));
    }

    #[test]
    fn help_documents_nvme_mode() {
        assert!(HELP.contains("nvme"));
        assert!(HELP.contains("--host-frac"));
        assert!(HELP.contains("--nvme-gb-per-s"));
        assert!(HELP.contains("--nvme-iops"));
        assert!(HELP.contains("--nvme-queue-depth"));
    }
}

//! Mini-batch gather compaction: deduplicate the requested node set and
//! plan a gather-unique / scatter-back execution of the feature fetch.
//!
//! Neighbor-sampled mini-batches request one feature row per `(dst,
//! fanout)` slot, so the gather stream `MiniBatch::src_nodes` is a
//! *multiset*: hub nodes of a skewed graph appear dozens of times per
//! batch.  The GPU-oriented communication follow-up (arXiv:2103.03330)
//! and GIDS (arXiv:2306.16384) both identify deduplicating that stream as
//! the single largest transfer reduction available — every duplicate row
//! fetched over PCIe/NVLink/NVMe is pure waste, because the row is already
//! on its way for the first occurrence.
//!
//! [`GatherPlan`] is that deduplication, captured once per batch:
//!
//! * [`GatherPlan::unique_nodes`] — the distinct requested ids in
//!   first-appearance order (the compacted id stream every cost model
//!   prices: warp request coalescing, hot-tier hit accounting, per-shard
//!   peer traffic, and NVMe block I/Os all consume this);
//! * [`GatherPlan::scatter_map`] — the inverse permutation: position `i`
//!   of the requested stream is served by unique row `scatter_map()[i]`,
//!   so one cheap device-memory scatter rebuilds the exact `[requested,
//!   f]` layout the model consumes.  Numerics are bitwise identical to
//!   the naive duplicated gather by construction (rows are copied, never
//!   recomputed).
//!
//! The plan is pure metadata — it never touches feature values — which is
//! what lets every access mode share it: the trainer builds one plan per
//! batch and threads it through
//! [`FeatureStore::gather_planned`](crate::featurestore::FeatureStore::gather_planned)
//! (or [`index_select_planned`](crate::tensor::indexing::index_select_planned)
//! for raw tensors).  `--no-dedup` skips the plan entirely and reproduces
//! the duplicated stream bit-exactly — the regression anchor pinned by
//! `tests/dedup_properties.rs`.
//!
//! ```
//! use ptdirect::sampler::GatherPlan;
//!
//! let requested = [7u32, 3, 7, 7, 1, 3];
//! let plan = GatherPlan::build(&requested);
//! assert_eq!(plan.unique_nodes(), &[7, 3, 1]);        // first-appearance order
//! assert_eq!(plan.scatter_map(), &[0, 1, 0, 0, 2, 1]); // inverse permutation
//! assert!(plan.dedup_ratio() == 2.0);                  // 6 requested / 3 unique
//! ```

use std::collections::HashMap;

/// Deduplicated gather plan for one requested id stream (see the module
/// docs for the model).
#[derive(Clone, Debug)]
pub struct GatherPlan {
    /// Distinct requested ids, first-appearance order.
    unique: Vec<u32>,
    /// `scatter[i]` = index into `unique` serving requested position `i`.
    scatter: Vec<u32>,
}

impl GatherPlan {
    /// Compact a requested id stream: every distinct id keeps its
    /// first-appearance position in the unique stream (so the compacted
    /// stream is still the order the warps issue their first-touch
    /// requests in), and the scatter map records where each requested
    /// slot finds its row.
    ///
    /// This runs once per batch on the gather stage's hot path, so the
    /// lookup structure matters: when the id range is compact relative
    /// to the batch (the common case — scaled graphs, skewed batches) a
    /// dense slot table gives O(1) unhashed lookups; wildly sparse ids
    /// fall back to a `HashMap`.  Both paths produce the identical plan.
    pub fn build(requested: &[u32]) -> GatherPlan {
        const VACANT: u32 = u32::MAX;
        let mut unique = Vec::new();
        let mut scatter = Vec::with_capacity(requested.len());
        let max_id = requested.iter().copied().max().map_or(0, |m| m as usize);
        if max_id < requested.len().saturating_mul(4).max(1024) {
            let mut pos = vec![VACANT; max_id + 1];
            for &r in requested {
                let slot = &mut pos[r as usize];
                if *slot == VACANT {
                    *slot = unique.len() as u32;
                    unique.push(r);
                }
                scatter.push(*slot);
            }
        } else {
            let mut pos: HashMap<u32, u32> = HashMap::with_capacity(requested.len());
            for &r in requested {
                let p = match pos.entry(r) {
                    std::collections::hash_map::Entry::Occupied(e) => *e.get(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        let p = unique.len() as u32;
                        unique.push(r);
                        e.insert(p);
                        p
                    }
                };
                scatter.push(p);
            }
        }
        GatherPlan { unique, scatter }
    }

    /// The compacted id stream — what every cost model should price.
    pub fn unique_nodes(&self) -> &[u32] {
        &self.unique
    }

    /// Inverse permutation: requested position `i` reads unique row
    /// `scatter_map()[i]`.
    pub fn scatter_map(&self) -> &[u32] {
        &self.scatter
    }

    /// Rows of the original (duplicated) request stream.
    pub fn requested_rows(&self) -> usize {
        self.scatter.len()
    }

    /// Rows actually fetched after deduplication.
    pub fn unique_rows(&self) -> usize {
        self.unique.len()
    }

    /// Duplicate rows the plan eliminates (`requested - unique`).
    pub fn rows_saved(&self) -> usize {
        self.scatter.len() - self.unique.len()
    }

    /// Requested over unique rows (≥ 1; 1.0 for an empty or
    /// duplicate-free stream).
    pub fn dedup_ratio(&self) -> f64 {
        if self.unique.is_empty() {
            1.0
        } else {
            self.scatter.len() as f64 / self.unique.len() as f64
        }
    }

    /// Scatter gathered unique rows back to the requested layout:
    /// `out[i] = uniq[scatter[i]]` row-wise for `f`-wide f32 rows.  This
    /// is the inverse of the compaction, so `scatter ∘ gather-unique` is
    /// bitwise identical to gathering the duplicated stream directly
    /// (pinned by `tests/dedup_properties.rs`).
    pub fn scatter_rows(&self, uniq: &[f32], f: usize, out: &mut [f32]) {
        debug_assert_eq!(uniq.len(), self.unique.len() * f);
        debug_assert_eq!(out.len(), self.scatter.len() * f);
        for (chunk, &u) in out.chunks_exact_mut(f).zip(&self.scatter) {
            let lo = u as usize * f;
            chunk.copy_from_slice(&uniq[lo..lo + f]);
        }
    }

    /// Remap a layer's `nbr` slot indices — positions into the requested
    /// src stream this plan compacted — to positions into
    /// [`GatherPlan::unique_nodes`].  This is the per-layer view a kernel
    /// consuming the compacted feature buffer directly would use; the
    /// default execution path keeps the original indices and scatters the
    /// rows instead ([`GatherPlan::scatter_rows`]), which is what keeps
    /// numerics bitwise identical to the naive gather.
    pub fn remap_nbr(&self, nbr: &[i32]) -> Vec<i32> {
        nbr.iter().map(|&i| self.scatter[i as usize] as i32).collect()
    }

    /// Structural invariants (used by tests and debug assertions):
    /// the unique stream is duplicate-free, the scatter map is in range,
    /// and `unique[scatter[i]]` round-trips every requested id.
    pub fn validate(&self, requested: &[u32]) -> Result<(), String> {
        if self.scatter.len() != requested.len() {
            return Err(format!(
                "scatter len {} != requested {}",
                self.scatter.len(),
                requested.len()
            ));
        }
        if self.unique.len() > self.scatter.len() && !requested.is_empty() {
            return Err("more unique rows than requested".into());
        }
        let mut seen = std::collections::HashSet::new();
        if !self.unique.iter().all(|&u| seen.insert(u)) {
            return Err("unique stream contains duplicates".into());
        }
        for (i, (&r, &s)) in requested.iter().zip(&self.scatter).enumerate() {
            match self.unique.get(s as usize) {
                Some(&u) if u == r => {}
                Some(&u) => return Err(format!("slot {i}: unique[{s}] = {u} != requested {r}")),
                None => return Err(format!("slot {i}: scatter {s} out of range")),
            }
        }
        Ok(())
    }
}

/// Cross-request gather plan for the serving engine: one [`GatherPlan`]
/// over the *concatenation* of several requests' id streams, plus the
/// per-request slot bounds needed to scatter each request's rows back
/// independently.
///
/// Coalescing concurrent inference requests into one minibatch extends the
/// per-batch dedup across request boundaries — hub rows requested by two
/// queued clients cross the link once.  The pinned invariant (see
/// `tests/serving_properties.rs`): [`CoalescedGatherPlan::scatter_request`]
/// rebuilds each request's `[rows, f]` block bitwise identical to serving
/// that request alone, because rows are copied from the same gathered
/// table, never recomputed.
#[derive(Clone, Debug)]
pub struct CoalescedGatherPlan {
    plan: GatherPlan,
    /// `bounds[r]..bounds[r + 1]` = request `r`'s slots in the
    /// concatenated stream (`bounds.len() == requests + 1`).
    bounds: Vec<usize>,
}

impl CoalescedGatherPlan {
    /// Build from per-request id streams (FIFO order of the admission
    /// queue, so the unique stream's first-appearance order is the order
    /// requests were admitted).
    pub fn build(streams: &[&[u32]]) -> CoalescedGatherPlan {
        let total: usize = streams.iter().map(|s| s.len()).sum();
        let mut concat = Vec::with_capacity(total);
        let mut bounds = Vec::with_capacity(streams.len() + 1);
        bounds.push(0);
        for s in streams {
            concat.extend_from_slice(s);
            bounds.push(concat.len());
        }
        CoalescedGatherPlan {
            plan: GatherPlan::build(&concat),
            bounds,
        }
    }

    /// The merged dedup plan over the concatenated stream.
    pub fn plan(&self) -> &GatherPlan {
        &self.plan
    }

    /// Distinct ids across all member requests, first-appearance order.
    pub fn unique_nodes(&self) -> &[u32] {
        self.plan.unique_nodes()
    }

    /// Member request count.
    pub fn requests(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Rows request `r` asked for.
    pub fn request_rows(&self, r: usize) -> usize {
        self.bounds[r + 1] - self.bounds[r]
    }

    /// Rows of the concatenated (duplicated) stream.
    pub fn requested_rows(&self) -> usize {
        self.plan.requested_rows()
    }

    /// Rows actually fetched after cross-request deduplication.
    pub fn unique_rows(&self) -> usize {
        self.plan.unique_rows()
    }

    /// Requested over unique rows across the whole coalesced batch.
    pub fn dedup_ratio(&self) -> f64 {
        self.plan.dedup_ratio()
    }

    /// Scatter request `r`'s rows out of the gathered unique buffer:
    /// `out` is that request's own `[request_rows(r), f]` block, laid out
    /// exactly as an uncoalesced gather of its stream would produce it.
    pub fn scatter_request(&self, r: usize, uniq: &[f32], f: usize, out: &mut [f32]) {
        let (lo, hi) = (self.bounds[r], self.bounds[r + 1]);
        debug_assert_eq!(uniq.len(), self.plan.unique.len() * f);
        debug_assert_eq!(out.len(), (hi - lo) * f);
        for (chunk, &u) in out.chunks_exact_mut(f).zip(&self.plan.scatter[lo..hi]) {
            let base = u as usize * f;
            chunk.copy_from_slice(&uniq[base..base + f]);
        }
    }

    /// Structural invariants on top of [`GatherPlan::validate`]: bounds
    /// are monotone, cover the concatenation exactly, and each member
    /// stream round-trips through the merged plan.
    pub fn validate(&self, streams: &[&[u32]]) -> Result<(), String> {
        if self.bounds.len() != streams.len() + 1 {
            return Err(format!(
                "bounds len {} != streams {} + 1",
                self.bounds.len(),
                streams.len()
            ));
        }
        let mut concat = Vec::new();
        for (r, s) in streams.iter().enumerate() {
            if self.bounds[r + 1] < self.bounds[r] {
                return Err(format!("bounds not monotone at request {r}"));
            }
            if self.request_rows(r) != s.len() {
                return Err(format!(
                    "request {r}: bounds span {} != stream len {}",
                    self.request_rows(r),
                    s.len()
                ));
            }
            concat.extend_from_slice(s);
        }
        if *self.bounds.last().unwrap() != concat.len() {
            return Err("bounds do not cover the concatenation".into());
        }
        self.plan.validate(&concat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert, Gen};

    #[test]
    fn unique_keeps_first_appearance_order() {
        let plan = GatherPlan::build(&[5, 2, 5, 9, 2, 2]);
        assert_eq!(plan.unique_nodes(), &[5, 2, 9]);
        assert_eq!(plan.scatter_map(), &[0, 1, 0, 2, 1, 1]);
        assert_eq!(plan.requested_rows(), 6);
        assert_eq!(plan.unique_rows(), 3);
        assert_eq!(plan.rows_saved(), 3);
        assert!((plan.dedup_ratio() - 2.0).abs() < 1e-12);
        plan.validate(&[5, 2, 5, 9, 2, 2]).unwrap();
    }

    #[test]
    fn duplicate_free_stream_is_identity() {
        let requested = [3u32, 1, 4, 5, 9];
        let plan = GatherPlan::build(&requested);
        assert_eq!(plan.unique_nodes(), &requested);
        assert_eq!(plan.scatter_map(), &[0, 1, 2, 3, 4]);
        assert_eq!(plan.dedup_ratio(), 1.0);
    }

    #[test]
    fn sparse_ids_take_the_hashmap_path_with_the_same_contract() {
        // max id >> 4x the batch length forces the sparse fallback; the
        // plan must be indistinguishable from the dense path's.
        let requested = [4_000_000_000u32, 7, 4_000_000_000, 123_456_789, 7];
        let plan = GatherPlan::build(&requested);
        assert_eq!(plan.unique_nodes(), &[4_000_000_000, 7, 123_456_789]);
        assert_eq!(plan.scatter_map(), &[0, 1, 0, 2, 1]);
        plan.validate(&requested).unwrap();
    }

    #[test]
    fn dense_and_sparse_paths_agree_property() {
        // The same logical stream, once with compact ids (dense slot
        // table) and once shifted into sparse territory (HashMap path):
        // unique ordering and scatter structure must match exactly.
        check(30, |g: &mut Gen| {
            let n = g.usize_in(1, 120);
            let compact_ids = g.vec_u32(n, 0, 40);
            let sparse_ids: Vec<u32> =
                compact_ids.iter().map(|&r| r * 50_000_000 + 3).collect();
            let a = GatherPlan::build(&compact_ids);
            let b = GatherPlan::build(&sparse_ids);
            prop_assert(a.scatter_map() == b.scatter_map(), "scatter maps diverged")?;
            prop_assert(
                a.unique_nodes().len() == b.unique_nodes().len(),
                "unique counts diverged",
            )?;
            let mapped: Vec<u32> =
                a.unique_nodes().iter().map(|&r| r * 50_000_000 + 3).collect();
            prop_assert(mapped == b.unique_nodes(), "unique order diverged")
        });
    }

    #[test]
    fn adversarial_id_ranges_agree_across_paths_property() {
        // Sparse-path stress: ids drawn from the *full* u32 range (far
        // past the dense slot-table cutoff) against the dense path run
        // on the equality-pattern-preserving compaction of the same
        // stream (rank ids by first appearance).  The plan depends only
        // on the equality pattern, so the two scatter maps must agree
        // position for position and the unique streams must correspond
        // rank for rank.
        check(40, |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            // A small pool forces heavy duplication even across a huge
            // id range; a large pool approaches duplicate-free.
            let pool_sz = g.usize_in(1, 48);
            let pool: Vec<u32> = (0..pool_sz)
                .map(|_| g.u64_in(0, u32::MAX as u64) as u32)
                .collect();
            let wild: Vec<u32> = (0..n).map(|_| pool[g.usize_in(0, pool_sz - 1)]).collect();
            let wild_plan = GatherPlan::build(&wild);
            wild_plan.validate(&wild).map_err(|e| e)?;

            let mut rank: HashMap<u32, u32> = HashMap::new();
            let dense: Vec<u32> = wild
                .iter()
                .map(|&r| {
                    let next = rank.len() as u32;
                    *rank.entry(r).or_insert(next)
                })
                .collect();
            let dense_plan = GatherPlan::build(&dense);
            dense_plan.validate(&dense).map_err(|e| e)?;
            prop_assert(
                wild_plan.scatter_map() == dense_plan.scatter_map(),
                "scatter maps diverged between sparse and dense paths",
            )?;
            let ranked: Vec<u32> = wild_plan.unique_nodes().iter().map(|&r| rank[&r]).collect();
            prop_assert(
                ranked == dense_plan.unique_nodes(),
                "unique order diverged between sparse and dense paths",
            )
        });
    }

    #[test]
    fn all_duplicate_and_singleton_batches_collapse_correctly_property() {
        check(40, |g: &mut Gen| {
            // All-duplicate: n copies of one id anywhere in the u32
            // range (huge ids exercise the sparse path, small ones the
            // dense one) collapse to a single fetched row.
            let n = g.usize_in(1, 300);
            let id = g.u64_in(0, u32::MAX as u64) as u32;
            let dup = vec![id; n];
            let plan = GatherPlan::build(&dup);
            plan.validate(&dup).map_err(|e| e)?;
            prop_assert(plan.unique_nodes() == [id], "all-duplicate unique != [id]")?;
            prop_assert(
                plan.scatter_map().iter().all(|&s| s == 0),
                "all-duplicate scatter not all-zero",
            )?;
            prop_assert(
                (plan.dedup_ratio() - n as f64).abs() < 1e-9,
                "all-duplicate ratio != n",
            )?;

            // Singleton batch: one slot, arbitrary id — the identity plan.
            let solo = g.u64_in(0, u32::MAX as u64) as u32;
            let plan = GatherPlan::build(&[solo]);
            plan.validate(&[solo]).map_err(|e| e)?;
            prop_assert(plan.unique_nodes() == [solo], "singleton unique != [id]")?;
            prop_assert(plan.scatter_map() == [0], "singleton scatter != [0]")
        });
    }

    #[test]
    fn empty_stream_is_empty_plan() {
        let plan = GatherPlan::build(&[]);
        assert_eq!(plan.unique_rows(), 0);
        assert_eq!(plan.requested_rows(), 0);
        assert_eq!(plan.dedup_ratio(), 1.0);
        plan.validate(&[]).unwrap();
    }

    #[test]
    fn scatter_rows_rebuilds_the_requested_layout() {
        let requested = [2u32, 0, 2, 1];
        let plan = GatherPlan::build(&requested);
        // unique = [2, 0, 1]; 2-wide rows keyed by id for readability.
        let uniq = [20.0, 21.0, 0.0, 1.0, 10.0, 11.0];
        let mut out = [0f32; 8];
        plan.scatter_rows(&uniq, 2, &mut out);
        assert_eq!(out, [20.0, 21.0, 0.0, 1.0, 20.0, 21.0, 10.0, 11.0]);
    }

    #[test]
    fn remap_nbr_points_slots_at_unique_positions() {
        // Requested stream [7, 3, 7]; a nbr slot pointing at position 2
        // (the duplicate 7) must remap to unique position 0.
        let plan = GatherPlan::build(&[7, 3, 7]);
        assert_eq!(plan.remap_nbr(&[2, 1, 0]), vec![0, 1, 0]);
    }

    #[test]
    fn plan_invariants_hold_property() {
        check(60, |g: &mut Gen| {
            let n = g.usize_in(0, 400);
            let requested = g.vec_u32(n, 0, 50); // heavy duplication
            let plan = GatherPlan::build(&requested);
            plan.validate(&requested).map_err(|e| e)?;
            prop_assert(
                plan.dedup_ratio() >= 1.0 - 1e-12,
                format!("ratio {} < 1", plan.dedup_ratio()),
            )?;
            // unique set == requested set (no row lost, none invented)
            let mut a: Vec<u32> = plan.unique_nodes().to_vec();
            let mut b: Vec<u32> = requested.clone();
            a.sort_unstable();
            b.sort_unstable();
            b.dedup();
            prop_assert(a == b, "unique set != requested set")
        });
    }

    #[test]
    fn coalesced_plan_dedups_across_requests() {
        // id 7 appears in both requests: fetched once, scattered to both
        let a: &[u32] = &[7, 3];
        let b: &[u32] = &[7, 9, 3];
        let plan = CoalescedGatherPlan::build(&[a, b]);
        assert_eq!(plan.requests(), 2);
        assert_eq!(plan.unique_nodes(), &[7, 3, 9]);
        assert_eq!(plan.requested_rows(), 5);
        assert_eq!(plan.unique_rows(), 3);
        assert_eq!(plan.request_rows(0), 2);
        assert_eq!(plan.request_rows(1), 3);
        plan.validate(&[a, b]).unwrap();
    }

    #[test]
    fn coalesced_single_request_degenerates_to_gather_plan() {
        let s: &[u32] = &[5, 2, 5, 9];
        let coal = CoalescedGatherPlan::build(&[s]);
        let solo = GatherPlan::build(s);
        assert_eq!(coal.unique_nodes(), solo.unique_nodes());
        assert_eq!(coal.plan().scatter_map(), solo.scatter_map());
        assert_eq!(coal.requests(), 1);
    }

    #[test]
    fn scatter_request_is_bitwise_identical_to_solo_gather_property() {
        // The pinned serving invariant at the plan level: each member
        // request's scattered block equals a direct gather of its stream.
        check(40, |g: &mut Gen| {
            let f = g.usize_in(1, 6);
            let n_req = g.usize_in(1, 5);
            let streams: Vec<Vec<u32>> = (0..n_req)
                .map(|_| g.vec_u32(g.usize_in(1, 40), 0, 30))
                .collect();
            let refs: Vec<&[u32]> = streams.iter().map(|s| s.as_slice()).collect();
            let plan = CoalescedGatherPlan::build(&refs);
            plan.validate(&refs).map_err(|e| e)?;

            let table: Vec<f32> = (0..31 * f).map(|i| (i as f32).sin()).collect();
            let mut uniq = vec![0f32; plan.unique_rows() * f];
            crate::tensor::indexing::gather_rows_into(&table, f, plan.unique_nodes(), &mut uniq);

            for (r, s) in streams.iter().enumerate() {
                let mut via_plan = vec![0f32; s.len() * f];
                plan.scatter_request(r, &uniq, f, &mut via_plan);
                let mut direct = vec![0f32; s.len() * f];
                crate::tensor::indexing::gather_rows_into(&table, f, s, &mut direct);
                prop_assert(
                    via_plan == direct,
                    format!("request {r}: coalesced scatter != solo gather"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn coalesced_empty_request_is_allowed() {
        let a: &[u32] = &[1, 2];
        let b: &[u32] = &[];
        let plan = CoalescedGatherPlan::build(&[a, b]);
        assert_eq!(plan.request_rows(1), 0);
        let mut out = vec![];
        plan.scatter_request(1, &[0.0, 0.0], 1, &mut out);
        plan.validate(&[a, b]).unwrap();
    }

    #[test]
    fn scatter_gather_identity_property() {
        check(40, |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            let f = g.usize_in(1, 8);
            let requested = g.vec_u32(n, 0, 60);
            let rows = 61usize;
            let table: Vec<f32> = (0..rows * f).map(|i| i as f32).collect();
            let plan = GatherPlan::build(&requested);

            let mut uniq = vec![0f32; plan.unique_rows() * f];
            crate::tensor::indexing::gather_rows_into(&table, f, plan.unique_nodes(), &mut uniq);
            let mut via_plan = vec![0f32; n * f];
            plan.scatter_rows(&uniq, f, &mut via_plan);

            let mut direct = vec![0f32; n * f];
            crate::tensor::indexing::gather_rows_into(&table, f, &requested, &mut direct);
            prop_assert(via_plan == direct, "scatter∘gather != direct gather")
        });
    }
}

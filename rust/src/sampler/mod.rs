//! Mini-batch neighbor sampling (the "complicated tasks such as traversing
//! neighboring nodes" of the paper's abstract).
//!
//! Produces fixed-shape message-flow-graph blocks matching the calling
//! convention of the AOT-compiled models (python/compile/model.py): layer
//! `l` maps `n_l` source nodes to `n_{l+1}` destination nodes, destinations
//! are the prefix of the source array, and every destination owns exactly
//! `fanout_l` neighbor slots (padded + masked when the true degree is
//! smaller, duplicated when sampling with replacement).
//!
//! The sampler is the *producer* side of the §5 split (DESIGN.md): its
//! `src_nodes` array is the gather index stream every access mode costs —
//! identical whatever the mode, which is what makes loss trajectories
//! bitwise comparable across them.  Sampling itself is host work: the
//! simulated epoch charges it per examined edge
//! (`SystemProfile::sample_s_per_edge`), the measured side times the real
//! traversal.  [`NeighborSampler`] seeds deterministically from the run
//! RNG, so a `(seed, batch, fanouts)` triple fully determines every batch
//! — the property the end-to-end suite leans on.

//!
//! The `src_nodes` stream is a *multiset* — hub nodes recur across slots —
//! so [`compact`] plans a deduplicated gather ([`GatherPlan`]): fetch each
//! distinct row once, scatter back via the inverse permutation.  Enabled
//! by default (`--no-dedup` restores the duplicated stream bit-exactly).
//!
//! [`aggregate`] plans the near-memory push-down (`--aggregate-pushdown`,
//! DESIGN.md §14): each layer-0 destination's masked neighbors in pinned
//! ascending-global-id order, so tiers can ship one partial-aggregate row
//! per destination instead of `fanout` raw rows, bitwise-reproducibly.

pub mod aggregate;
pub mod batch;
pub mod compact;
pub mod neighbor;

pub use aggregate::AggregatePlan;
pub use batch::{LayerBlock, MiniBatch};
pub use compact::{CoalescedGatherPlan, GatherPlan};
pub use neighbor::NeighborSampler;

//! Mini-batch neighbor sampling (the "complicated tasks such as traversing
//! neighboring nodes" of the paper's abstract).
//!
//! Produces fixed-shape message-flow-graph blocks matching the calling
//! convention of the AOT-compiled models (python/compile/model.py): layer
//! `l` maps `n_l` source nodes to `n_{l+1}` destination nodes, destinations
//! are the prefix of the source array, and every destination owns exactly
//! `fanout_l` neighbor slots (padded + masked when the true degree is
//! smaller, duplicated when sampling with replacement).

pub mod batch;
pub mod neighbor;

pub use batch::{LayerBlock, MiniBatch};
pub use neighbor::NeighborSampler;

//! Mini-batch block structures (DGL-style MFGs, fixed shapes for AOT).

/// One sampling layer: `n_dst` destinations, each with `fanout` neighbor
/// slots pointing into the layer's source node array.
#[derive(Clone, Debug)]
pub struct LayerBlock {
    pub n_dst: usize,
    pub fanout: usize,
    /// Local neighbor indices, row-major `[n_dst, fanout]`, each in
    /// `[0, n_src)` where `n_src = n_dst * (1 + fanout)`.
    pub nbr: Vec<i32>,
    /// 1.0 = real sampled neighbor, 0.0 = padding (degree < fanout).
    pub mask: Vec<f32>,
}

impl LayerBlock {
    pub fn n_src(&self) -> usize {
        self.n_dst * (1 + self.fanout)
    }

    /// Fraction of neighbor slots holding real samples.
    pub fn fill_ratio(&self) -> f64 {
        if self.mask.is_empty() {
            return 0.0;
        }
        self.mask.iter().map(|&m| m as f64).sum::<f64>() / self.mask.len() as f64
    }

    /// Structural invariants (used by tests and debug assertions).
    pub fn validate(&self) -> Result<(), String> {
        if self.nbr.len() != self.n_dst * self.fanout {
            return Err(format!(
                "nbr len {} != {}x{}",
                self.nbr.len(),
                self.n_dst,
                self.fanout
            ));
        }
        if self.mask.len() != self.nbr.len() {
            return Err("mask/nbr length mismatch".into());
        }
        let n_src = self.n_src() as i32;
        if let Some(&bad) = self.nbr.iter().find(|&&i| i < 0 || i >= n_src) {
            return Err(format!("nbr {bad} out of [0,{n_src})"));
        }
        if self.mask.iter().any(|&m| m != 0.0 && m != 1.0) {
            return Err("mask values must be 0/1".into());
        }
        Ok(())
    }
}

/// A complete sampled mini-batch.
#[derive(Clone, Debug)]
pub struct MiniBatch {
    /// Global node ids of the input layer's source array (`n_0` entries) —
    /// the rows the feature gather must fetch. THE hot set of the paper.
    pub src_nodes: Vec<u32>,
    /// Blocks input-side first: `layers[l]` consumes layer `l`'s sources.
    pub layers: Vec<LayerBlock>,
    /// Batch roots (global ids), `batch` entries.
    pub seeds: Vec<u32>,
    /// Class labels for the roots.
    pub labels: Vec<i32>,
}

impl MiniBatch {
    pub fn batch_size(&self) -> usize {
        self.seeds.len()
    }

    /// Total feature rows the gather stage *requests* (duplicates
    /// included — see [`MiniBatch::compact`] for the deduplicated count).
    pub fn gather_rows(&self) -> usize {
        self.src_nodes.len()
    }

    /// Plan a deduplicated gather of this batch's `src_nodes` stream
    /// (unique ids + inverse-permutation scatter map; see
    /// [`GatherPlan`](crate::sampler::compact::GatherPlan)).
    pub fn compact(&self) -> crate::sampler::compact::GatherPlan {
        crate::sampler::compact::GatherPlan::build(&self.src_nodes)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err("no layers".into());
        }
        // chain: n_src of layer l == n_dst of layer l * (1+fanout); and
        // layer l+1's n_src must equal layer l's n_dst.
        if self.src_nodes.len() != self.layers[0].n_src() {
            return Err(format!(
                "src_nodes {} != layer0 n_src {}",
                self.src_nodes.len(),
                self.layers[0].n_src()
            ));
        }
        for w in self.layers.windows(2) {
            if w[1].n_src() != w[0].n_dst {
                return Err(format!(
                    "layer chain mismatch: {} vs {}",
                    w[1].n_src(),
                    w[0].n_dst
                ));
            }
        }
        if self.layers.last().unwrap().n_dst != self.seeds.len() {
            return Err("last layer n_dst != batch".into());
        }
        if self.labels.len() != self.seeds.len() {
            return Err("labels != seeds".into());
        }
        for l in &self.layers {
            l.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_block_validation() {
        let ok = LayerBlock {
            n_dst: 2,
            fanout: 2,
            nbr: vec![2, 3, 4, 5],
            mask: vec![1.0, 1.0, 0.0, 1.0],
        };
        ok.validate().unwrap();
        assert_eq!(ok.n_src(), 6);
        assert!((ok.fill_ratio() - 0.75).abs() < 1e-12);

        let bad = LayerBlock {
            n_dst: 2,
            fanout: 2,
            nbr: vec![2, 3, 4, 6], // 6 >= n_src
            mask: vec![1.0; 4],
        };
        assert!(bad.validate().is_err());
    }
}

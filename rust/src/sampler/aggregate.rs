//! Per-destination aggregation plan for near-memory push-down (DESIGN.md §14).
//!
//! GNNear's observation (arXiv:2111.00680): the first thing a SAGE-style
//! layer does with the gathered neighbor rows is *reduce* them (sum/mean per
//! destination).  If the reduction moves to where the rows live, the link
//! only has to carry one partial-aggregate row per destination plus a
//! per-destination neighbor count — a model-aware traffic cut of up to the
//! fan-out factor, multiplicative with the PR 5 dedup.
//!
//! [`AggregatePlan`] is the sampler-side artifact, built beside
//! [`GatherPlan`](crate::sampler::compact::GatherPlan) from a mini-batch's
//! input layer (layer 0, the widest one — the layer whose sources are the
//! full `src_nodes` gather stream).  It records, for every layer-0
//! destination, the *masked* neighbor slots in a pinned canonical order:
//! **ascending global neighbor id** (stable, so duplicate ids keep their
//! slot order — bitwise harmless, identical rows sum identically in either
//! order).  That single pinned order is what makes the pushed-down sum
//! bitwise reproducible: every tier sums its resident subsequence in this
//! order and the tier partials combine by ascending id again, which is
//! associativity-free — each destination's neighbors are summed left to
//! right over one globally sorted list, no matter how placement slices it.
//!
//! The plan is placement-agnostic: tier classification (which neighbors are
//! GPU-hot, host-resident, peer-sharded, or NVMe-cold) happens in
//! [`FeatureStore::pushdown_cost`](crate::featurestore::FeatureStore::pushdown_cost),
//! which walks `neighbor_ids()` read-only against the store's current
//! residency maps.

use crate::sampler::batch::MiniBatch;
use crate::error::{Error, Result};

/// CSR of each layer-0 destination's masked neighbors, sorted ascending by
/// global id — the pinned floating-point reduction order for push-down.
#[derive(Clone, Debug)]
pub struct AggregatePlan {
    n_dst: usize,
    fanout: usize,
    /// Global ids of the `n_dst` destinations (the `src_nodes` prefix).
    dst_nodes: Vec<u32>,
    /// CSR offsets, `n_dst + 1` entries.
    offsets: Vec<u32>,
    /// Global neighbor ids, ascending within each destination's segment.
    nbr_ids: Vec<u32>,
    /// Matching local row index into the `src_nodes` feature matrix.
    nbr_slots: Vec<u32>,
}

impl AggregatePlan {
    /// Build the plan from a batch's input layer (`mb.layers[0]`).
    pub fn build(mb: &MiniBatch) -> Result<AggregatePlan> {
        let l0 = mb
            .layers
            .first()
            .ok_or_else(|| Error::Pipeline("aggregate plan needs >= 1 layer".into()))?;
        let n_dst = l0.n_dst;
        let fanout = l0.fanout;
        if mb.src_nodes.len() != l0.n_src() {
            return Err(Error::Pipeline(format!(
                "src_nodes {} != layer0 n_src {}",
                mb.src_nodes.len(),
                l0.n_src()
            )));
        }
        let dst_nodes = mb.src_nodes[..n_dst].to_vec();
        let mut offsets = Vec::with_capacity(n_dst + 1);
        let mut nbr_ids = Vec::with_capacity(n_dst * fanout);
        let mut nbr_slots = Vec::with_capacity(n_dst * fanout);
        let mut seg: Vec<(u32, u32)> = Vec::with_capacity(fanout);
        offsets.push(0u32);
        for j in 0..n_dst {
            seg.clear();
            for k in 0..fanout {
                let s = j * fanout + k;
                if l0.mask[s] == 1.0 {
                    let slot = l0.nbr[s] as u32;
                    seg.push((mb.src_nodes[slot as usize], slot));
                }
            }
            // Pinned canonical order: ascending global id, stable on ties.
            seg.sort_by_key(|&(id, _)| id);
            for &(id, slot) in &seg {
                nbr_ids.push(id);
                nbr_slots.push(slot);
            }
            offsets.push(nbr_ids.len() as u32);
        }
        Ok(AggregatePlan {
            n_dst,
            fanout,
            dst_nodes,
            offsets,
            nbr_ids,
            nbr_slots,
        })
    }

    pub fn n_dst(&self) -> usize {
        self.n_dst
    }

    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// Global ids of the destinations — the push-down *self stream* (each
    /// destination still needs its own feature row on the GPU).
    pub fn dst_nodes(&self) -> &[u32] {
        &self.dst_nodes
    }

    /// Total masked neighbor slots across all destinations — the raw rows
    /// the aggregate stream replaces.
    pub fn neighbor_rows(&self) -> usize {
        self.nbr_ids.len()
    }

    /// Global neighbor ids for destination `j`, ascending.
    pub fn neighbor_ids(&self, j: usize) -> &[u32] {
        let lo = self.offsets[j] as usize;
        let hi = self.offsets[j + 1] as usize;
        &self.nbr_ids[lo..hi]
    }

    /// Per-destination masked neighbor counts (shipped alongside the
    /// aggregate rows so the consumer can finish a mean).
    pub fn counts(&self) -> Vec<u32> {
        (0..self.n_dst)
            .map(|j| self.offsets[j + 1] - self.offsets[j])
            .collect()
    }

    /// Reference reduction over a gathered feature matrix: `x0` holds
    /// `src_nodes.len()` rows of `f` floats in src order (exactly what the
    /// gather stage produces), and the output gets one summed row per
    /// destination — zeros for isolated destinations — plus the counts.
    ///
    /// The summation walks each destination's neighbors in the plan's
    /// pinned ascending-id order, left to right, so the result is the
    /// bitwise reference every pushed-down tier combination must hit.
    pub fn aggregate_gathered(
        &self,
        x0: &[f32],
        f: usize,
        agg_out: &mut [f32],
        counts_out: &mut [u32],
    ) -> Result<()> {
        if agg_out.len() != self.n_dst * f {
            return Err(Error::Pipeline(format!(
                "agg_out len {} != n_dst {} * f {}",
                agg_out.len(),
                self.n_dst,
                f
            )));
        }
        if counts_out.len() != self.n_dst {
            return Err(Error::Pipeline("counts_out len != n_dst".into()));
        }
        agg_out.fill(0.0);
        for j in 0..self.n_dst {
            let lo = self.offsets[j] as usize;
            let hi = self.offsets[j + 1] as usize;
            counts_out[j] = (hi - lo) as u32;
            let dst = &mut agg_out[j * f..(j + 1) * f];
            for &slot in &self.nbr_slots[lo..hi] {
                let row = slot as usize * f;
                let src = x0
                    .get(row..row + f)
                    .ok_or_else(|| Error::Pipeline("x0 too short for plan slot".into()))?;
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
        Ok(())
    }

    /// FLOPs of the reduction itself (`off-link` or on-GPU, the work is the
    /// same): one add per neighbor element.
    pub fn reduction_flops(&self, f: usize) -> u64 {
        self.nbr_ids.len() as u64 * f as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, RmatParams};
    use crate::sampler::batch::LayerBlock;
    use crate::sampler::neighbor::NeighborSampler;
    use crate::util::rng::Rng;

    fn hand_batch() -> MiniBatch {
        // 2 dsts, fanout 2; dst0 has neighbors [9, 3] (unsorted on purpose),
        // dst1 is isolated (mask 0).
        MiniBatch {
            src_nodes: vec![7, 5, 9, 3, 5, 5],
            layers: vec![LayerBlock {
                n_dst: 2,
                fanout: 2,
                nbr: vec![2, 3, 4, 5],
                mask: vec![1.0, 1.0, 0.0, 0.0],
            }],
            seeds: vec![7, 5],
            labels: vec![0, 1],
        }
    }

    #[test]
    fn neighbors_sorted_ascending_per_destination() {
        let plan = AggregatePlan::build(&hand_batch()).unwrap();
        assert_eq!(plan.n_dst(), 2);
        assert_eq!(plan.dst_nodes(), &[7, 5]);
        assert_eq!(plan.neighbor_ids(0), &[3, 9]); // sorted, was [9, 3]
        assert_eq!(plan.neighbor_ids(1), &[] as &[u32]);
        assert_eq!(plan.counts(), vec![2, 0]);
        assert_eq!(plan.neighbor_rows(), 2);
        assert_eq!(plan.reduction_flops(4), 8);
    }

    #[test]
    fn aggregate_matches_hand_sum_and_zeros_isolated() {
        let plan = AggregatePlan::build(&hand_batch()).unwrap();
        let f = 2;
        // row r = [r, 10r]
        let x0: Vec<f32> = hand_batch()
            .src_nodes
            .iter()
            .flat_map(|&r| vec![r as f32, 10.0 * r as f32])
            .collect();
        let mut agg = vec![f32::NAN; 2 * f];
        let mut counts = vec![0u32; 2];
        plan.aggregate_gathered(&x0, f, &mut agg, &mut counts).unwrap();
        // dst0: rows for 9 and 3 -> [12, 120]; dst1 isolated -> zeros.
        assert_eq!(agg, vec![12.0, 120.0, 0.0, 0.0]);
        assert_eq!(counts, vec![2, 0]);
    }

    #[test]
    fn pinned_order_is_slot_permutation_invariant() {
        // Two batches with the same (dst, neighbor-multiset) content but
        // different slot orderings must produce bitwise-identical sums —
        // that is what "pinned ascending-id order" buys.
        let mut a = hand_batch();
        a.layers[0].mask = vec![1.0, 1.0, 1.0, 1.0];
        let mut b = a.clone();
        // swap dst0's two neighbor slots (and their src rows stay in place;
        // nbr indirection is what moves).
        b.layers[0].nbr = vec![3, 2, 4, 5];
        let f = 3;
        let x0: Vec<f32> = a
            .src_nodes
            .iter()
            .flat_map(|&r| vec![0.1 + r as f32, 0.7 * r as f32, -(r as f32)])
            .collect();
        let (pa, pb) = (AggregatePlan::build(&a).unwrap(), AggregatePlan::build(&b).unwrap());
        let mut ra = vec![0.0; 2 * f];
        let mut rb = vec![0.0; 2 * f];
        let mut c = vec![0u32; 2];
        pa.aggregate_gathered(&x0, f, &mut ra, &mut c).unwrap();
        pb.aggregate_gathered(&x0, f, &mut rb, &mut c).unwrap();
        assert_eq!(ra.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   rb.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn sampled_batches_build_consistent_plans() {
        let g = rmat(400, 3000, RmatParams::default(), 11).unwrap();
        let s = NeighborSampler::new(&g, &[3, 2], 10);
        let mut rng = Rng::new(5);
        let seeds: Vec<u32> = (0..8).collect();
        let mb = s.sample(&seeds, &mut rng);
        let plan = AggregatePlan::build(&mb).unwrap();
        assert_eq!(plan.n_dst(), mb.layers[0].n_dst);
        assert_eq!(plan.dst_nodes(), &mb.src_nodes[..plan.n_dst()]);
        // masked slots == plan rows
        let masked: usize = mb.layers[0].mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(plan.neighbor_rows(), masked);
        for j in 0..plan.n_dst() {
            let ids = plan.neighbor_ids(j);
            assert!(ids.windows(2).all(|w| w[0] <= w[1]), "unsorted at dst {j}");
        }
    }

    #[test]
    fn empty_layer_batch_is_rejected_not_panicking() {
        let mb = MiniBatch {
            src_nodes: vec![],
            layers: vec![],
            seeds: vec![],
            labels: vec![],
        };
        assert!(AggregatePlan::build(&mb).is_err());
    }
}

//! Uniform fan-out neighbor sampler (GraphSAGE-style, with replacement).

use crate::graph::csr::Csr;
use crate::sampler::batch::{LayerBlock, MiniBatch};
use crate::util::rng::Rng;

/// Sampler over a CSR graph with per-layer fan-outs.
///
/// Layer convention follows the AOT models: `fanouts[0]` is the *input-side*
/// fan-out (between `n_0` and `n_1`); sampling proceeds from the roots
/// outward, so the construction loop walks fan-outs in reverse.
pub struct NeighborSampler<'g> {
    graph: &'g Csr,
    fanouts: Vec<usize>,
    classes: u32,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g Csr, fanouts: &[usize], classes: u32) -> Self {
        assert!(!fanouts.is_empty());
        // Labels are `node_hash % classes`: zero would be a modulo-by-zero
        // panic deep in the epoch loop.  `RunConfig` rejects it at parse
        // time; this guard covers direct library users with a clear
        // message instead of an arithmetic panic.
        assert!(classes > 0, "classes must be >= 1 (labels are node_hash % classes)");
        NeighborSampler {
            graph,
            fanouts: fanouts.to_vec(),
            classes,
        }
    }

    /// Deterministic synthetic label for a node (classification target).
    #[inline]
    pub fn label_of(node: u32, classes: u32) -> i32 {
        // Mix bits so labels are uncorrelated with node id magnitude.
        let mut x = node as u64 ^ 0x9E37_79B9_7F4A_7C15;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        x ^= x >> 33;
        (x % classes as u64) as i32
    }

    /// Sample one mini-batch rooted at `seeds`.
    pub fn sample(&self, seeds: &[u32], rng: &mut Rng) -> MiniBatch {
        let num_layers = self.fanouts.len();
        // nodes per level, roots outward: level[num_layers] = seeds.
        let mut level_nodes: Vec<Vec<u32>> = vec![Vec::new(); num_layers + 1];
        level_nodes[num_layers] = seeds.to_vec();

        let mut layers_rev: Vec<LayerBlock> = Vec::with_capacity(num_layers);
        for l in (0..num_layers).rev() {
            let fanout = self.fanouts[l];
            let dst: &Vec<u32> = &level_nodes[l + 1];
            let n_dst = dst.len();
            let mut src = Vec::with_capacity(n_dst * (1 + fanout));
            src.extend_from_slice(dst); // destinations are the src prefix
            let mut nbr = Vec::with_capacity(n_dst * fanout);
            let mut mask = Vec::with_capacity(n_dst * fanout);
            for (j, &v) in dst.iter().enumerate() {
                let neigh = self.graph.neighbors(v);
                for k in 0..fanout {
                    // every (j, k) slot owns src position n_dst + j*fanout + k
                    nbr.push((n_dst + j * fanout + k) as i32);
                    if neigh.is_empty() {
                        // isolated node: point the slot at the node itself,
                        // masked out so it contributes nothing.
                        src.push(v);
                        mask.push(0.0);
                    } else {
                        let pick = neigh[rng.gen_range_usize(neigh.len())];
                        src.push(pick);
                        mask.push(1.0);
                    }
                }
            }
            layers_rev.push(LayerBlock {
                n_dst,
                fanout,
                nbr,
                mask,
            });
            level_nodes[l] = src;
        }
        layers_rev.reverse(); // input-side first

        let labels = seeds
            .iter()
            .map(|&s| Self::label_of(s, self.classes))
            .collect();
        MiniBatch {
            src_nodes: std::mem::take(&mut level_nodes[0]),
            layers: layers_rev,
            seeds: seeds.to_vec(),
            labels,
        }
    }

    /// Iterate epoch batches: a shuffled permutation of all nodes, chopped
    /// into fixed-size root sets (remainder dropped, as DGL does with
    /// `drop_last=True` — required by the fixed AOT shapes).
    ///
    /// `batch > num_nodes` therefore yields *zero* batches — the whole
    /// epoch is "remainder".  The trainer rejects such configs up front
    /// ([`Trainer::new`](crate::coordinator::Trainer::new)) so per-epoch
    /// averages never divide by an empty batch list.
    pub fn epoch_seeds(&self, batch: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
        let mut order: Vec<u32> = (0..self.graph.num_nodes() as u32).collect();
        rng.shuffle(&mut order);
        order
            .chunks_exact(batch)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, RmatParams};
    use crate::util::proptest::{check, prop_assert, Gen};

    fn toy_graph() -> Csr {
        // 0..4 ring + isolated node 5
        Csr::from_edges(
            6,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 0), (2, 1)],
        )
        .unwrap()
    }

    #[test]
    fn block_shapes_match_model_convention() {
        let g = toy_graph();
        let s = NeighborSampler::new(&g, &[2, 3], 10);
        let mut rng = Rng::new(1);
        let mb = s.sample(&[0, 1], &mut rng);
        mb.validate().unwrap();
        // batch 2, fanouts [2,3]: n2=2, n1=2*4=8, n0=8*3=24
        assert_eq!(mb.layers.len(), 2);
        assert_eq!(mb.layers[1].n_dst, 2);
        assert_eq!(mb.layers[1].fanout, 3);
        assert_eq!(mb.layers[0].n_dst, 8);
        assert_eq!(mb.layers[0].fanout, 2);
        assert_eq!(mb.src_nodes.len(), 24);
        // destinations are the src prefix
        assert_eq!(&mb.src_nodes[..8], {
            // level1 nodes = seeds ++ sampled(3 per seed)
            let l1_len = 2 * (1 + 3);
            assert_eq!(l1_len, 8);
            &mb.src_nodes[..8]
        });
    }

    #[test]
    fn isolated_nodes_masked_out() {
        let g = toy_graph();
        let s = NeighborSampler::new(&g, &[2], 10);
        let mut rng = Rng::new(2);
        let mb = s.sample(&[5], &mut rng);
        mb.validate().unwrap();
        assert!(mb.layers[0].mask.iter().all(|&m| m == 0.0));
        // padding points at the node itself
        assert!(mb.src_nodes[1..].iter().all(|&n| n == 5));
    }

    #[test]
    fn sampled_neighbors_are_real_edges() {
        let g = rmat(200, 2000, RmatParams::default(), 4).unwrap();
        let s = NeighborSampler::new(&g, &[4], 10);
        let mut rng = Rng::new(3);
        let seeds: Vec<u32> = (0..16).collect();
        let mb = s.sample(&seeds, &mut rng);
        let block = &mb.layers[0];
        for (j, &seed) in seeds.iter().enumerate() {
            for k in 0..block.fanout {
                let slot = j * block.fanout + k;
                if block.mask[slot] == 1.0 {
                    let src_pos = block.nbr[slot] as usize;
                    let picked = mb.src_nodes[src_pos];
                    assert!(
                        g.neighbors(seed).contains(&picked),
                        "{picked} not a neighbor of {seed}"
                    );
                }
            }
        }
    }

    #[test]
    fn labels_deterministic_and_in_range() {
        let a = NeighborSampler::label_of(12345, 47);
        let b = NeighborSampler::label_of(12345, 47);
        assert_eq!(a, b);
        for n in 0..1000u32 {
            let l = NeighborSampler::label_of(n, 47);
            assert!((0..47).contains(&l));
        }
    }

    #[test]
    #[should_panic(expected = "classes must be >= 1")]
    fn zero_classes_rejected_with_a_clear_message() {
        let g = toy_graph();
        let _ = NeighborSampler::new(&g, &[2], 0);
    }

    #[test]
    fn oversized_batch_yields_zero_batches_by_contract() {
        // Documented drop_last semantics; the trainer layer rejects such
        // configs before they reach this (see coordinator::trainer tests).
        let g = toy_graph();
        let s = NeighborSampler::new(&g, &[2], 10);
        let mut rng = Rng::new(9);
        assert!(s.epoch_seeds(7, &mut rng).is_empty());
    }

    #[test]
    fn epoch_seeds_partition_nodes() {
        let g = toy_graph();
        let s = NeighborSampler::new(&g, &[2], 10);
        let mut rng = Rng::new(4);
        let batches = s.epoch_seeds(2, &mut rng);
        assert_eq!(batches.len(), 3);
        let mut all: Vec<u32> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn sampled_batches_always_validate_property() {
        let g = rmat(300, 1500, RmatParams::default(), 9).unwrap();
        check(25, |gen: &mut Gen| {
            let batch = gen.usize_in(1, 16);
            let f1 = gen.usize_in(1, 5);
            let f2 = gen.usize_in(1, 5);
            let seeds: Vec<u32> = gen.vec_u32(batch, 0, 299);
            let s = NeighborSampler::new(&g, &[f1, f2], 7);
            let mut rng = Rng::new(gen.u64_in(0, u32::MAX as u64));
            let mb = s.sample(&seeds, &mut rng);
            mb.validate().map_err(|e| e)?;
            prop_assert(
                mb.gather_rows() == batch * (1 + f2) * (1 + f1),
                format!("rows {}", mb.gather_rows()),
            )
        });
    }
}

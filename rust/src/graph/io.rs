//! Binary on-disk graph format (magic + version + little-endian arrays).
//!
//! Lets expensive generator runs be cached across benchmark invocations
//! (`ptdirect gen-data` writes, everything else mmap-free reads).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::csr::Csr;

const MAGIC: &[u8; 8] = b"PTDCSR01";

/// Write a CSR graph.
pub fn save(csr: &Csr, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(csr.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(csr.num_edges() as u64).to_le_bytes())?;
    for &p in &csr.indptr {
        w.write_all(&p.to_le_bytes())?;
    }
    for &i in &csr.indices {
        w.write_all(&i.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a CSR graph, validating invariants.
pub fn load(path: &Path) -> Result<Csr> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Graph(format!(
            "bad magic in {}: expected PTDCSR01",
            path.display()
        )));
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut indptr = vec![0u64; n + 1];
    for p in indptr.iter_mut() {
        r.read_exact(&mut buf8)?;
        *p = u64::from_le_bytes(buf8);
    }
    let mut buf4 = [0u8; 4];
    let mut indices = vec![0u32; m];
    for i in indices.iter_mut() {
        r.read_exact(&mut buf4)?;
        *i = u32::from_le_bytes(buf4);
    }
    let csr = Csr { indptr, indices };
    csr.validate()?;
    Ok(csr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, RmatParams};

    #[test]
    fn roundtrip() {
        let g = rmat(300, 2400, RmatParams::default(), 11).unwrap();
        let dir = std::env::temp_dir().join("ptdirect_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.csr");
        save(&g, &path).unwrap();
        let h = load(&path).unwrap();
        assert_eq!(g.indptr, h.indptr);
        assert_eq!(g.indices, h.indices);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ptdirect_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.csr");
        std::fs::write(&path, b"not a graph").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}

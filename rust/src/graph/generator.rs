//! Synthetic graph generators.
//!
//! The paper's large graphs (twitter7, sk-2005, ogbn-papers100M, wikipedia)
//! are web/social crawls with heavy-tailed degree distributions.  What the
//! gather-traffic experiments depend on is the *degree distribution* and
//! *edge locality*, both of which R-MAT (Chakrabarti et al. 2004) captures
//! with four quadrant probabilities; (0.57, 0.19, 0.19, 0.05) is the
//! standard "social network" parameterization the Graph500 uses.

use crate::error::Result;
use crate::graph::csr::Csr;
use crate::util::rng::Rng;

/// R-MAT quadrant probabilities (must sum to 1).
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    /// Noise added per recursion level to avoid exact self-similarity.
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams {
            a: 0.57,
            b: 0.19,
            c: 0.19,
            d: 0.05,
            noise: 0.1,
        }
    }
}

/// Generate an R-MAT graph with `n_nodes` (rounded up to a power of two
/// internally, then mapped down) and `n_edges` directed edges.
pub fn rmat(n_nodes: usize, n_edges: usize, params: RmatParams, seed: u64) -> Result<Csr> {
    assert!(n_nodes > 0);
    let levels = (usize::BITS - (n_nodes - 1).leading_zeros()).max(1);
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n_edges);
    while edges.len() < n_edges {
        let (mut src, mut dst) = (0usize, 0usize);
        // Per-edge jittered quadrant probabilities.
        let jitter = 1.0 + params.noise * (rng.gen_f64() - 0.5);
        let a = params.a * jitter;
        let b = params.b;
        let c = params.c;
        let norm = a + b + c + params.d * (2.0 - jitter);
        for _ in 0..levels {
            let r = rng.gen_f64() * norm;
            let (sbit, dbit) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            src = (src << 1) | sbit;
            dst = (dst << 1) | dbit;
        }
        if src < n_nodes && dst < n_nodes && src != dst {
            edges.push((src as u32, dst as u32));
        }
    }
    Csr::from_edges(n_nodes, &edges)
}

/// Erdős–Rényi-ish uniform random graph (baseline for locality ablations).
pub fn uniform(n_nodes: usize, n_edges: usize, seed: u64) -> Result<Csr> {
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> = (0..n_edges)
        .map(|_| {
            (
                rng.gen_range(n_nodes as u64) as u32,
                rng.gen_range(n_nodes as u64) as u32,
            )
        })
        .collect();
    Csr::from_edges(n_nodes, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_produces_requested_size() {
        let g = rmat(1000, 8000, RmatParams::default(), 7).unwrap();
        assert_eq!(g.num_nodes(), 1000);
        assert_eq!(g.num_edges(), 8000);
        g.validate().unwrap();
    }

    #[test]
    fn rmat_is_deterministic() {
        let a = rmat(500, 2000, RmatParams::default(), 9).unwrap();
        let b = rmat(500, 2000, RmatParams::default(), 9).unwrap();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.indptr, b.indptr);
    }

    #[test]
    fn rmat_is_heavy_tailed_vs_uniform() {
        // The social-network parameterization concentrates edges: the top 1%
        // of nodes should own far more than 1% of edges, unlike uniform.
        let n = 4096;
        let m = 65_536;
        let r = rmat(n, m, RmatParams::default(), 3).unwrap();
        let u = uniform(n, m, 3).unwrap();
        let top_share = |g: &Csr| {
            let mut degs: Vec<usize> = (0..n as u32).map(|v| g.degree(v)).collect();
            degs.sort_unstable_by(|a, b| b.cmp(a));
            let top: usize = degs[..n / 100].iter().sum();
            top as f64 / g.num_edges() as f64
        };
        let rs = top_share(&r);
        let us = top_share(&u);
        assert!(rs > 2.0 * us, "rmat top-1% share {rs} vs uniform {us}");
        assert!(r.max_degree() > 4 * u.max_degree());
    }

    #[test]
    fn no_self_loops_in_rmat() {
        let g = rmat(256, 4096, RmatParams::default(), 5).unwrap();
        for v in 0..g.num_nodes() as u32 {
            assert!(!g.neighbors(v).contains(&v));
        }
    }
}

//! Graph substrate: CSR storage, synthetic generators, dataset presets
//! (paper Table 4), and a binary on-disk format.

pub mod csr;
pub mod datasets;
pub mod generator;
pub mod io;

pub use csr::Csr;
pub use datasets::DatasetPreset;
pub use generator::{rmat, RmatParams};

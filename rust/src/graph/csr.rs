//! Compressed-sparse-row graph storage.
//!
//! Node ids are `u32` (the paper's largest graph, ogbn-papers100M, has
//! 111 M nodes — fits comfortably), edge offsets are `u64` (1.9 B edges in
//! sk-2005 would overflow u32).

use crate::error::{Error, Result};

/// Immutable CSR adjacency.
#[derive(Clone, Debug)]
pub struct Csr {
    /// `indptr[v]..indptr[v+1]` spans v's neighbor list in `indices`.
    pub indptr: Vec<u64>,
    pub indices: Vec<u32>,
}

impl Csr {
    /// Build from an edge list (src, dst). Parallel edges are kept
    /// (real-world crawls have them; sampling treats them as weight).
    pub fn from_edges(n_nodes: usize, edges: &[(u32, u32)]) -> Result<Csr> {
        let mut degree = vec![0u64; n_nodes];
        for &(s, d) in edges {
            if s as usize >= n_nodes || d as usize >= n_nodes {
                return Err(Error::Graph(format!(
                    "edge ({s},{d}) out of range for {n_nodes} nodes"
                )));
            }
            degree[s as usize] += 1;
        }
        let mut indptr = vec![0u64; n_nodes + 1];
        for v in 0..n_nodes {
            indptr[v + 1] = indptr[v] + degree[v];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; edges.len()];
        for &(s, d) in edges {
            let c = &mut cursor[s as usize];
            indices[*c as usize] = d;
            *c += 1;
        }
        Ok(Csr { indptr, indices })
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let lo = self.indptr[v as usize] as usize;
        let hi = self.indptr[v as usize + 1] as usize;
        &self.indices[lo..hi]
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_nodes() as f64
        }
    }

    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes() as u32)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Structural invariants; used by tests and after deserialization.
    pub fn validate(&self) -> Result<()> {
        if self.indptr.is_empty() {
            return Err(Error::Graph("empty indptr".into()));
        }
        if self.indptr[0] != 0 {
            return Err(Error::Graph("indptr[0] != 0".into()));
        }
        if !self.indptr.windows(2).all(|w| w[0] <= w[1]) {
            return Err(Error::Graph("indptr not monotone".into()));
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err(Error::Graph("indptr tail != |indices|".into()));
        }
        let n = self.num_nodes() as u32;
        if let Some(&bad) = self.indices.iter().find(|&&d| d >= n) {
            return Err(Error::Graph(format!("neighbor {bad} >= {n}")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert, Gen};

    fn diamond() -> Csr {
        // 0 -> 1,2 ; 1 -> 3 ; 2 -> 3 ; 3 -> (none)
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]).unwrap()
    }

    #[test]
    fn basic_topology() {
        let g = diamond();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[3]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 1.0).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn preserves_parallel_edges() {
        let g = Csr::from_edges(2, &[(0, 1), (0, 1)]).unwrap();
        assert_eq!(g.neighbors(0), &[1, 1]);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Csr::from_edges(2, &[(0, 5)]).is_err());
        assert!(Csr::from_edges(2, &[(5, 0)]).is_err());
    }

    #[test]
    fn from_edges_is_valid_property() {
        check(40, |g: &mut Gen| {
            let n = g.usize_in(1, 60);
            let m = g.usize_in(0, 200);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    (
                        g.usize_in(0, n - 1) as u32,
                        g.usize_in(0, n - 1) as u32,
                    )
                })
                .collect();
            let csr = Csr::from_edges(n, &edges).unwrap();
            csr.validate().map_err(|e| e.to_string())?;
            prop_assert(csr.num_edges() == m, "edge count preserved")?;
            // every input edge appears exactly as often as given
            let mut want = std::collections::HashMap::new();
            for &e in &edges {
                *want.entry(e).or_insert(0i64) += 1;
            }
            for v in 0..n as u32 {
                for &d in csr.neighbors(v) {
                    *want.entry((v, d)).or_insert(0) -= 1;
                }
            }
            prop_assert(want.values().all(|&c| c == 0), "multiset equality")
        });
    }
}

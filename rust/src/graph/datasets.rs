//! Dataset presets — paper Table 4, with configurable down-scaling.
//!
//! The paper's feature tables reach 59 GB; the benchmarks here scale node
//! counts by a divisor while preserving (a) average degree, (b) feature
//! width, and (c) the degree-distribution family (R-MAT social for the
//! crawls), which are the quantities the gather traffic depends on
//! (DESIGN.md §2).  Reported numbers are per-epoch shapes, not absolute
//! sizes, exactly as the Fig. 6–9 reproductions require.

use crate::error::Result;
use crate::graph::csr::Csr;
use crate::graph::generator::{rmat, RmatParams};

/// One row of paper Table 4.
#[derive(Clone, Copy, Debug)]
pub struct DatasetPreset {
    /// Paper abbreviation ("reddit", "product", "twit", "sk", "paper", "wiki").
    pub abbv: &'static str,
    pub full_name: &'static str,
    /// Feature width (#Feat. column).
    pub feat_dim: u32,
    /// Full-scale node count.
    pub nodes: u64,
    /// Full-scale edge count.
    pub edges: u64,
    /// Classifier label count (for the synthetic labels).
    pub classes: u32,
    /// R-MAT skew; crawls are more skewed than the OGB product graph.
    pub rmat_a: f64,
}

/// Paper Table 4 (reddit node/edge counts from Hamilton et al. 2017;
/// the paper's table lists its 11.6 M edges).
pub const DATASETS: [DatasetPreset; 6] = [
    DatasetPreset {
        abbv: "reddit",
        full_name: "reddit",
        feat_dim: 602,
        nodes: 233_000,
        edges: 11_600_000,
        classes: 41,
        rmat_a: 0.55,
    },
    DatasetPreset {
        abbv: "product",
        full_name: "ogbn-products",
        feat_dim: 100,
        nodes: 2_400_000,
        edges: 61_900_000,
        classes: 47,
        rmat_a: 0.50,
    },
    DatasetPreset {
        abbv: "twit",
        full_name: "twitter7",
        feat_dim: 343,
        nodes: 41_700_000,
        edges: 1_500_000_000,
        classes: 64,
        rmat_a: 0.57,
    },
    DatasetPreset {
        abbv: "sk",
        full_name: "sk-2005",
        feat_dim: 293,
        nodes: 50_600_000,
        edges: 1_900_000_000,
        classes: 64,
        rmat_a: 0.60,
    },
    DatasetPreset {
        abbv: "paper",
        full_name: "ogbn-papers100M",
        feat_dim: 128,
        nodes: 111_100_000,
        edges: 1_600_000_000,
        classes: 172,
        rmat_a: 0.55,
    },
    DatasetPreset {
        abbv: "wiki",
        full_name: "wikipedia_link_en",
        feat_dim: 800,
        nodes: 13_600_000,
        edges: 437_200_000,
        classes: 64,
        rmat_a: 0.57,
    },
];

impl DatasetPreset {
    pub fn by_abbv(abbv: &str) -> Option<DatasetPreset> {
        DATASETS.iter().find(|d| d.abbv == abbv).copied()
    }

    /// Full-scale feature table bytes (f32).
    pub fn feature_bytes(&self) -> u64 {
        self.nodes * self.feat_dim as u64 * 4
    }

    /// Scaled node/edge counts for a divisor.
    pub fn scaled(&self, scale: u32) -> (usize, usize) {
        let n = (self.nodes / scale as u64).max(1024) as usize;
        // preserve average degree
        let avg_deg = self.edges as f64 / self.nodes as f64;
        let m = (n as f64 * avg_deg) as usize;
        (n, m)
    }

    /// Smallest scale whose f32 feature table fits `budget` bytes, starting
    /// from `requested`.
    pub fn scale_for_budget(&self, requested: u32, budget: u64) -> u32 {
        let mut scale = requested.max(1);
        loop {
            let (n, _) = self.scaled(scale);
            let bytes = n as u64 * self.feat_dim as u64 * 4;
            if bytes <= budget || scale >= 1 << 20 {
                return scale;
            }
            scale *= 2;
        }
    }

    /// Generate the scaled synthetic graph.
    pub fn build_graph(&self, scale: u32, seed: u64) -> Result<Csr> {
        let (n, m) = self.scaled(scale);
        let params = RmatParams {
            a: self.rmat_a,
            b: 0.19,
            c: 0.19,
            d: (1.0 - self.rmat_a - 0.38).max(0.01),
            noise: 0.1,
        };
        rmat(n, m, params, seed ^ fxhash(self.abbv))
    }
}

/// Tiny string hash for stable per-dataset seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_rows_present() {
        assert_eq!(DATASETS.len(), 6);
        let reddit = DatasetPreset::by_abbv("reddit").unwrap();
        assert_eq!(reddit.feat_dim, 602);
        let paper = DatasetPreset::by_abbv("paper").unwrap();
        assert_eq!(paper.nodes, 111_100_000);
        assert!(DatasetPreset::by_abbv("imagenet").is_none());
    }

    #[test]
    fn table4_sizes_match_paper_magnitudes() {
        // Paper Table 4 "Size" column: twit 57 GB, sk 59 GB, wiki 44 GB.
        let gb = |d: &str| DatasetPreset::by_abbv(d).unwrap().feature_bytes() as f64 / 1e9;
        assert!((gb("twit") - 57.0).abs() < 3.0, "{}", gb("twit"));
        assert!((gb("sk") - 59.0).abs() < 3.0, "{}", gb("sk"));
        assert!((gb("wiki") - 43.5).abs() < 3.0, "{}", gb("wiki"));
        assert!((gb("product") - 0.96).abs() < 0.1, "{}", gb("product"));
    }

    #[test]
    fn scaling_preserves_avg_degree() {
        let d = DatasetPreset::by_abbv("twit").unwrap();
        let (n, m) = d.scaled(256);
        let full_deg = d.edges as f64 / d.nodes as f64;
        let scaled_deg = m as f64 / n as f64;
        assert!((full_deg - scaled_deg).abs() / full_deg < 0.01);
    }

    #[test]
    fn budget_raises_scale() {
        let d = DatasetPreset::by_abbv("wiki").unwrap();
        let s = d.scale_for_budget(1, 64 << 20);
        assert!(s > 1);
        let (n, _) = d.scaled(s);
        assert!(n as u64 * d.feat_dim as u64 * 4 <= 64 << 20);
    }

    #[test]
    fn build_scaled_graph() {
        let d = DatasetPreset::by_abbv("product").unwrap();
        let g = d.build_graph(512, 1).unwrap();
        g.validate().unwrap();
        let want_deg = d.edges as f64 / d.nodes as f64;
        assert!((g.avg_degree() - want_deg).abs() / want_deg < 0.05);
    }
}

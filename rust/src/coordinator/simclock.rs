//! Discrete-event simulation substrate for the overlap engine
//! (DESIGN.md §9): the shared hardware resources of the simulated testbed
//! as stateful busy-until lanes.
//!
//! The serial cost accounting of DESIGN.md §5 prices every stage in
//! isolation and adds the results.  The overlap engine keeps the exact
//! same per-stage durations but *schedules* them onto the resources below,
//! so stages of different steps overlap when (and only when) they use
//! different hardware — which is how the paper's pipelined epoch hides the
//! feature-copy time under training compute.
//!
//! The resource vocabulary itself — [`ResourceKind`], its canonical
//! order, and the per-kind [`ResourceBusy`] accounting — lives in the
//! link-topology registry (`interconnect::topology`, DESIGN.md §15) and
//! is re-exported here: the overlap engine builds its lane set from
//! [`Topology::lanes`](crate::interconnect::Topology::lanes) rather than
//! naming resources, so a new link enters the schedule by joining the
//! topology, not by editing the scheduler.
//!
//! A [`SimResource`] is one piece of hardware with one or more service
//! lanes (the CPU sampler has `sampler_workers` lanes; the links and the
//! GPU have one).  Lanes are busy-until scalars: the scheduler asks when a
//! lane frees ([`SimResource::peek`]), picks the start time, and commits
//! the occupancy ([`SimResource::occupy`]).  Service order per lane is
//! *fixed in step order* — this is what makes the schedule deterministic
//! and the epoch makespan provably monotone non-increasing in the prefetch
//! window (pinned by `tests/overlap_properties.rs`): relaxing a gate can
//! only move every downstream start earlier, never reorder the queue.
//!
//! ```
//! use ptdirect::coordinator::simclock::{ResourceKind, SimResource};
//!
//! let mut link = SimResource::new(ResourceKind::HostLink, 1);
//! assert_eq!(link.peek(0), (0.0, None));
//! link.occupy(0, 0.5, 1.0, 7); // event 7 holds the link over [0.5, 1.5)
//! assert_eq!(link.peek(0), (1.5, Some(7)));
//! assert_eq!(link.busy_s(), 1.0);
//! ```

pub use crate::interconnect::topology::{ResourceBusy, ResourceKind};

/// One piece of simulated hardware: `lanes` busy-until scalars plus the
/// id of each lane's most recent user (for critical-path bookkeeping) and
/// cumulative occupied seconds.
#[derive(Clone, Debug)]
pub struct SimResource {
    kind: ResourceKind,
    free_s: Vec<f64>,
    last_user: Vec<Option<usize>>,
    busy_s: f64,
}

impl SimResource {
    pub fn new(kind: ResourceKind, lanes: usize) -> SimResource {
        let lanes = lanes.max(1);
        SimResource {
            kind,
            free_s: vec![0.0; lanes],
            last_user: vec![None; lanes],
            busy_s: 0.0,
        }
    }

    pub fn kind(&self) -> ResourceKind {
        self.kind
    }

    pub fn lanes(&self) -> usize {
        self.free_s.len()
    }

    /// When `lane` next frees, and which event holds it until then.
    pub fn peek(&self, lane: usize) -> (f64, Option<usize>) {
        (self.free_s[lane], self.last_user[lane])
    }

    /// Commit event `user` to `lane` over `[start_s, start_s + dur_s)`.
    /// Service order is the caller's (fixed, step order); starting before
    /// the lane frees is a scheduler bug.
    pub fn occupy(&mut self, lane: usize, start_s: f64, dur_s: f64, user: usize) {
        debug_assert!(
            start_s >= self.free_s[lane],
            "lane {lane} of {:?} occupied at {start_s} while busy until {}",
            self.kind,
            self.free_s[lane]
        );
        self.free_s[lane] = start_s + dur_s;
        self.last_user[lane] = Some(user);
        self.busy_s += dur_s;
    }

    /// Lane with the earliest free time (ties resolve to the lowest
    /// index, keeping lane choice deterministic).  The serving engine's
    /// dispatcher uses this to start the next coalesced batch on whichever
    /// sampler worker frees first.
    pub fn earliest_lane(&self) -> usize {
        let mut best = 0usize;
        for (lane, &free) in self.free_s.iter().enumerate().skip(1) {
            if free < self.free_s[best] {
                best = lane;
            }
        }
        best
    }

    /// Total seconds this resource has been occupied.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_track_busy_until_and_last_user() {
        let mut r = SimResource::new(ResourceKind::Sampler, 2);
        assert_eq!(r.lanes(), 2);
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(1, 0.5, 1.0, 2);
        assert_eq!(r.peek(0), (2.0, Some(1)));
        assert_eq!(r.peek(1), (1.5, Some(2)));
        assert!((r.busy_s() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_lanes_clamps_to_one() {
        let r = SimResource::new(ResourceKind::Gpu, 0);
        assert_eq!(r.lanes(), 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "occupied")]
    fn occupying_a_busy_lane_is_a_bug() {
        let mut r = SimResource::new(ResourceKind::HostLink, 1);
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(0, 1.0, 1.0, 2); // starts inside [0, 2)
    }

    #[test]
    fn earliest_lane_picks_first_free() {
        let mut r = SimResource::new(ResourceKind::Sampler, 3);
        assert_eq!(r.earliest_lane(), 0); // all free: lowest index
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(1, 0.0, 0.5, 2);
        r.occupy(2, 0.0, 0.5, 3);
        assert_eq!(r.earliest_lane(), 1); // tie at 0.5: lowest index
    }

    #[test]
    fn reexported_kinds_are_the_topology_kinds() {
        // The scheduler's resource vocabulary IS the topology's — one
        // canonical order, re-exported (DESIGN.md §15).
        use crate::interconnect::topology;
        assert_eq!(ResourceKind::all(), topology::ResourceKind::all());
        let mut b = ResourceBusy::default();
        b.add(ResourceKind::HostLink, 1.5);
        assert_eq!(b.get(topology::ResourceKind::HostLink), 1.5);
    }
}

//! Discrete-event simulation substrate for the overlap engine
//! (DESIGN.md §9): the shared hardware resources of the simulated testbed
//! as stateful busy-until lanes.
//!
//! The serial cost accounting of DESIGN.md §5 prices every stage in
//! isolation and adds the results.  The overlap engine keeps the exact
//! same per-stage durations but *schedules* them onto the resources below,
//! so stages of different steps overlap when (and only when) they use
//! different hardware — which is how the paper's pipelined epoch hides the
//! feature-copy time under training compute.
//!
//! A [`SimResource`] is one piece of hardware with one or more service
//! lanes (the CPU sampler has `sampler_workers` lanes; the links and the
//! GPU have one).  Lanes are busy-until scalars: the scheduler asks when a
//! lane frees ([`SimResource::peek`]), picks the start time, and commits
//! the occupancy ([`SimResource::occupy`]).  Service order per lane is
//! *fixed in step order* — this is what makes the schedule deterministic
//! and the epoch makespan provably monotone non-increasing in the prefetch
//! window (pinned by `tests/overlap_properties.rs`): relaxing a gate can
//! only move every downstream start earlier, never reorder the queue.
//!
//! ```
//! use ptdirect::coordinator::simclock::{ResourceKind, SimResource};
//!
//! let mut link = SimResource::new(ResourceKind::HostLink, 1);
//! assert_eq!(link.peek(0), (0.0, None));
//! link.occupy(0, 0.5, 1.0, 7); // event 7 holds the link over [0.5, 1.5)
//! assert_eq!(link.peek(0), (1.5, Some(7)));
//! assert_eq!(link.busy_s(), 1.0);
//! ```

/// The shared hardware resources a training step's stages contend for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// CPU sampler lanes (neighbor sampling, plus the CPU half of the
    /// baseline's gather/staging work — they fight for the same cores).
    Sampler,
    /// The host link: PCIe zero-copy reads, DMA copies, UVM migrations.
    HostLink,
    /// The NVLink peer-ingress budget of the sharded store.
    PeerLink,
    /// The NVMe command queue / storage link of the three-tier store.
    StorageLink,
    /// The GPU compute engine (training steps; kernel-launch-only
    /// transfers are attributed here without occupying it).
    #[default]
    Gpu,
}

impl ResourceKind {
    /// All kinds, in reporting order.
    pub fn all() -> [ResourceKind; 5] {
        [
            ResourceKind::Sampler,
            ResourceKind::HostLink,
            ResourceKind::PeerLink,
            ResourceKind::StorageLink,
            ResourceKind::Gpu,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ResourceKind::Sampler => "sampler",
            ResourceKind::HostLink => "host-link",
            ResourceKind::PeerLink => "peer-link",
            ResourceKind::StorageLink => "storage-link",
            ResourceKind::Gpu => "gpu",
        }
    }
}

/// Seconds accounted per resource (busy time, or critical-path share).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceBusy {
    pub sampler_s: f64,
    pub host_link_s: f64,
    pub peer_link_s: f64,
    pub storage_link_s: f64,
    pub gpu_s: f64,
}

impl ResourceBusy {
    pub fn add(&mut self, kind: ResourceKind, seconds: f64) {
        match kind {
            ResourceKind::Sampler => self.sampler_s += seconds,
            ResourceKind::HostLink => self.host_link_s += seconds,
            ResourceKind::PeerLink => self.peer_link_s += seconds,
            ResourceKind::StorageLink => self.storage_link_s += seconds,
            ResourceKind::Gpu => self.gpu_s += seconds,
        }
    }

    pub fn get(&self, kind: ResourceKind) -> f64 {
        match kind {
            ResourceKind::Sampler => self.sampler_s,
            ResourceKind::HostLink => self.host_link_s,
            ResourceKind::PeerLink => self.peer_link_s,
            ResourceKind::StorageLink => self.storage_link_s,
            ResourceKind::Gpu => self.gpu_s,
        }
    }

    pub fn total(&self) -> f64 {
        self.sampler_s + self.host_link_s + self.peer_link_s + self.storage_link_s + self.gpu_s
    }

    /// Resource with the largest share (ties resolved in
    /// [`ResourceKind::all`] order, so the result is deterministic).
    pub fn max_kind(&self) -> ResourceKind {
        let mut best = ResourceKind::Sampler;
        let mut best_s = self.get(best);
        for kind in ResourceKind::all() {
            let s = self.get(kind);
            if s > best_s {
                best = kind;
                best_s = s;
            }
        }
        best
    }
}

/// One piece of simulated hardware: `lanes` busy-until scalars plus the
/// id of each lane's most recent user (for critical-path bookkeeping) and
/// cumulative occupied seconds.
#[derive(Clone, Debug)]
pub struct SimResource {
    kind: ResourceKind,
    free_s: Vec<f64>,
    last_user: Vec<Option<usize>>,
    busy_s: f64,
}

impl SimResource {
    pub fn new(kind: ResourceKind, lanes: usize) -> SimResource {
        let lanes = lanes.max(1);
        SimResource {
            kind,
            free_s: vec![0.0; lanes],
            last_user: vec![None; lanes],
            busy_s: 0.0,
        }
    }

    pub fn kind(&self) -> ResourceKind {
        self.kind
    }

    pub fn lanes(&self) -> usize {
        self.free_s.len()
    }

    /// When `lane` next frees, and which event holds it until then.
    pub fn peek(&self, lane: usize) -> (f64, Option<usize>) {
        (self.free_s[lane], self.last_user[lane])
    }

    /// Commit event `user` to `lane` over `[start_s, start_s + dur_s)`.
    /// Service order is the caller's (fixed, step order); starting before
    /// the lane frees is a scheduler bug.
    pub fn occupy(&mut self, lane: usize, start_s: f64, dur_s: f64, user: usize) {
        debug_assert!(
            start_s >= self.free_s[lane],
            "lane {lane} of {:?} occupied at {start_s} while busy until {}",
            self.kind,
            self.free_s[lane]
        );
        self.free_s[lane] = start_s + dur_s;
        self.last_user[lane] = Some(user);
        self.busy_s += dur_s;
    }

    /// Lane with the earliest free time (ties resolve to the lowest
    /// index, keeping lane choice deterministic).  The serving engine's
    /// dispatcher uses this to start the next coalesced batch on whichever
    /// sampler worker frees first.
    pub fn earliest_lane(&self) -> usize {
        let mut best = 0usize;
        for (lane, &free) in self.free_s.iter().enumerate().skip(1) {
            if free < self.free_s[best] {
                best = lane;
            }
        }
        best
    }

    /// Total seconds this resource has been occupied.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_track_busy_until_and_last_user() {
        let mut r = SimResource::new(ResourceKind::Sampler, 2);
        assert_eq!(r.lanes(), 2);
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(1, 0.5, 1.0, 2);
        assert_eq!(r.peek(0), (2.0, Some(1)));
        assert_eq!(r.peek(1), (1.5, Some(2)));
        assert!((r.busy_s() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_lanes_clamps_to_one() {
        let r = SimResource::new(ResourceKind::Gpu, 0);
        assert_eq!(r.lanes(), 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "occupied")]
    fn occupying_a_busy_lane_is_a_bug() {
        let mut r = SimResource::new(ResourceKind::HostLink, 1);
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(0, 1.0, 1.0, 2); // starts inside [0, 2)
    }

    #[test]
    fn earliest_lane_picks_first_free() {
        let mut r = SimResource::new(ResourceKind::Sampler, 3);
        assert_eq!(r.earliest_lane(), 0); // all free: lowest index
        r.occupy(0, 0.0, 2.0, 1);
        r.occupy(1, 0.0, 0.5, 2);
        r.occupy(2, 0.0, 0.5, 3);
        assert_eq!(r.earliest_lane(), 1); // tie at 0.5: lowest index
    }

    #[test]
    fn busy_accumulates_by_kind() {
        let mut b = ResourceBusy::default();
        b.add(ResourceKind::HostLink, 1.0);
        b.add(ResourceKind::HostLink, 0.5);
        b.add(ResourceKind::Gpu, 2.0);
        assert!((b.get(ResourceKind::HostLink) - 1.5).abs() < 1e-12);
        assert!((b.total() - 3.5).abs() < 1e-12);
        assert_eq!(b.max_kind(), ResourceKind::Gpu);
    }

    #[test]
    fn max_kind_tie_break_is_deterministic() {
        let mut b = ResourceBusy::default();
        b.add(ResourceKind::Gpu, 1.0);
        b.add(ResourceKind::Sampler, 1.0);
        // Equal shares: reporting order wins (Sampler precedes Gpu).
        assert_eq!(b.max_kind(), ResourceKind::Sampler);
        assert_eq!(ResourceBusy::default().max_kind(), ResourceKind::Sampler);
    }

    #[test]
    fn labels_cover_every_kind() {
        for kind in ResourceKind::all() {
            assert!(!kind.label().is_empty());
        }
        assert_eq!(ResourceKind::all().len(), 5);
    }
}

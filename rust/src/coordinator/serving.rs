//! Request-driven online inference serving (`--serve`): the ROADMAP's
//! production-shaped counterpart of the batch inference runner, built on
//! the discrete-event substrate of `simclock`.
//!
//! The batch runner (`coordinator/inference.rs`) measures a closed back-to-
//! back loop — useful for throughput, blind to *latency under load*, which
//! is what a deployment actually provisions for (the paper's §4.1 framing:
//! GPU out-of-memory training *and inference*).  This engine generates an
//! arrival stream of inference requests, pushes them through a bounded
//! admission queue, and schedules each dispatched batch's
//! sample → gather → transfer → execute DAG onto the shared
//! [`SimResource`]s, reporting tail latency (p50/p95/p99/p999), goodput,
//! queue depth, and rejection rate.
//!
//! **Arrival models.**  `--arrival-rps R` draws Poisson interarrivals
//! (`-ln(1-u)/R`) from the deterministic [`Rng`] — the open loop, where
//! load is independent of service capacity and queues actually build.
//! `--arrival-rps 0` (default) runs `--clients N` in a closed loop: each
//! client re-issues the moment its previous request completes, so exactly
//! `N` requests are ever in flight.  A single closed-loop client
//! degenerates to the batch inference runner's serial rhythm — its
//! simulated breakdown reproduces `InferenceRunner::run`'s bit-exactly
//! (pinned by `tests/serving_properties.rs`).
//!
//! **Admission.**  An arrival that finds `--admit-depth` requests already
//! queued is rejected and counted as goodput loss — the knob every SLO
//! study turns first (shed load early, keep tail latency bounded).
//!
//! **Coalescing.**  While a batch is in service, queued requests pile up;
//! the dispatcher folds up to `--coalesce-limit` of them into one
//! minibatch via [`CoalescedGatherPlan`], extending the gather dedup
//! *across* requests — hub rows two clients both need cross the link
//! once.  The pinned invariant: each member's scattered feature block is
//! bitwise identical to serving that request alone (rows are copied from
//! the same gathered table, never recomputed), so coalescing changes
//! *cost and latency only*, never results.  `--no-coalesce` dispatches
//! one request per batch.
//!
//! Requests draw their seed sets deterministically: request `r` roots at
//! nodes `(r*batch + k) % n` — the same window rule the batch runner uses
//! per batch index — and minibatches are sampled in request-id order from
//! the `fork(1)` sampler stream, so the sampled structure is identical
//! whether or not batches coalesce (only *grouping* differs).
//!
//! [`SimResource`]: crate::coordinator::simclock::SimResource
//! [`Rng`]: crate::util::rng::Rng
//! [`CoalescedGatherPlan`]: crate::sampler::CoalescedGatherPlan

use std::collections::VecDeque;
use std::path::Path;

use crate::config::{Backend, RunConfig};
use crate::coordinator::costmodel::{ComputeModel, DEFAULT_HIDDEN};
use crate::coordinator::schedule::link_window;
use crate::coordinator::simclock::{ResourceBusy, ResourceKind, SimResource};
use crate::coordinator::trainer::{Breakdown, PushdownReport};
use crate::error::{Error, Result};
use crate::featurestore::{FeatureStore, TierStats};
use crate::graph::{Csr, DatasetPreset};
use crate::interconnect::{Topology, TransferCost};
use crate::runtime::Manifest;
use crate::sampler::{AggregatePlan, CoalescedGatherPlan, MiniBatch, NeighborSampler};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One serving run's results.
#[derive(Clone, Debug, Default)]
pub struct ServingReport {
    /// Requests the arrival stream offered (`admitted + rejected`).
    pub offered: u64,
    pub admitted: u64,
    /// Arrivals dropped at the admission queue (goodput loss).
    pub rejected: u64,
    /// Requests served to completion (== admitted: the queue drains).
    pub completed: u64,
    /// Dispatched batches (`completed / batches` ≥ 1 is the mean
    /// coalescing factor).
    pub batches: u64,
    /// Simulated time from the first arrival to the last completion.
    pub makespan_s: f64,
    /// Per-request end-to-end latency (arrival → completion), seconds.
    pub latency: Summary,
    /// Queue depth sampled at every arrival and dispatch.
    pub queue_depth: Summary,
    pub max_queue_depth: usize,
    /// Simulated stage totals across all batches (the batch runner's
    /// breakdown, for the single-client degeneracy anchor).
    pub breakdown_sim: Breakdown,
    /// Feature rows requested across all batches, before dedup.
    pub requested_rows: u64,
    /// Rows actually fetched (after per-request and cross-request dedup).
    pub unique_rows: u64,
    /// Seconds each simulated resource was occupied.
    pub busy: ResourceBusy,
    /// Resource with the largest busy share — what bound the run.
    pub bound_by: ResourceKind,
    /// Hot-tier cache activity over this run (tiered / sharded / nvme
    /// modes; `None` otherwise).  With `--clients 2`+ the streams share
    /// one paged cache, so this is the *combined* residency picture —
    /// `tests/serving_properties.rs` pins that sharing never changes
    /// results and never hurts the hit rate under static placement.
    pub tier: Option<TierStats>,
    /// Aggregation push-down accounting (`--aggregate-pushdown`,
    /// DESIGN.md §14).  Partial-aggregate payloads are *per request* —
    /// each client needs its own per-destination sums, so coalescing
    /// merges nothing across members on the aggregate streams (unlike
    /// the raw path's cross-request dedup); the engine prices one
    /// pushed-down stream per member and sums them into the batch's
    /// transfer window.
    pub pushdown: PushdownReport,
}

impl ServingReport {
    /// Completed requests per second of simulated makespan.
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.completed as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Fraction of offered requests dropped at admission.
    pub fn rejection_rate(&self) -> f64 {
        if self.offered > 0 {
            self.rejected as f64 / self.offered as f64
        } else {
            0.0
        }
    }

    /// Mean requests per dispatched batch (1.0 with `--no-coalesce`).
    pub fn coalesce_factor(&self) -> f64 {
        if self.batches > 0 {
            self.completed as f64 / self.batches as f64
        } else {
            1.0
        }
    }

    /// Requested over fetched rows (cross-request dedup payoff).
    pub fn dedup_ratio(&self) -> f64 {
        if self.unique_rows > 0 {
            self.requested_rows as f64 / self.unique_rows as f64
        } else {
            1.0
        }
    }
}

/// A request sitting in the admission queue.
struct Pending {
    id: u64,
    arrival_s: f64,
    client: u32,
}

/// Pop the next batch off the admission queue in FIFO order: up to
/// `limit` requests when coalescing, exactly one otherwise.  The batch
/// size is clamped to the queue length, so an empty queue (or a coalesce
/// window that raced the queue empty) yields an empty batch instead of
/// panicking on `pop_front`.
fn take_batch(queue: &mut VecDeque<Pending>, coalesce: bool, limit: usize) -> Vec<Pending> {
    let k = if coalesce { queue.len().min(limit) } else { 1 };
    queue.drain(..k.min(queue.len())).collect()
}

/// Request-driven serving engine over the full data path (sampler +
/// feature store of the configured access mode) with simulated timing.
///
/// The store is stateful (hot-tier promotion, NVMe cache), so one engine
/// should serve one run; build a fresh engine per experiment point.
pub struct ServingEngine {
    cfg: RunConfig,
    preset: DatasetPreset,
    graph: Csr,
    store: FeatureStore,
    compute: ComputeModel,
    /// Feature rows one request's gather delivers (= layer_sizes[0]).
    gather_rows: usize,
}

impl ServingEngine {
    /// Build the serving stack.  Uses the `{arch}_{dataset}_infer`
    /// artifact's shapes when the manifest has them, else the run-config
    /// shapes — matching `InferenceRunner::new`'s model selection so the
    /// degeneracy anchor holds in both environments.
    pub fn new(cfg: RunConfig) -> Result<ServingEngine> {
        // Programmatic configs bypass the CLI's validation pass; reject
        // impossible shapes (e.g. empty `fanouts`) before the sampler
        // can panic on them.
        cfg.validate()?;
        let mut preset = DatasetPreset::by_abbv(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset `{}`", cfg.dataset)))?;
        crate::coordinator::trainer::apply_classes_override(&cfg, &mut preset);
        let scale = preset.scale_for_budget(cfg.scale, cfg.feature_budget);
        let graph = preset.build_graph(scale, cfg.seed)?;
        let store = crate::coordinator::trainer::build_store(&cfg, &graph, &preset)?;

        // Same shape-source rule as `InferenceRunner::new` (the backend
        // decides, not mere manifest presence) so the single-client
        // degeneracy anchor holds whether or not artifacts are built.
        let infer_name = format!("{}_infer", cfg.artifact_name());
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir));
        let use_spec = match cfg.backend {
            Backend::Pjrt => true,
            Backend::Native => false,
            Backend::Auto => manifest
                .as_ref()
                .map(|m| m.get(&infer_name).is_ok())
                .unwrap_or(false),
        };
        let (compute, gather_rows) = if use_spec {
            let manifest = manifest?;
            let spec = manifest.get(&infer_name)?;
            (ComputeModel::from_spec(spec), spec.layer_sizes[0])
        } else {
            (
                ComputeModel::from_shape(
                    &cfg.arch,
                    cfg.batch,
                    &cfg.fanouts,
                    preset.feat_dim as usize,
                    DEFAULT_HIDDEN,
                    preset.classes as usize,
                ),
                ComputeModel::layer_sizes_for(cfg.batch, &cfg.fanouts)[0],
            )
        };

        Ok(ServingEngine {
            cfg,
            preset,
            graph,
            store,
            compute,
            gather_rows,
        })
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Serve the configured request stream.
    pub fn run(&mut self) -> Result<ServingReport> {
        Ok(self.run_inner(false)?.0)
    }

    /// Serve, additionally returning every admitted request's gathered
    /// feature block (indexed by request id; rejected ids stay empty).
    /// This is the hook `tests/serving_properties.rs` uses to pin the
    /// coalescing invariant: block `r` must be bitwise identical whether
    /// or not request `r` shared a batch with others.
    pub fn run_with_blocks(&mut self) -> Result<(ServingReport, Vec<Vec<f32>>)> {
        self.run_inner(true)
    }

    fn run_inner(&mut self, capture: bool) -> Result<(ServingReport, Vec<Vec<f32>>)> {
        let total = self.cfg.serve_requests;
        let open_loop = self.cfg.arrival_rps > 0.0;
        let batch = self.cfg.batch;
        let n_nodes = self.graph.num_nodes();
        let dim = self.store.dim();
        let sampler = NeighborSampler::new(&self.graph, &self.cfg.fanouts, self.preset.classes);
        // fork(1) is the batch runner's sampler stream — requests sample
        // identically to its batches; fork(2) feeds the arrival draws.
        let mut base = Rng::new(self.cfg.seed);
        let mut srng = base.fork(1);
        let mut arng = base.fork(2);
        let sim_fwd = self.compute.train_step_s(&self.cfg.system) / 3.0;

        let lanes = self.cfg.sampler_workers.max(1);
        // One lane set per registered resource, canonical topology order
        // (kind-ordinal indexed — the epoch engine's layout, DESIGN.md §15).
        let mut resources: Vec<SimResource> = Topology::lanes(lanes)
            .links()
            .iter()
            .map(|l| SimResource::new(l.kind, l.lanes))
            .collect();
        let sampler = ResourceKind::Sampler.ordinal();
        let gpu = ResourceKind::Gpu.ordinal();
        let mut ev = 0usize; // occupancy tags (no critical-path walk here)

        // Arrival times are non-decreasing by construction: the open loop
        // is a cumulative sum, and closed-loop re-issues happen at batch
        // completions, which the FIFO GPU emits in order — so a deque
        // suffices (no heap, no float ordering).
        let mut arrivals: VecDeque<(f64, u32)> = VecDeque::new();
        let mut offered: u64 = 0;
        if open_loop {
            let mut t = 0.0;
            for _ in 0..total {
                let u = arng.gen_f64();
                t += -(1.0 - u).ln() / self.cfg.arrival_rps;
                arrivals.push_back((t, 0));
            }
            offered = total;
        } else {
            let clients = (self.cfg.clients as u64).min(total);
            for c in 0..clients {
                arrivals.push_back((0.0, c as u32));
            }
            offered = clients;
        }

        let tier_start = self.store.tier_stats();
        let mut report = ServingReport::default();
        report.pushdown.enabled = self.cfg.aggregate_pushdown;
        let mut blocks: Vec<Vec<f32>> = if capture {
            vec![Vec::new(); total as usize]
        } else {
            Vec::new()
        };
        let mut queue: VecDeque<Pending> = VecDeque::new();
        let mut next_id: u64 = 0;

        while !queue.is_empty() || !arrivals.is_empty() {
            if queue.is_empty() {
                // idle until the next arrival (an empty queue can't reject);
                // the loop condition guarantees arrivals is non-empty here,
                // and an unreachable break beats a panic in the serving loop.
                let (t_a, client) = match arrivals.pop_front() {
                    Some(a) => a,
                    None => break,
                };
                queue.push_back(Pending {
                    id: next_id,
                    arrival_s: t_a,
                    client,
                });
                next_id += 1;
                report.admitted += 1;
                report.queue_depth.add(queue.len() as f64);
                report.max_queue_depth = report.max_queue_depth.max(queue.len());
                continue;
            }

            // The next batch starts sampling when a sampler lane frees (or
            // immediately for the queue head's arrival, if later).
            let lane = resources[sampler].earliest_lane();
            let (lane_free, _) = resources[sampler].peek(lane);
            let t_start = lane_free.max(
                queue
                    .front()
                    .expect("dispatch path runs only with a non-empty queue (empty case continues above)")
                    .arrival_s,
            );

            // Everything arriving up to the dispatch instant faces the
            // admission check against the queue it actually finds.
            while let Some(&(t_a, client)) = arrivals.front() {
                if t_a > t_start {
                    break;
                }
                arrivals.pop_front();
                if queue.len() >= self.cfg.admit_depth {
                    report.rejected += 1;
                } else {
                    queue.push_back(Pending {
                        id: next_id,
                        arrival_s: t_a,
                        client,
                    });
                    report.admitted += 1;
                    report.max_queue_depth = report.max_queue_depth.max(queue.len());
                }
                next_id += 1;
                report.queue_depth.add(queue.len() as f64);
            }

            // Form the batch: FIFO order == request-id order.
            let members = take_batch(&mut queue, self.cfg.coalesce, self.cfg.coalesce_limit);
            if members.is_empty() {
                // Unreachable (queue is non-empty past the branch above),
                // but an empty batch must loop, not divide by zero below.
                continue;
            }
            let k = members.len();
            report.queue_depth.add(queue.len() as f64);

            // Sample each member (id order keeps the fork(1) stream
            // grouping-independent); the lane serves the whole batch.
            let mut mbs: Vec<MiniBatch> = Vec::with_capacity(k);
            let mut sample_dur = 0.0;
            for m in &members {
                let seeds: Vec<u32> = (0..batch)
                    .map(|kk| ((m.id as usize * batch + kk) % n_nodes) as u32)
                    .collect();
                let mb = sampler.sample(&seeds, &mut srng);
                let sim_sample = mb
                    .layers
                    .iter()
                    .map(|l| (l.n_dst * l.fanout) as f64)
                    .sum::<f64>()
                    * self.cfg.system.sample_s_per_edge;
                sample_dur += sim_sample;
                report.breakdown_sim.sample_s += sim_sample;
                mbs.push(mb);
            }
            resources[sampler].occupy(lane, t_start, sample_dur, ev);
            ev += 1;
            let mut t = t_start + sample_dur;

            // Push-down prices each member's streams *before* the
            // physical gather mutates tier state (read-only, pre-batch
            // classification — the trainer's ordering, DESIGN.md §14).
            // Aggregate payloads are per request, so the members' costs
            // sum; the raw gather cost below rides along for the
            // reduction factor.
            let pushed_cost = if self.cfg.aggregate_pushdown {
                let mut sum = TransferCost::default();
                for mb in &mbs {
                    let plan = AggregatePlan::build(mb)?;
                    let pd = self.store.pushdown_cost(&plan, self.cfg.dedup)?;
                    sum.absorb(&pd.cost);
                    let p = &mut report.pushdown;
                    p.pushed_bytes_on_link += pd.cost.bytes_on_link;
                    p.agg_bytes_on_link += pd.agg_bytes_on_link;
                    p.dst_rows += pd.dst_rows;
                    p.neighbor_rows += pd.neighbor_rows;
                    p.agg_rows += pd.agg_rows;
                    p.near_mem_flops += pd.near_mem_flops;
                    p.near_mem_s += pd.near_mem_s;
                }
                Some(sum)
            } else {
                None
            };

            // Gather (real rows, priced by the store's access mode).
            let raw_cost =
                self.gather_batch(&members, &mbs, dim, capture, &mut blocks, &mut report)?;
            let cost = match pushed_cost {
                Some(c) => {
                    report.pushdown.raw_bytes_on_link += raw_cost.bytes_on_link;
                    c
                }
                None => raw_cost,
            };
            report.breakdown_sim.transfer_s += cost.time_s;

            // Transfer window → CPU share, launch-only pre-segment, and
            // scaled per-class link occupancies (the epoch engine's
            // decomposition, shared via `link_window`).
            let d = cost.demand();
            if d.cpu_s > 0.0 {
                resources[sampler].occupy(lane, t, d.cpu_s, ev);
                ev += 1;
                t += d.cpu_s;
            }
            let win = link_window(&d);
            t += win.pre_s;
            let mut start = t;
            for (kind, class_s) in d.links() {
                if class_s > 0.0 {
                    let (free, _) = resources[kind.ordinal()].peek(0);
                    start = start.max(free);
                }
            }
            let mut seg = start;
            for (kind, class_s) in d.links() {
                if class_s > 0.0 {
                    let dur = class_s * win.scale;
                    resources[kind.ordinal()].occupy(0, seg, dur, ev);
                    ev += 1;
                    seg += dur;
                }
            }

            // Execute: the forward estimate scales with the member count.
            let exec_dur = sim_fwd * k as f64;
            report.breakdown_sim.train_s += exec_dur;
            let (gpu_free, _) = resources[gpu].peek(0);
            let exec_start = seg.max(gpu_free);
            resources[gpu].occupy(0, exec_start, exec_dur, ev);
            ev += 1;
            let completion = exec_start + exec_dur;
            report.makespan_s = report.makespan_s.max(completion);
            report.batches += 1;

            for m in &members {
                report.latency.add(completion - m.arrival_s);
                report.completed += 1;
                // Closed loop: the member's client comes straight back.
                if !open_loop && offered < total {
                    arrivals.push_back((completion, m.client));
                    offered += 1;
                }
            }
        }

        report.offered = offered;
        for r in &resources {
            report.busy.add(r.kind(), r.busy_s());
        }
        report.bound_by = report.busy.max_kind();
        report.tier = self.store.tier_stats().map(|now| match &tier_start {
            Some(s) => now.since(s),
            None => now,
        });
        Ok((report, blocks))
    }

    /// Gather one dispatched batch's feature rows and scatter them back
    /// per request.  Four shapes, one invariant — every member's block is
    /// bitwise what a solo gather of its stream returns:
    ///
    /// * coalesce + dedup: one [`CoalescedGatherPlan`] across members
    ///   (cross-request dedup), unique rows fetched once, scattered per
    ///   request;
    /// * coalesce, no dedup: the concatenated duplicated stream in one
    ///   fetch (fewer transfers, no row elimination);
    /// * no coalesce + dedup: the batch runner's per-request
    ///   `gather_planned`;
    /// * neither: the per-request duplicated gather.
    ///
    /// Each shape pins its window's rows in the paged hot-tier cache for
    /// the scatter's duration: between the gather and the last member's
    /// copy-out, a concurrent stream's admissions must not evict a page
    /// this batch is still reading (DESIGN.md §12).  The pin lands
    /// *after* `gather_into`/`gather_planned` returns — admission for
    /// this batch already ran inside `record()` — so pinning shifts no
    /// eviction decision and the single-client degeneracy anchor keeps
    /// its bit-exact reports.
    fn gather_batch(
        &mut self,
        members: &[Pending],
        mbs: &[MiniBatch],
        dim: usize,
        capture: bool,
        blocks: &mut [Vec<f32>],
        report: &mut ServingReport,
    ) -> Result<TransferCost> {
        debug_assert_eq!(members.len(), mbs.len());
        if self.cfg.coalesce {
            if self.cfg.dedup {
                let streams: Vec<&[u32]> = mbs.iter().map(|mb| mb.src_nodes.as_slice()).collect();
                let plan = CoalescedGatherPlan::build(&streams);
                debug_assert!(plan.validate(&streams).is_ok());
                let mut uniq = vec![0f32; plan.unique_rows() * dim];
                let cost = self.store.gather_into(plan.unique_nodes(), &mut uniq)?;
                self.store.pin_rows(plan.unique_nodes());
                report.requested_rows += plan.requested_rows() as u64;
                report.unique_rows += plan.unique_rows() as u64;
                let mut out = vec![0f32; self.gather_rows * dim];
                for (r, m) in members.iter().enumerate() {
                    out.resize(plan.request_rows(r) * dim, 0.0);
                    plan.scatter_request(r, &uniq, dim, &mut out);
                    if capture {
                        blocks[m.id as usize] = out.clone();
                    }
                }
                self.store.unpin_rows(plan.unique_nodes());
                Ok(cost)
            } else {
                let mut concat: Vec<u32> = Vec::new();
                for mb in mbs {
                    concat.extend_from_slice(&mb.src_nodes);
                }
                let mut out = vec![0f32; concat.len() * dim];
                let cost = self.store.gather_into(&concat, &mut out)?;
                self.store.pin_rows(&concat);
                report.requested_rows += concat.len() as u64;
                report.unique_rows += concat.len() as u64;
                if capture {
                    let mut lo = 0usize;
                    for (m, mb) in members.iter().zip(mbs) {
                        let hi = lo + mb.src_nodes.len() * dim;
                        blocks[m.id as usize] = out[lo..hi].to_vec();
                        lo = hi;
                    }
                }
                self.store.unpin_rows(&concat);
                Ok(cost)
            }
        } else {
            // One member per batch; reuse the batch runner's exact calls
            // so the single-client degeneracy anchor is structural.
            let (m, mb) = (&members[0], &mbs[0]);
            let mut out = vec![0f32; mb.src_nodes.len() * dim];
            let cost = if self.cfg.dedup {
                let plan = mb.compact();
                report.requested_rows += plan.requested_rows() as u64;
                report.unique_rows += plan.unique_rows() as u64;
                let cost = self.store.gather_planned(&plan, &mut out)?;
                self.store.pin_rows(plan.unique_nodes());
                self.store.unpin_rows(plan.unique_nodes());
                cost
            } else {
                let cost = self.store.gather_into(&mb.src_nodes, &mut out)?;
                report.requested_rows += mb.src_nodes.len() as u64;
                report.unique_rows += mb.src_nodes.len() as u64;
                self.store.pin_rows(&mb.src_nodes);
                self.store.unpin_rows(&mb.src_nodes);
                cost
            };
            if capture {
                blocks[m.id as usize] = out;
            }
            Ok(cost)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_queue_coalesce_window_cannot_panic() {
        // Regression: the coalesce window used to `pop_front().unwrap()`
        // `k` times — an empty queue must yield an empty batch in both
        // arms, never panic.
        let mut q: VecDeque<Pending> = VecDeque::new();
        assert!(take_batch(&mut q, true, 8).is_empty());
        assert!(take_batch(&mut q, false, 8).is_empty());

        for id in 0..5 {
            q.push_back(Pending {
                id,
                arrival_s: 0.0,
                client: 0,
            });
        }
        // Coalesced pops keep FIFO order and respect the limit.
        let b = take_batch(&mut q, true, 3);
        assert_eq!(b.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        // Non-coalesced pops exactly one.
        let b = take_batch(&mut q, false, 3);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 3);
        // A limit past the queue length drains what's there and no more.
        let b = take_batch(&mut q, true, 99);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 4);
        assert!(q.is_empty());
        assert!(take_batch(&mut q, true, 99).is_empty());
    }
}

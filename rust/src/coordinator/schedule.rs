//! The discrete-event overlap engine (DESIGN.md §9): pipelined simulated
//! epoch timelines with shared-resource contention, for every access mode.
//!
//! The serial accounting of DESIGN.md §5 adds the per-stage simulated
//! times (`sample + transfer + train + other`), which models the paper's
//! *unpipelined* epoch.  The end-to-end speedup the paper reports, though,
//! comes from overlapping the GPU-centric feature transfer under training
//! compute; the follow-ups push further (Data Tiering prefetches hot rows
//! while the GPU trains; GIDS keeps the NVMe queue saturated concurrently
//! with PCIe traffic).  [`schedule_epoch`] reproduces that: each training
//! step is a DAG of events
//!
//! ```text
//! sample ── cpu-gather ── link transfer ── train
//! (CPU)     (CPU)          (PCIe/NVLink/NVMe)  (GPU)
//! ```
//!
//! scheduled onto the stateful [`SimResource`]s of `simclock`, under a
//! `prefetch_depth`-bounded window: `sample(i)` may not start before
//! `train(i - depth)` has finished (at most `depth` steps in flight).
//! The per-step [`ResourceDemand`]s arrive already shaped by the gather
//! deduplication (DESIGN.md §10): with `--dedup` (the default) every
//! link occupancy reflects the compacted unique-row stream, so the
//! engine pipelines the reduced traffic; `--no-dedup` feeds it the
//! legacy duplicated-stream demands.  Either way the depth-0 anchor
//! below returns that run's own serial sum bit-exactly.
//! Per-stage durations are exactly the ones the serial accounting uses:
//! the transfer window is [`TransferCost::time_s`] split via
//! [`ResourceDemand`] into its CPU share (a CPU event), a chain-only GPU
//! pre-segment (kernel-launch overhead — it delays the step but occupies
//! no link), and the *launch-free* per-class link occupancies, laid out
//! host → peer → storage on their respective links.  The engine changes
//! *when* stages run, never how long they take, and each link's busy
//! time stays exactly the launch-free occupancy the cost model charged.
//!
//! **Degeneracy chain** (the regression anchor): depth 0 is defined as the
//! serial sum and returns it bit-exactly; depth 1 runs the event engine
//! with a window that still serializes every step (equal to the serial sum
//! up to floating-point summation order); depth ≥ 2 overlaps.  The epoch
//! makespan is monotone non-increasing in depth and bounded below by every
//! resource's busy time over its lane count (the links and the GPU are
//! single-lane; the sampler has `sampler_workers` lanes) — both pinned by
//! `tests/overlap_properties.rs` and `benches/overlap_sweep.rs`.
//!
//! Critical-path attribution: every event records which constraint bound
//! its start (previous stage, resource queue, or prefetch window), so
//! walking back from the last `train` event yields the exact chain whose
//! durations sum to the makespan — per-resource shares of that chain tell
//! which hardware bound the epoch.
//!
//! ```
//! use ptdirect::coordinator::schedule::{schedule_epoch, OverlapParams};
//! use ptdirect::interconnect::ResourceDemand;
//!
//! // Four steps: 1 ms sampling, 1 ms zero-copy transfer, 1 ms training.
//! let step = ResourceDemand {
//!     total_s: 1e-3, cpu_s: 0.0, host_s: 1e-3, peer_s: 0.0, storage_s: 0.0, net_s: 0.0,
//! };
//! let demands = vec![step; 4];
//! let serial = 4.0 * 3e-3;
//! let params = |depth| OverlapParams {
//!     sample_step_s: 1e-3, train_step_s: 1e-3, other_s: 0.0,
//!     serial_s: serial, prefetch_depth: depth, sampler_lanes: 1,
//! };
//! let anchor = schedule_epoch(&demands, &params(0));
//! assert_eq!(anchor.overlapped_s, serial);       // depth 0 == serial, bit-exact
//! let piped = schedule_epoch(&demands, &params(4));
//! assert!(piped.overlapped_s < serial);          // stages hide behind each other
//! assert!(piped.overlapped_s >= 4.0 * 1e-3);     // ≥ the busiest resource
//! ```
//!
//! [`TransferCost::time_s`]: crate::interconnect::TransferCost
//! [`ResourceDemand`]: crate::interconnect::ResourceDemand

use crate::coordinator::simclock::{ResourceBusy, ResourceKind, SimResource};
use crate::interconnect::{ResourceDemand, Topology};

/// Epoch-level inputs of the overlap engine (everything the per-step
/// [`ResourceDemand`]s don't carry).
#[derive(Clone, Copy, Debug)]
pub struct OverlapParams {
    /// Simulated sampling seconds per step (constant across an epoch).
    pub sample_step_s: f64,
    /// Simulated training seconds per step (constant across an epoch).
    pub train_step_s: f64,
    /// The serial accounting's bookkeeping term (`Breakdown::other_s`),
    /// added on top of the makespan — batch assembly does not pipeline.
    pub other_s: f64,
    /// The serial (additive) epoch total — the depth-0 anchor, returned
    /// bit-exactly as `overlapped_s` when `prefetch_depth == 0`.
    pub serial_s: f64,
    /// Bounded prefetch window: `sample(i)` waits for `train(i - depth)`.
    pub prefetch_depth: u32,
    /// CPU sampler lanes (`RunConfig::sampler_workers`).
    pub sampler_lanes: usize,
}

/// One epoch's overlapped timeline + critical-path attribution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverlapReport {
    pub prefetch_depth: u32,
    /// Serial (additive) epoch seconds — the DESIGN.md §5 accounting.
    pub serial_s: f64,
    /// Pipelined epoch seconds (== `serial_s` at depth 0).
    pub overlapped_s: f64,
    /// Seconds each resource was occupied.
    pub busy: ResourceBusy,
    /// Seconds each resource contributed to the epoch's critical path
    /// (the chain of binding constraints ending at the last train event;
    /// sums to the makespan).
    pub critical: ResourceBusy,
    /// The resource with the largest critical-path share — what bound
    /// this epoch.
    pub bound_by: ResourceKind,
}

impl OverlapReport {
    /// Serial over overlapped epoch time (≥ 1 up to rounding).
    pub fn speedup(&self) -> f64 {
        if self.overlapped_s > 0.0 {
            self.serial_s / self.overlapped_s
        } else {
            1.0
        }
    }

    /// Fraction of the critical path spent on `kind`.
    pub fn critical_share(&self, kind: ResourceKind) -> f64 {
        let total = self.critical.total();
        if total > 0.0 {
            self.critical.get(kind) / total
        } else {
            0.0
        }
    }
}

/// Link class a transfer's link-side time is attributed to in the serial
/// (depth-0) anchor: its busiest class, or the GPU (kernel launch) when it
/// touches no link at all (`GpuResident`, fully-hot tiered batches).  The
/// event engine attributes per-class segments directly.
fn dominant_link(d: &ResourceDemand) -> ResourceKind {
    let mut kind = ResourceKind::Gpu;
    let mut best = 0.0;
    for (k, s) in d.links() {
        if s > best {
            kind = k;
            best = s;
        }
    }
    kind
}

/// Decomposition of one step's transfer window into schedulable segments
/// (shared by the epoch engine and the serving engine): the launch-only
/// GPU pre-segment and the scale that fits the per-class link occupancies
/// inside the window.  See the long comment in [`schedule_epoch`]'s link
/// stage for the model.
pub(crate) struct LinkWindow {
    /// The transfer window minus its CPU share.
    pub link_dur_s: f64,
    /// Factor applied to each class occupancy so their sum fits the
    /// window (1.0 when they already fit).
    pub scale: f64,
    /// Chain-only GPU pre-segment (kernel-launch overhead — delays the
    /// step but occupies no link).
    pub pre_s: f64,
}

pub(crate) fn link_window(d: &ResourceDemand) -> LinkWindow {
    let link_dur_s = (d.total_s - d.cpu_s).max(0.0);
    let raw_class_s = d.link_total();
    let scale = if raw_class_s > link_dur_s && raw_class_s > 0.0 {
        link_dur_s / raw_class_s
    } else {
        1.0
    };
    let pre_s = (link_dur_s - raw_class_s * scale).max(0.0);
    LinkWindow {
        link_dur_s,
        scale,
        pre_s,
    }
}

/// One scheduled stage: its attribution resource, duration, and the event
/// that bound its start time (`None` for an unconstrained start at t=0).
struct Event {
    res: ResourceKind,
    dur_s: f64,
    binding: Option<usize>,
}

/// Schedule one epoch's steps onto the shared resources and report the
/// overlapped timeline (see the module docs for the model).
pub fn schedule_epoch(demands: &[ResourceDemand], p: &OverlapParams) -> OverlapReport {
    if p.prefetch_depth == 0 {
        return serial_anchor(demands, p);
    }

    let lanes = p.sampler_lanes.max(1);
    let depth = p.prefetch_depth as usize;
    // One lane set per registered resource, in canonical topology order
    // (indexed by kind ordinal — a new link joins the schedule by joining
    // the topology, DESIGN.md §15).
    let mut resources: Vec<SimResource> = Topology::lanes(lanes)
        .links()
        .iter()
        .map(|l| SimResource::new(l.kind, l.lanes))
        .collect();
    let sampler = ResourceKind::Sampler.ordinal();
    let gpu = ResourceKind::Gpu.ordinal();
    let mut events: Vec<Event> = Vec::with_capacity(4 * demands.len());
    // (finish, event id) of each step's train stage — the window gates.
    let mut train_done: Vec<(f64, usize)> = Vec::with_capacity(demands.len());

    for (i, d) in demands.iter().enumerate() {
        let lane = i % lanes;

        // --- sample: CPU lane, gated by the prefetch window ---
        let (mut start, mut bind) = (0.0, None);
        if i >= depth {
            let (finish, ev) = train_done[i - depth];
            start = finish;
            bind = Some(ev);
        }
        let (free, last) = resources[sampler].peek(lane);
        if free > start {
            start = free;
            bind = last;
        }
        let ev = events.len();
        events.push(Event { res: ResourceKind::Sampler, dur_s: p.sample_step_s, binding: bind });
        resources[sampler].occupy(lane, start, p.sample_step_s, ev);
        let mut t = start + p.sample_step_s;
        let mut prev = ev;

        // --- CPU-side gather/staging share (baseline + UVM fault work):
        // same lane, right behind the sample — it fights sampling for CPU.
        if d.cpu_s > 0.0 {
            let ev = events.len();
            events.push(Event { res: ResourceKind::Sampler, dur_s: d.cpu_s, binding: Some(prev) });
            resources[sampler].occupy(lane, t, d.cpu_s, ev);
            t += d.cpu_s;
            prev = ev;
        }

        // --- link transfer: the step's transfer window minus its CPU
        // share, split into a chain-only GPU pre-segment (kernel-launch
        // overhead — it delays the step but occupies no link) and the
        // *launch-free* per-class occupancies of `PathSplit`, laid out in
        // canonical link order (host -> peer -> storage -> net) inside the
        // window (an NVMe-mode step's storage reads drain right behind its
        // host reads on the shared PCIe root complex, DESIGN.md §8).  When
        // the summed class occupancies exceed the window (the sharded
        // per-GPU times sum across concurrent GPUs; the baseline's
        // host_time includes its CPU share), they are scaled to fit —
        // per-link busy time never exceeds what the step actually spends
        // on the link.
        let win = link_window(d);
        let scale = win.scale;
        if win.pre_s > 0.0 {
            let ev = events.len();
            events.push(Event { res: ResourceKind::Gpu, dur_s: win.pre_s, binding: Some(prev) });
            t += win.pre_s;
            prev = ev;
        }
        let (mut start, mut bind) = (t, Some(prev));
        for (kind, class_s) in d.links() {
            if class_s > 0.0 {
                let (free, last) = resources[kind.ordinal()].peek(0);
                if free > start {
                    start = free;
                    bind = last;
                }
            }
        }
        let mut seg = start;
        let mut first = true;
        for (kind, class_s) in d.links() {
            if class_s > 0.0 {
                let dur = class_s * scale;
                let ev = events.len();
                let binding = if first { bind } else { Some(prev) };
                events.push(Event { res: kind, dur_s: dur, binding });
                resources[kind.ordinal()].occupy(0, seg, dur, ev);
                seg += dur;
                prev = ev;
                first = false;
            }
        }
        let t = seg;

        // --- train: the single GPU, in step order ---
        let (mut start, mut bind) = (t, Some(prev));
        let (free, last) = resources[gpu].peek(0);
        if free > start {
            start = free;
            bind = last;
        }
        let ev = events.len();
        events.push(Event { res: ResourceKind::Gpu, dur_s: p.train_step_s, binding: bind });
        resources[gpu].occupy(0, start, p.train_step_s, ev);
        train_done.push((start + p.train_step_s, ev));
    }

    let makespan_s = train_done.last().map(|&(f, _)| f).unwrap_or(0.0);

    // Critical path: walk the binding chain back from the last train
    // event.  Every start equals its binding constraint's finish exactly
    // (it was picked by `max`), so the chain's durations sum to the
    // makespan — pinned by `tests/overlap_properties.rs`.
    let mut critical = ResourceBusy::default();
    let mut cursor = train_done.last().map(|&(_, ev)| ev);
    while let Some(ev) = cursor {
        critical.add(events[ev].res, events[ev].dur_s);
        cursor = events[ev].binding;
    }

    let mut busy = ResourceBusy::default();
    for r in &resources {
        busy.add(r.kind(), r.busy_s());
    }

    OverlapReport {
        prefetch_depth: p.prefetch_depth,
        serial_s: p.serial_s,
        overlapped_s: makespan_s + p.other_s,
        busy,
        critical,
        bound_by: critical.max_kind(),
    }
}

/// Depth 0: the pre-engine serial accounting, returned bit-exactly (the
/// regression anchor).  Everything is on the critical path when nothing
/// overlaps, so attribution is the per-resource share of the serial time.
fn serial_anchor(demands: &[ResourceDemand], p: &OverlapParams) -> OverlapReport {
    let mut busy = ResourceBusy::default();
    let mut critical = ResourceBusy::default();
    for d in demands {
        let link_dur = link_window(d).link_dur_s;
        busy.add(ResourceKind::Sampler, p.sample_step_s + d.cpu_s);
        critical.add(ResourceKind::Sampler, p.sample_step_s + d.cpu_s);
        for (kind, s) in d.links() {
            if s > 0.0 {
                busy.add(kind, link_dur);
            }
        }
        critical.add(dominant_link(d), link_dur);
        busy.add(ResourceKind::Gpu, p.train_step_s);
        critical.add(ResourceKind::Gpu, p.train_step_s);
    }
    OverlapReport {
        prefetch_depth: 0,
        serial_s: p.serial_s,
        overlapped_s: p.serial_s,
        busy,
        critical,
        bound_by: critical.max_kind(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn host_step(total_s: f64) -> ResourceDemand {
        ResourceDemand {
            total_s,
            cpu_s: 0.0,
            host_s: total_s,
            ..ResourceDemand::default()
        }
    }

    fn params(depth: u32, serial_s: f64) -> OverlapParams {
        OverlapParams {
            sample_step_s: 1e-3,
            train_step_s: 1e-3,
            other_s: 0.0,
            serial_s,
            prefetch_depth: depth,
            sampler_lanes: 1,
        }
    }

    fn serial_of(demands: &[ResourceDemand], p: &OverlapParams) -> f64 {
        p.sample_step_s * demands.len() as f64
            + demands.iter().map(|d| d.total_s).sum::<f64>()
            + p.train_step_s * demands.len() as f64
            + p.other_s
    }

    #[test]
    fn depth_zero_returns_the_serial_anchor_bit_exactly() {
        let demands = vec![host_step(2e-3); 5];
        let mut p = params(0, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        assert_eq!(r.overlapped_s, p.serial_s);
        assert_eq!(r.serial_s, p.serial_s);
        assert_eq!(r.prefetch_depth, 0);
    }

    #[test]
    fn depth_one_serializes_every_step() {
        // sample(i) waits for train(i-1): the window admits one step at a
        // time, so the makespan is the per-step chain sum.
        let demands = vec![host_step(2e-3); 4];
        let mut p = params(1, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        let chain = 4.0 * (1e-3 + 2e-3 + 1e-3);
        assert!((r.overlapped_s - chain).abs() < 1e-12, "{}", r.overlapped_s);
    }

    #[test]
    fn deep_window_overlaps_and_respects_both_bounds() {
        let demands = vec![host_step(2e-3); 8];
        let mut p = params(8, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        assert!(r.overlapped_s < p.serial_s, "no overlap happened");
        // Lower bound: the busiest resource (host link, 8 × 2 ms).
        assert!(r.overlapped_s >= 8.0 * 2e-3);
        assert_eq!(r.bound_by, ResourceKind::HostLink);
    }

    #[test]
    fn critical_path_sums_to_the_makespan() {
        let demands: Vec<ResourceDemand> =
            (0..6).map(|i| host_step(1e-3 + i as f64 * 1e-4)).collect();
        let mut p = params(3, 0.0);
        p.other_s = 5e-4;
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        let makespan = r.overlapped_s - p.other_s;
        assert!(
            (r.critical.total() - makespan).abs() < 1e-12,
            "critical {} != makespan {makespan}",
            r.critical.total()
        );
    }

    #[test]
    fn cpu_gather_share_contends_with_sampling() {
        // Baseline-shaped steps: half the transfer is CPU gather work.
        // The CPU must serialize sample + gather, so the epoch stays above
        // the summed CPU time even with a deep window.
        let demands: Vec<ResourceDemand> = (0..6)
            .map(|_| ResourceDemand {
                total_s: 2e-3,
                cpu_s: 1e-3,
                host_s: 2e-3,
                ..ResourceDemand::default()
            })
            .collect();
        let mut p = params(8, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        let cpu_busy = 6.0 * (1e-3 + 1e-3);
        assert!((r.busy.get(ResourceKind::Sampler) - cpu_busy).abs() < 1e-12);
        assert!(r.overlapped_s >= cpu_busy);
        // Sample + gather saturate the single CPU lane: the epoch is
        // CPU-bound and the attribution says so.
        assert_eq!(r.bound_by, ResourceKind::Sampler);
        assert!(r.critical.get(ResourceKind::Sampler) > r.critical.get(ResourceKind::HostLink));
    }

    #[test]
    fn monotone_non_increasing_in_depth() {
        let demands: Vec<ResourceDemand> = (0..10)
            .map(|i| ResourceDemand {
                total_s: (1 + i % 3) as f64 * 1e-3,
                cpu_s: if i % 2 == 0 { 2e-4 } else { 0.0 },
                host_s: 8e-4,
                peer_s: if i % 3 == 0 { 3e-4 } else { 0.0 },
                ..ResourceDemand::default()
            })
            .collect();
        let mut last = f64::INFINITY;
        for depth in 0..=8 {
            let mut p = params(depth, 0.0);
            p.serial_s = serial_of(&demands, &p);
            let r = schedule_epoch(&demands, &p);
            assert!(
                r.overlapped_s <= last * (1.0 + 1e-12),
                "depth {depth}: {} > {last}",
                r.overlapped_s
            );
            last = r.overlapped_s;
        }
    }

    #[test]
    fn multi_lane_sampler_relieves_the_cpu_bound() {
        // Sampling dominates; two lanes should roughly halve the epoch.
        let demands = vec![host_step(1e-4); 8];
        let mut p = params(8, 0.0);
        p.sample_step_s = 2e-3;
        p.serial_s = serial_of(&demands, &p);
        let one = schedule_epoch(&demands, &p);
        p.sampler_lanes = 2;
        let two = schedule_epoch(&demands, &p);
        assert!(two.overlapped_s < one.overlapped_s);
        assert_eq!(one.bound_by, ResourceKind::Sampler);
    }

    #[test]
    fn empty_epoch_is_just_the_bookkeeping_tail() {
        let mut p = params(4, 0.0);
        p.other_s = 1e-3;
        p.serial_s = 1e-3;
        let r = schedule_epoch(&[], &p);
        assert_eq!(r.overlapped_s, 1e-3);
    }

    #[test]
    fn storage_and_host_steps_interleave_across_steps() {
        // Alternating host-only and storage-only transfers: with a deep
        // window the two links overlap across steps, beating depth 1.
        let demands: Vec<ResourceDemand> = (0..8)
            .map(|i| {
                if i % 2 == 0 {
                    host_step(2e-3)
                } else {
                    ResourceDemand {
                        total_s: 2e-3,
                        storage_s: 2e-3,
                        ..ResourceDemand::default()
                    }
                }
            })
            .collect();
        let mut p1 = params(1, 0.0);
        p1.serial_s = serial_of(&demands, &p1);
        let mut p4 = params(4, 0.0);
        p4.serial_s = p1.serial_s;
        let serialised = schedule_epoch(&demands, &p1);
        let piped = schedule_epoch(&demands, &p4);
        assert!(piped.overlapped_s < serialised.overlapped_s);
        assert!(
            piped.busy.get(ResourceKind::StorageLink) > 0.0
                && piped.busy.get(ResourceKind::HostLink) > 0.0
        );
    }

    #[test]
    fn net_demand_occupies_the_net_lane() {
        // Remote-fetch-shaped steps: part of the transfer window rides the
        // network lane.  The engine must track its busy time separately
        // and still overlap it against the other links across steps.
        let demands: Vec<ResourceDemand> = (0..8)
            .map(|i| {
                if i % 2 == 0 {
                    host_step(2e-3)
                } else {
                    ResourceDemand {
                        total_s: 2e-3,
                        host_s: 1e-3,
                        net_s: 1e-3,
                        ..ResourceDemand::default()
                    }
                }
            })
            .collect();
        let mut p = params(4, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        assert!((r.busy.get(ResourceKind::NetLink) - 4.0 * 1e-3).abs() < 1e-12);
        assert!(r.busy.get(ResourceKind::HostLink) > 0.0);
        // Net-free steps leave the lane untouched in the serial anchor too.
        let mut p0 = params(0, 0.0);
        p0.serial_s = serial_of(&demands, &p0);
        let anchor = schedule_epoch(&demands, &p0);
        assert!(anchor.busy.get(ResourceKind::NetLink) > 0.0);
        let host_only = vec![host_step(2e-3); 4];
        let mut ph = params(0, 0.0);
        ph.serial_s = serial_of(&host_only, &ph);
        assert_eq!(schedule_epoch(&host_only, &ph).busy.get(ResourceKind::NetLink), 0.0);
    }

    #[test]
    fn speedup_and_shares_are_consistent() {
        let demands = vec![host_step(2e-3); 6];
        let mut p = params(4, 0.0);
        p.serial_s = serial_of(&demands, &p);
        let r = schedule_epoch(&demands, &p);
        assert!(r.speedup() > 1.0);
        let share_sum: f64 = ResourceKind::all()
            .iter()
            .map(|&k| r.critical_share(k))
            .sum();
        assert!((share_sum - 1.0).abs() < 1e-9);
    }
}

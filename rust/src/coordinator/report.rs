//! Plain-text table formatting for benches and the CLI (criterion is not
//! vendored; every bench prints paper-style tables through this), plus the
//! per-GPU epoch table of the sharded mode and the overlap engine's
//! critical-path summary line.

use crate::coordinator::schedule::OverlapReport;
use crate::coordinator::simclock::ResourceKind;
use crate::featurestore::ShardStats;
use crate::util::bytes::human_bytes;

/// Column-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds as adaptive milliseconds string.
pub fn ms(s: f64) -> String {
    format!("{:.2}", s * 1e3)
}

/// Per-GPU epoch columns for a sharded run (`EpochReport::shard`): row and
/// byte splits across the local/peer/host paths, link occupancy, and the
/// busy time whose spread is the load-imbalance factor.
pub fn shard_table(stats: &ShardStats) -> Table {
    let mut t = Table::new(
        &format!(
            "per-GPU epoch breakdown — {} GPUs, {} placement (imbalance {:.2}x)",
            stats.num_gpus(),
            stats.policy.label(),
            stats.load_imbalance()
        ),
        &[
            "gpu", "shard rows", "hot/cap", "local", "peer", "host", "remote", "halo",
            "peer B", "host B", "net B", "peer ms", "host ms", "net ms", "busy ms",
        ],
    );
    for (g, s) in stats.per_gpu.iter().enumerate() {
        t.row(&[
            g.to_string(),
            s.shard_rows.to_string(),
            format!("{}/{}", s.hot_rows, s.capacity_rows),
            s.local_rows.to_string(),
            s.peer_rows.to_string(),
            s.host_rows.to_string(),
            s.remote_rows.to_string(),
            s.halo_rows.to_string(),
            human_bytes(s.peer_bytes),
            human_bytes(s.host_bytes),
            human_bytes(s.remote_bytes),
            ms(s.peer_time_s),
            ms(s.host_time_s),
            ms(s.net_time_s),
            ms(s.busy_s),
        ]);
    }
    t
}

/// Format a ratio as "1.23x".
pub fn ratio(r: f64) -> String {
    format!("{r:.2}x")
}

/// One-line critical-path attribution for the per-epoch report:
/// nonzero resource shares in reporting order, then the binding resource
/// — e.g. `"sampler 31% / host-link 61% / gpu 8% -> bound by host-link"`.
pub fn critical_path_summary(o: &OverlapReport) -> String {
    let shares: Vec<String> = ResourceKind::all()
        .iter()
        .filter(|&&k| o.critical.get(k) > 0.0)
        .map(|&k| format!("{} {}", k.label(), pct(o.critical_share(k))))
        .collect();
    if shares.is_empty() {
        return "idle".into();
    }
    format!("{} -> bound by {}", shares.join(" / "), o.bound_by.label())
}

/// Format a fraction as "12.3%".
pub fn pct(f: f64) -> String {
    format!("{:.1}%", f * 100.0)
}

/// One-line tail-latency summary in milliseconds for a latency
/// [`Summary`](crate::util::stats::Summary) — the serving engine's SLO
/// digest: `"p50 1.20ms  p95 3.40ms  p99 5.60ms  p999 7.80ms  max 9.00ms"`.
pub fn latency_line(s: &crate::util::stats::Summary) -> String {
    format!(
        "p50 {}ms  p95 {}ms  p99 {}ms  p999 {}ms  max {}ms",
        ms(s.percentile(0.50)),
        ms(s.percentile(0.95)),
        ms(s.percentile(0.99)),
        ms(s.percentile(0.999)),
        ms(s.max())
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "12345".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| long-name |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len()); // aligned widths
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.0123), "12.30");
        assert_eq!(ratio(1.234), "1.23x");
        assert_eq!(pct(0.471), "47.1%");
    }

    #[test]
    fn latency_line_reports_ms_percentiles() {
        let mut s = crate::util::stats::Summary::new();
        for i in 1..=1000 {
            s.add(i as f64 * 1e-3); // 1ms..1000ms
        }
        let line = latency_line(&s);
        assert!(line.starts_with("p50 500.00ms"), "{line}");
        assert!(line.contains("p99 990.00ms"), "{line}");
        assert!(line.ends_with("max 1000.00ms"), "{line}");
    }

    #[test]
    fn critical_path_summary_names_shares_and_binder() {
        use crate::coordinator::simclock::ResourceBusy;
        let mut critical = ResourceBusy::default();
        critical.add(ResourceKind::Sampler, 1.0);
        critical.add(ResourceKind::HostLink, 3.0);
        let o = OverlapReport {
            prefetch_depth: 2,
            serial_s: 5.0,
            overlapped_s: 4.0,
            busy: ResourceBusy::default(),
            critical,
            bound_by: ResourceKind::HostLink,
        };
        let line = critical_path_summary(&o);
        assert!(line.contains("sampler 25.0%"), "{line}");
        assert!(line.contains("host-link 75.0%"), "{line}");
        assert!(line.ends_with("bound by host-link"), "{line}");
        assert!(!line.contains("gpu"), "zero shares must be elided: {line}");
        assert_eq!(critical_path_summary(&OverlapReport::default()), "idle");
    }

    #[test]
    fn shard_table_has_one_row_per_gpu() {
        use crate::config::ShardPolicy;
        use crate::featurestore::GpuShardStats;
        let stats = ShardStats {
            policy: ShardPolicy::Degree,
            per_gpu: vec![GpuShardStats::default(); 3],
        };
        let t = shard_table(&stats);
        assert_eq!(t.rows(), 3);
        let r = t.render();
        assert!(r.contains("3 GPUs"));
        assert!(r.contains("degree"));
    }
}

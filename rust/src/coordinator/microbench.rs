//! Microbenchmark drivers for paper Figs. 6 and 7 (library side; the bench
//! binaries and the CLI both call these).
//!
//! Fig. 6: gather N features of S bytes each from a 4M-row table, compare
//! Py (CPU gather + DMA) vs PyD (zero-copy aligned) vs ideal, across the
//! three Table-5 systems.
//!
//! Fig. 7: fix N, sweep the feature size from 2048 B to 2076 B in 4 B
//! steps, compare Py vs PyD-naive vs PyD-optimized.

use crate::config::SystemProfile;
use crate::device::warp::{count_requests, WarpModel};
use crate::interconnect::{DmaEngine, PcieLink};
use crate::util::rng::Rng;

/// Paper's microbenchmark table size ("total number of items is fixed to
/// 4M for all experiments").
pub const TABLE_ROWS: u32 = 4_000_000;

/// One (N, S, system) microbenchmark cell.
#[derive(Clone, Copy, Debug)]
pub struct MicrobenchCell {
    pub n_features: u64,
    pub feat_bytes: u64,
    pub ideal_s: f64,
    pub py_s: f64,
    pub pyd_s: f64,
    pub pyd_naive_s: f64,
}

impl MicrobenchCell {
    pub fn py_slowdown(&self) -> f64 {
        self.py_s / self.ideal_s
    }

    pub fn pyd_slowdown(&self) -> f64 {
        self.pyd_s / self.ideal_s
    }

    pub fn pyd_speedup_over_py(&self) -> f64 {
        self.py_s / self.pyd_s
    }
}

/// Random gather indices (uniform over the table, like the paper's RNG).
pub fn random_indices(n: u64, rng: &mut Rng) -> Vec<u32> {
    (0..n).map(|_| rng.gen_range(TABLE_ROWS as u64) as u32).collect()
}

/// Evaluate one microbenchmark cell on one system.
pub fn run_cell(
    sys: &SystemProfile,
    n_features: u64,
    feat_bytes: u64,
    rng: &mut Rng,
) -> MicrobenchCell {
    let idx = random_indices(n_features, rng);
    let feat_elems = feat_bytes / 4;
    let link = PcieLink::new(sys);
    let dma = DmaEngine::new(sys);

    let ideal = link.ideal(n_features * feat_bytes);
    let py = dma.cpu_gather_transfer(n_features, feat_bytes);
    let model = WarpModel::default();
    let opt = count_requests(&idx, feat_elems, model, model.shift_applies(feat_elems));
    let naive = count_requests(&idx, feat_elems, model, false);
    MicrobenchCell {
        n_features,
        feat_bytes,
        ideal_s: ideal.time_s,
        py_s: py.time_s,
        pyd_s: link.direct_gather(&opt).time_s,
        pyd_naive_s: link.direct_gather(&naive).time_s,
    }
}

/// The paper's Fig. 6 grid: N ∈ {8K..256K}, S ∈ {256 B..16 KiB}.
pub fn fig6_grid() -> (Vec<u64>, Vec<u64>) {
    (
        vec![8 << 10, 32 << 10, 128 << 10, 256 << 10],
        vec![256, 1024, 4096, 16384],
    )
}

/// Fig. 7 sweep: 2048..=2076 B in 4 B strides.
pub fn fig7_sizes() -> Vec<u64> {
    (0..8).map(|i| 2048 + 4 * i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape_on_all_systems() {
        // Paper §5.2: Py slowdowns 1.85x-5.01x (excluding the tiny-transfer
        // corner); PyD within 1.03x-1.20x of ideal.
        let mut rng = Rng::new(1);
        for sys in SystemProfile::all() {
            for &(n, s) in &[(32u64 << 10, 1024u64), (128 << 10, 4096), (256 << 10, 16384)] {
                let c = run_cell(&sys, n, s, &mut rng);
                let pys = c.py_slowdown();
                let pyds = c.pyd_slowdown();
                assert!(
                    (1.5..5.6).contains(&pys),
                    "{}: Py slowdown {pys} at ({n},{s})",
                    sys.name
                );
                assert!(
                    (1.0..1.30).contains(&pyds),
                    "{}: PyD slowdown {pyds} at ({n},{s})",
                    sys.name
                );
            }
        }
    }

    #[test]
    fn system2_py_is_worst() {
        let mut rng = Rng::new(2);
        let s1 = run_cell(&SystemProfile::system1(), 128 << 10, 4096, &mut rng);
        let s2 = run_cell(&SystemProfile::system2(), 128 << 10, 4096, &mut rng);
        assert!(s2.py_slowdown() > s1.py_slowdown());
    }

    #[test]
    fn tiny_transfer_corner_overhead_bound() {
        // (8K, 256B): the paper exempts this cell — API overheads dominate.
        let mut rng = Rng::new(3);
        let c = run_cell(&SystemProfile::system1(), 8 << 10, 256, &mut rng);
        assert!(c.pyd_slowdown() > 1.05);
    }

    #[test]
    fn fig7_alignment_band() {
        // Paper §5.3: naive ~1.17x over Py at 2052 B; optimized ~1.95x;
        // optimized benefit roughly constant (~1.93x average).
        let sys = SystemProfile::system1();
        let mut rng = Rng::new(4);
        let mut opt_speedups = Vec::new();
        for s in fig7_sizes() {
            let c = run_cell(&sys, 64 << 10, s, &mut rng);
            let naive_speedup = c.py_s / c.pyd_naive_s;
            let opt_speedup = c.py_s / c.pyd_s;
            assert!(opt_speedup >= naive_speedup - 1e-9);
            if s % 128 != 0 {
                // misaligned: the gap must be large
                assert!(
                    opt_speedup / naive_speedup > 1.4,
                    "s={s}: opt {opt_speedup} naive {naive_speedup}"
                );
            }
            opt_speedups.push(opt_speedup);
        }
        let avg = opt_speedups.iter().sum::<f64>() / opt_speedups.len() as f64;
        assert!((1.5..2.5).contains(&avg), "avg opt speedup {avg}");
    }
}

//! Paper-testbed cost models for the *measured-here, simulated-there*
//! split (DESIGN.md §5): GPU step time from a FLOP estimate, host sampling
//! time from an edges-examined estimate.

use crate::config::SystemProfile;
use crate::runtime::artifact::ArtifactSpec;

/// FLOP/edge-work estimator for one model variant.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    pub flops_per_step: f64,
    pub kernel_launches: u64,
    pub sample_slots_per_step: u64,
}

/// Hidden width assumed when no artifact manifest supplies one (matches the
/// AOT compiler's default GNN width).
pub const DEFAULT_HIDDEN: usize = 64;

impl ComputeModel {
    /// Estimate from the artifact's shapes.
    pub fn from_spec(spec: &ArtifactSpec) -> ComputeModel {
        estimate(
            spec.arch.as_deref().unwrap_or("sage"),
            spec.batch,
            spec.hidden,
            spec.in_dim,
            spec.classes,
            &spec.fanouts,
            &spec.layer_sizes,
            spec.param_elems(),
        )
    }

    /// Estimate from run-config shapes when no artifact manifest exists
    /// (native-backend inference/serving).  Layer sizes follow the
    /// sampler's dst-prefix convention and the parameter count mirrors the
    /// AOT compiler's layouts, so the estimate matches `from_spec` on an
    /// artifact compiled for the same shapes.
    pub fn from_shape(
        arch: &str,
        batch: usize,
        fanouts: &[usize],
        in_dim: usize,
        hidden: usize,
        classes: usize,
    ) -> ComputeModel {
        let nl = fanouts.len();
        let layer_sizes = Self::layer_sizes_for(batch, fanouts);
        let mut dims = vec![in_dim];
        for _ in 0..nl {
            dims.push(hidden);
        }
        let mut params = 0usize;
        for l in 0..nl {
            let (d_in, d_out) = (dims[l], dims[l + 1]);
            params += if arch == "gat" {
                d_in * d_out + 3 * d_out // W + attention pair + bias
            } else {
                2 * d_in * d_out + d_out // W_self + W_nbr + bias
            };
        }
        params += hidden * classes + classes; // head
        estimate(
            arch,
            batch,
            hidden,
            in_dim,
            classes,
            fanouts,
            &layer_sizes,
            params,
        )
    }

    /// Simulated layer sizes for config shapes (dst-prefix convention:
    /// `layer_sizes[0]` is the gathered block, `layer_sizes[nl]` the batch).
    pub fn layer_sizes_for(batch: usize, fanouts: &[usize]) -> Vec<usize> {
        let nl = fanouts.len();
        let mut layer_sizes = vec![0usize; nl + 1];
        layer_sizes[nl] = batch;
        for l in (0..nl).rev() {
            layer_sizes[l] = layer_sizes[l + 1] * (1 + fanouts[l]);
        }
        layer_sizes
    }

    /// Simulated GPU step time on `sys`.
    pub fn train_step_s(&self, sys: &SystemProfile) -> f64 {
        self.flops_per_step / (sys.gpu_fp32_flops * sys.gpu_efficiency)
            + self.kernel_launches as f64 * sys.kernel_launch_s
    }

    /// Simulated host sampling time per step on `sys`.
    pub fn sample_step_s(&self, sys: &SystemProfile) -> f64 {
        self.sample_slots_per_step as f64 * sys.sample_s_per_edge
    }
}

#[allow(clippy::too_many_arguments)]
fn estimate(
    arch: &str,
    batch: usize,
    hidden: usize,
    in_dim: usize,
    classes: usize,
    fanouts: &[usize],
    layer_sizes: &[usize],
    param_elems: usize,
) -> ComputeModel {
    let nl = fanouts.len();
    let mut dims = vec![in_dim];
    for _ in 0..nl {
        dims.push(hidden);
    }
    let mut fwd = 0f64;
    let mut launches = 6u64; // loss + optimizer epilogue
    for l in 0..nl {
        let n_dst = layer_sizes[l + 1] as f64;
        let n_src = layer_sizes[l] as f64;
        let k = fanouts[l] as f64;
        if arch == "gat" {
            // projection of all sources + per-slot attention work
            fwd += 2.0 * n_src * dims[l] as f64 * dims[l + 1] as f64; // z = x W
            fwd += n_dst * (k + 1.0) * dims[l + 1] as f64 * 6.0; // scores+softmax+wsum
            launches += 12;
        } else {
            fwd += 2.0 * n_dst * dims[l] as f64 * dims[l + 1] as f64; // W_self
            fwd += 2.0 * n_dst * dims[l] as f64 * dims[l + 1] as f64; // W_nbr
            fwd += n_dst * k * dims[l] as f64 * 2.0; // masked mean agg
            launches += 8;
        }
    }
    // classifier head
    fwd += 2.0 * batch as f64 * hidden as f64 * classes as f64;
    // backward ~= 2x forward; SGD+momentum ~= 4 ops/param
    let flops = fwd * 3.0 + param_elems as f64 * 4.0;
    // sampling examines each neighbor slot (+ bookkeeping folded into
    // the per-edge constant)
    let slots: u64 = (0..nl)
        .map(|l| (layer_sizes[l + 1] * fanouts[l]) as u64)
        .sum();
    ComputeModel {
        flops_per_step: flops,
        kernel_launches: launches,
        sample_slots_per_step: slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ArtifactKind, ArtifactSpec};

    fn spec(arch: &str) -> ArtifactSpec {
        ArtifactSpec {
            name: format!("{arch}_x"),
            file: "x.hlo.txt".into(),
            kind: ArtifactKind::Train,
            arch: Some(arch.into()),
            batch: 64,
            hidden: 64,
            in_dim: 100,
            classes: 47,
            fanouts: vec![5, 5],
            layer_sizes: vec![2304, 384, 64],
            lr: 0.003,
            momentum: 0.9,
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn flops_scale_with_arch_and_width() {
        let sage = ComputeModel::from_spec(&spec("sage"));
        assert!(sage.flops_per_step > 1e6);
        let mut wide = spec("sage");
        wide.in_dim = 800;
        let sage_w = ComputeModel::from_spec(&wide);
        assert!(sage_w.flops_per_step > 3.0 * sage.flops_per_step);
    }

    #[test]
    fn gat_heavier_than_sage_in_time() {
        // Paper §5.4: "GAT training is computationally heavier than
        // GraphSAGE" (per gathered byte), so PyD helps it less.
        let sys = SystemProfile::system1();
        let sage = ComputeModel::from_spec(&spec("sage"));
        let gat = ComputeModel::from_spec(&spec("gat"));
        assert!(gat.train_step_s(&sys) > 0.5 * sage.train_step_s(&sys));
        assert!(gat.kernel_launches > sage.kernel_launches);
    }

    #[test]
    fn from_shape_matches_spec_shapes() {
        let a = ComputeModel::from_spec(&spec("sage"));
        let b = ComputeModel::from_shape("sage", 64, &[5, 5], 100, 64, 47);
        assert_eq!(a.sample_slots_per_step, b.sample_slots_per_step);
        assert_eq!(a.kernel_launches, b.kernel_launches);
        // identical except from_shape's analytic optimizer-param term (the
        // fixture spec carries no IoSpec inputs, so its param_elems() is 0)
        let params = (2 * 100 * 64 + 64) + (2 * 64 * 64 + 64) + 64 * 47 + 47;
        let param_term = params as f64 * 4.0;
        assert!((b.flops_per_step - a.flops_per_step - param_term).abs() < 1e-6);
        assert_eq!(
            ComputeModel::layer_sizes_for(64, &[5, 5]),
            vec![2304, 384, 64]
        );
    }

    #[test]
    fn sample_time_counts_all_slots() {
        let m = ComputeModel::from_spec(&spec("sage"));
        assert_eq!(m.sample_slots_per_step, (384 * 5 + 64 * 5) as u64);
        let sys = SystemProfile::system1();
        assert!(m.sample_step_s(&sys) > 0.0);
    }
}

//! Paper-testbed cost models for the *measured-here, simulated-there*
//! split (DESIGN.md §5): GPU step time from a FLOP estimate, host sampling
//! time from an edges-examined estimate.

use crate::config::SystemProfile;
use crate::runtime::artifact::ArtifactSpec;

/// FLOP/edge-work estimator for one model variant.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    pub flops_per_step: f64,
    pub kernel_launches: u64,
    pub sample_slots_per_step: u64,
}

impl ComputeModel {
    /// Estimate from the artifact's shapes.
    pub fn from_spec(spec: &ArtifactSpec) -> ComputeModel {
        let arch = spec.arch.as_deref().unwrap_or("sage");
        let nl = spec.fanouts.len();
        let mut dims = vec![spec.in_dim];
        for _ in 0..nl {
            dims.push(spec.hidden);
        }
        let mut fwd = 0f64;
        let mut launches = 6u64; // loss + optimizer epilogue
        for l in 0..nl {
            let n_dst = spec.layer_sizes[l + 1] as f64;
            let n_src = spec.layer_sizes[l] as f64;
            let k = spec.fanouts[l] as f64;
            let (d_in, d_out) = (dims[l] as f64, dims[l + 1] as f64);
            if arch == "gat" {
                // projection of all sources + per-slot attention work
                fwd += 2.0 * n_src * d_in * d_out; // z = x W
                fwd += n_dst * (k + 1.0) * d_out * 6.0; // scores+softmax+wsum
                launches += 12;
            } else {
                fwd += 2.0 * n_dst * d_in * d_out; // W_self
                fwd += 2.0 * n_dst * d_in * d_out; // W_nbr
                fwd += n_dst * k * d_in * 2.0; // masked mean agg
                launches += 8;
            }
        }
        // classifier head
        fwd += 2.0 * spec.batch as f64 * spec.hidden as f64 * spec.classes as f64;
        // backward ~= 2x forward; SGD+momentum ~= 4 ops/param
        let flops = fwd * 3.0 + spec.param_elems() as f64 * 4.0;
        // sampling examines each neighbor slot (+ bookkeeping folded into
        // the per-edge constant)
        let slots: u64 = (0..nl)
            .map(|l| (spec.layer_sizes[l + 1] * spec.fanouts[l]) as u64)
            .sum();
        ComputeModel {
            flops_per_step: flops,
            kernel_launches: launches,
            sample_slots_per_step: slots,
        }
    }

    /// Simulated GPU step time on `sys`.
    pub fn train_step_s(&self, sys: &SystemProfile) -> f64 {
        self.flops_per_step / (sys.gpu_fp32_flops * sys.gpu_efficiency)
            + self.kernel_launches as f64 * sys.kernel_launch_s
    }

    /// Simulated host sampling time per step on `sys`.
    pub fn sample_step_s(&self, sys: &SystemProfile) -> f64 {
        self.sample_slots_per_step as f64 * sys.sample_s_per_edge
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ArtifactKind, ArtifactSpec};

    fn spec(arch: &str) -> ArtifactSpec {
        ArtifactSpec {
            name: format!("{arch}_x"),
            file: "x.hlo.txt".into(),
            kind: ArtifactKind::Train,
            arch: Some(arch.into()),
            batch: 64,
            hidden: 64,
            in_dim: 100,
            classes: 47,
            fanouts: vec![5, 5],
            layer_sizes: vec![2304, 384, 64],
            lr: 0.003,
            momentum: 0.9,
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn flops_scale_with_arch_and_width() {
        let sage = ComputeModel::from_spec(&spec("sage"));
        assert!(sage.flops_per_step > 1e6);
        let mut wide = spec("sage");
        wide.in_dim = 800;
        let sage_w = ComputeModel::from_spec(&wide);
        assert!(sage_w.flops_per_step > 3.0 * sage.flops_per_step);
    }

    #[test]
    fn gat_heavier_than_sage_in_time() {
        // Paper §5.4: "GAT training is computationally heavier than
        // GraphSAGE" (per gathered byte), so PyD helps it less.
        let sys = SystemProfile::system1();
        let sage = ComputeModel::from_spec(&spec("sage"));
        let gat = ComputeModel::from_spec(&spec("gat"));
        assert!(gat.train_step_s(&sys) > 0.5 * sage.train_step_s(&sys));
        assert!(gat.kernel_launches > sage.kernel_launches);
    }

    #[test]
    fn sample_time_counts_all_slots() {
        let m = ComputeModel::from_spec(&spec("sage"));
        assert_eq!(m.sample_slots_per_step, (384 * 5 + 64 * 5) as u64);
        let sys = SystemProfile::system1();
        assert!(m.sample_step_s(&sys) > 0.0);
    }
}

//! Batched inference over the AOT `*_infer` artifacts (paper §4.1:
//! "PyTorch-Direct aims to enable GPU out-of-memory training *and
//! inference* for GNN").
//!
//! Reuses the training pipeline's sampler + feature store; the forward-only
//! artifact returns logits for the batch roots.  Reports per-batch latency
//! (measured PJRT + simulated transfer) and accuracy against the synthetic
//! labels — the serving-path counterpart of the Fig. 8 trainer.
//!
//! Backend selection mirrors the trainer: `--backend pjrt` requires the
//! `{arch}_{dataset}_infer` artifact, `--backend native` executes the
//! built-in softmax model over the gathered roots, and `auto` falls back
//! to native when the infer artifact is absent — so inference (and the
//! serving engine built on it) runs end-to-end in a container with no XLA
//! build.

use std::path::Path;

use crate::config::{Backend, RunConfig};
use crate::coordinator::costmodel::{ComputeModel, DEFAULT_HIDDEN};
use crate::coordinator::trainer::{Breakdown, PushdownReport};
use crate::error::{Error, Result};
use crate::featurestore::FeatureStore;
use crate::graph::{Csr, DatasetPreset};
use crate::runtime::client::{literal_f32, literal_i32};
use crate::runtime::native::{self, NativeTrainState};
use crate::runtime::{ArtifactKind, ArtifactSpec, LoadedArtifact, Manifest, Runtime};
use crate::sampler::NeighborSampler;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Inference run results.
#[derive(Clone, Debug, Default)]
pub struct InferenceReport {
    pub batches: u64,
    pub accuracy: f64,
    /// Measured execution latency per batch (seconds).
    pub exec_latency: Summary,
    /// Simulated end-to-end batch latency on the target system (sample +
    /// transfer + execute estimate).
    pub sim_latency: Summary,
    pub breakdown_sim: Breakdown,
    /// Aggregation push-down accounting (`--aggregate-pushdown`,
    /// DESIGN.md §14): raw vs pushed-down link bytes and the near-memory
    /// reduction work, accumulated over all batches.
    pub pushdown: PushdownReport,
}

/// Execution backend for the forward pass.
enum InferExec {
    Pjrt {
        artifact: LoadedArtifact,
        params: Vec<xla::Literal>,
    },
    Native(NativeTrainState),
}

/// Forward-only runner over the full data path.
pub struct InferenceRunner {
    cfg: RunConfig,
    preset: DatasetPreset,
    graph: Csr,
    store: FeatureStore,
    exec: InferExec,
    compute: ComputeModel,
    /// Rows the feature gather delivers per batch (= layer_sizes[0]).
    gather_rows: usize,
    classes: usize,
    rng: Rng,
}

/// Dims of a named param in the artifact's manifest inputs.  A manifest
/// whose param names don't match the train state (stale or hand-edited)
/// is a runtime error naming the missing param, not a panic.
fn param_dims(spec: &ArtifactSpec, name: &str) -> Result<Vec<usize>> {
    spec.params()
        .find(|p| p.name == name)
        .map(|p| p.dims.clone())
        .ok_or_else(|| {
            Error::Runtime(format!(
                "artifact {} has no param `{name}` among its manifest inputs \
                 (stale or hand-edited manifest; re-run `make artifacts`)",
                spec.name
            ))
        })
}

impl InferenceRunner {
    /// Build the stack; load `{arch}_{dataset}_infer` or fall back to the
    /// native forward model per the backend selection rules above.
    pub fn new(cfg: RunConfig) -> Result<InferenceRunner> {
        // Programmatic configs bypass the CLI's validation pass; reject
        // impossible shapes (e.g. empty `fanouts`) before the sampler
        // can panic on them.
        cfg.validate()?;
        let mut preset = DatasetPreset::by_abbv(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset `{}`", cfg.dataset)))?;
        crate::coordinator::trainer::apply_classes_override(&cfg, &mut preset);
        let scale = preset.scale_for_budget(cfg.scale, cfg.feature_budget);
        let graph = preset.build_graph(scale, cfg.seed)?;
        // Shares the trainer's store construction so `Tiered` inference
        // gets the same degree-ranked hot set and capacity knobs.
        let store = crate::coordinator::trainer::build_store(&cfg, &graph, &preset)?;

        let infer_name = format!("{}_infer", cfg.artifact_name());
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir));
        let use_pjrt = match cfg.backend {
            Backend::Pjrt => true,
            Backend::Native => false,
            Backend::Auto => manifest
                .as_ref()
                .map(|m| m.get(&infer_name).is_ok())
                .unwrap_or(false),
        };

        let (exec, compute, gather_rows) = if use_pjrt {
            let manifest = manifest?;
            let spec = manifest.get(&infer_name)?;
            if spec.kind != ArtifactKind::Infer {
                return Err(Error::Runtime(format!(
                    "{} is not an infer artifact",
                    spec.name
                )));
            }
            crate::coordinator::trainer::check_artifact_classes(&cfg, spec, preset.classes)?;
            let runtime = Runtime::cpu()?;
            let artifact = runtime.load(Path::new(&cfg.artifacts_dir), spec)?;
            // Glorot params (a real deployment would load a checkpoint; the
            // serving *path* — gather, transfer, execute — is what we exercise).
            let state = crate::runtime::TrainState::init(spec, cfg.seed ^ 0x9A23)?;
            let params = state
                .param_names()
                .iter()
                .map(|n| {
                    let vals = state.param_values(n)?;
                    literal_f32(&vals, &param_dims(spec, n)?)
                })
                .collect::<Result<Vec<_>>>()?;
            let compute = ComputeModel::from_spec(spec);
            let gather_rows = spec.layer_sizes[0];
            (InferExec::Pjrt { artifact, params }, compute, gather_rows)
        } else {
            log::info!(
                "backend: native forward model (softmax over roots) — no AOT \
                 artifacts needed"
            );
            let mut state = NativeTrainState::init(
                preset.feat_dim as usize,
                preset.classes,
                native::DEFAULT_LR,
                cfg.seed ^ 0x9A23,
            );
            state.set_workers(cfg.sampler_workers.max(1));
            let compute = ComputeModel::from_shape(
                &cfg.arch,
                cfg.batch,
                &cfg.fanouts,
                preset.feat_dim as usize,
                DEFAULT_HIDDEN,
                preset.classes as usize,
            );
            let gather_rows = ComputeModel::layer_sizes_for(cfg.batch, &cfg.fanouts)[0];
            (InferExec::Native(state), compute, gather_rows)
        };

        let classes = preset.classes as usize;
        let rng = Rng::new(cfg.seed);
        Ok(InferenceRunner {
            cfg,
            preset,
            graph,
            store,
            exec,
            compute,
            gather_rows,
            classes,
            rng,
        })
    }

    /// Serve `n_batches` sampled batches; returns latency + accuracy stats.
    pub fn run(&mut self, n_batches: u64) -> Result<InferenceReport> {
        let sampler = NeighborSampler::new(&self.graph, &self.cfg.fanouts, self.preset.classes);
        let mut rng = self.rng.fork(1);
        let mut report = InferenceReport::default();
        report.pushdown.enabled = self.cfg.aggregate_pushdown;
        let mut correct = 0u64;
        let mut total = 0u64;
        let n_nodes = self.graph.num_nodes();
        let dim = self.store.dim();
        let mut x0 = vec![0f32; self.gather_rows * dim];
        let sim_fwd = self.compute.train_step_s(&self.cfg.system) / 3.0;

        for b in 0..n_batches {
            let seeds: Vec<u32> = (0..self.cfg.batch)
                .map(|k| ((b as usize * self.cfg.batch + k) % n_nodes) as u32)
                .collect();
            let mb = sampler.sample(&seeds, &mut rng);
            // Push-down prices the batch before the physical gather
            // mutates tier state (read-only, pre-batch classification —
            // the trainer's ordering, DESIGN.md §14).
            let pd = if self.cfg.aggregate_pushdown {
                let plan = crate::sampler::AggregatePlan::build(&mb)?;
                Some(self.store.pushdown_cost(&plan, self.cfg.dedup)?)
            } else {
                None
            };
            // Serving uses the same dedup plan as training: fetch each
            // distinct row once, scatter back (bitwise-identical x0).
            let raw_cost = if self.cfg.dedup {
                self.store.gather_planned(&mb.compact(), &mut x0)?
            } else {
                self.store.gather_into(&mb.src_nodes, &mut x0)?
            };
            // Pushed-down batches pay the pushed cost; the raw costing
            // rides along for the reduction factor.
            let cost = match pd {
                Some(p) => {
                    let r = &mut report.pushdown;
                    r.raw_bytes_on_link += raw_cost.bytes_on_link;
                    r.pushed_bytes_on_link += p.cost.bytes_on_link;
                    r.agg_bytes_on_link += p.agg_bytes_on_link;
                    r.dst_rows += p.dst_rows;
                    r.neighbor_rows += p.neighbor_rows;
                    r.agg_rows += p.agg_rows;
                    r.near_mem_flops += p.near_mem_flops;
                    r.near_mem_s += p.near_mem_s;
                    p.cost
                }
                None => raw_cost,
            };

            let t_exec = Timer::start();
            match &self.exec {
                InferExec::Pjrt { artifact, params } => {
                    let spec = &artifact.spec;
                    // assemble literals: params, x0, nbrs, masks
                    let x0_lit = literal_f32(&x0, &[spec.layer_sizes[0], spec.in_dim])?;
                    let mut nbr_lits = Vec::new();
                    let mut mask_lits = Vec::new();
                    for (l, layer) in mb.layers.iter().enumerate() {
                        let dims = [spec.layer_sizes[l + 1], spec.fanouts[l]];
                        nbr_lits.push(literal_i32(&layer.nbr, &dims)?);
                        mask_lits.push(literal_f32(&layer.mask, &dims)?);
                    }
                    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
                    inputs.push(&x0_lit);
                    inputs.extend(nbr_lits.iter());
                    inputs.extend(mask_lits.iter());

                    let outs = artifact.execute(&inputs)?;
                    let logits = outs[0].to_vec::<f32>()?;
                    for (i, &label) in mb.labels.iter().enumerate() {
                        let row = &logits[i * spec.classes..(i + 1) * spec.classes];
                        // total_cmp: NaN logits order last instead of panicking
                        let argmax = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(j, _)| j as i32)
                            .unwrap();
                        if argmax == label {
                            correct += 1;
                        }
                        total += 1;
                    }
                }
                InferExec::Native(state) => {
                    // dst-prefix convention: the batch roots are the first
                    // `labels.len()` rows of the gathered block
                    let mut logits = vec![0f32; self.classes];
                    for (i, &label) in mb.labels.iter().enumerate() {
                        state.logits_into(&x0[i * dim..(i + 1) * dim], &mut logits);
                        let argmax = logits
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(j, _)| j as i32)
                            .unwrap();
                        if argmax == label {
                            correct += 1;
                        }
                        total += 1;
                    }
                }
            }
            report.exec_latency.add(t_exec.elapsed_s());

            // simulated per-batch latency on the target system: sampling
            // estimate + transfer model + forward-only GPU estimate (the
            // fused train step is fwd + ~2x fwd bwd + update, so fwd ≈ 1/3)
            let sim_sample = mb
                .layers
                .iter()
                .map(|l| (l.n_dst * l.fanout) as f64)
                .sum::<f64>()
                * self.cfg.system.sample_s_per_edge;
            report.breakdown_sim.sample_s += sim_sample;
            report.breakdown_sim.transfer_s += cost.time_s;
            report.breakdown_sim.train_s += sim_fwd;
            report.sim_latency.add(sim_sample + cost.time_s + sim_fwd);
            report.batches += 1;
        }
        report.accuracy = correct as f64 / total.max(1) as f64;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ArtifactKind, IoRole, IoSpec};
    use crate::tensor::DType;

    fn spec_with_params(names: &[&str]) -> ArtifactSpec {
        ArtifactSpec {
            name: "sage_x_infer".into(),
            file: "x.hlo.txt".into(),
            kind: ArtifactKind::Infer,
            arch: Some("sage".into()),
            batch: 4,
            hidden: 8,
            in_dim: 16,
            classes: 3,
            fanouts: vec![2],
            layer_sizes: vec![12, 4],
            lr: 0.003,
            momentum: 0.9,
            inputs: names
                .iter()
                .map(|n| IoSpec {
                    role: IoRole::Param,
                    name: (*n).into(),
                    dtype: DType::F32,
                    dims: vec![16, 8],
                })
                .collect(),
            outputs: vec![],
        }
    }

    #[test]
    fn param_dims_finds_present_param() {
        let spec = spec_with_params(&["l0_w_self", "l0_w_nbr"]);
        assert_eq!(param_dims(&spec, "l0_w_nbr").unwrap(), vec![16, 8]);
    }

    #[test]
    fn missing_param_is_clear_error_not_panic() {
        // a hand-edited manifest whose param names drifted from the train
        // state must produce Error::Runtime naming the missing param
        let spec = spec_with_params(&["l0_w_self"]);
        let err = param_dims(&spec, "head_w").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("head_w"), "{msg}");
        assert!(msg.contains("sage_x_infer"), "{msg}");
    }
}

//! Batched inference over the AOT `*_infer` artifacts (paper §4.1:
//! "PyTorch-Direct aims to enable GPU out-of-memory training *and
//! inference* for GNN").
//!
//! Reuses the training pipeline's sampler + feature store; the forward-only
//! artifact returns logits for the batch roots.  Reports per-batch latency
//! (measured PJRT + simulated transfer) and accuracy against the synthetic
//! labels — the serving-path counterpart of the Fig. 8 trainer.

use std::path::Path;

use crate::config::RunConfig;
use crate::coordinator::trainer::Breakdown;
use crate::error::{Error, Result};
use crate::featurestore::FeatureStore;
use crate::graph::{Csr, DatasetPreset};
use crate::runtime::client::{literal_f32, literal_i32};
use crate::runtime::{ArtifactKind, LoadedArtifact, Manifest, Runtime};
use crate::sampler::NeighborSampler;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Inference run results.
#[derive(Clone, Debug, Default)]
pub struct InferenceReport {
    pub batches: u64,
    pub accuracy: f64,
    /// Measured PJRT execution latency per batch (seconds).
    pub exec_latency: Summary,
    /// Simulated end-to-end batch latency on the target system (sample +
    /// transfer + execute estimate).
    pub sim_latency: Summary,
    pub breakdown_sim: Breakdown,
}

/// Forward-only runner over the full data path.
pub struct InferenceRunner {
    cfg: RunConfig,
    preset: DatasetPreset,
    graph: Csr,
    store: FeatureStore,
    artifact: LoadedArtifact,
    params: Vec<xla::Literal>,
    rng: Rng,
}

impl InferenceRunner {
    /// Build the stack and load `{arch}_{dataset}_infer`.
    pub fn new(cfg: RunConfig) -> Result<InferenceRunner> {
        let mut preset = DatasetPreset::by_abbv(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset `{}`", cfg.dataset)))?;
        crate::coordinator::trainer::apply_classes_override(&cfg, &mut preset);
        let scale = preset.scale_for_budget(cfg.scale, cfg.feature_budget);
        let graph = preset.build_graph(scale, cfg.seed)?;
        // Shares the trainer's store construction so `Tiered` inference
        // gets the same degree-ranked hot set and capacity knobs.
        let store = crate::coordinator::trainer::build_store(&cfg, &graph, &preset)?;
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
        let spec = manifest.get(&format!("{}_infer", cfg.artifact_name()))?;
        if spec.kind != ArtifactKind::Infer {
            return Err(Error::Runtime(format!("{} is not an infer artifact", spec.name)));
        }
        crate::coordinator::trainer::check_artifact_classes(&cfg, spec, preset.classes)?;
        let runtime = Runtime::cpu()?;
        let artifact = runtime.load(Path::new(&cfg.artifacts_dir), spec)?;
        // Glorot params (a real deployment would load a checkpoint; the
        // serving *path* — gather, transfer, execute — is what we exercise).
        let state = crate::runtime::TrainState::init(spec, cfg.seed ^ 0x9A23)?;
        let params = state
            .param_names()
            .iter()
            .map(|n| {
                let vals = state.param_values(n)?;
                let dims: Vec<usize> = spec
                    .params()
                    .find(|p| &p.name == n)
                    .map(|p| p.dims.clone())
                    .unwrap();
                literal_f32(&vals, &dims)
            })
            .collect::<Result<Vec<_>>>()?;
        let rng = Rng::new(cfg.seed);
        Ok(InferenceRunner {
            cfg,
            preset,
            graph,
            store,
            artifact,
            params,
            rng,
        })
    }

    /// Serve `n_batches` sampled batches; returns latency + accuracy stats.
    pub fn run(&mut self, n_batches: u64) -> Result<InferenceReport> {
        let spec = &self.artifact.spec;
        let sampler = NeighborSampler::new(&self.graph, &self.cfg.fanouts, self.preset.classes);
        let mut rng = self.rng.fork(1);
        let mut report = InferenceReport::default();
        let mut correct = 0u64;
        let mut total = 0u64;
        let n_nodes = self.graph.num_nodes();
        let dim = self.store.dim();
        let mut x0 = vec![0f32; spec.layer_sizes[0] * dim];

        for b in 0..n_batches {
            let seeds: Vec<u32> = (0..self.cfg.batch)
                .map(|k| ((b as usize * self.cfg.batch + k) % n_nodes) as u32)
                .collect();
            let mb = sampler.sample(&seeds, &mut rng);
            // Serving uses the same dedup plan as training: fetch each
            // distinct row once, scatter back (bitwise-identical x0).
            let cost = if self.cfg.dedup {
                self.store.gather_planned(&mb.compact(), &mut x0)?
            } else {
                self.store.gather_into(&mb.src_nodes, &mut x0)?
            };

            // assemble literals: params, x0, nbrs, masks
            let x0_lit = literal_f32(&x0, &[spec.layer_sizes[0], spec.in_dim])?;
            let mut nbr_lits = Vec::new();
            let mut mask_lits = Vec::new();
            for (l, layer) in mb.layers.iter().enumerate() {
                let dims = [spec.layer_sizes[l + 1], spec.fanouts[l]];
                nbr_lits.push(literal_i32(&layer.nbr, &dims)?);
                mask_lits.push(literal_f32(&layer.mask, &dims)?);
            }
            let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
            inputs.push(&x0_lit);
            inputs.extend(nbr_lits.iter());
            inputs.extend(mask_lits.iter());

            let t_exec = Timer::start();
            let outs = self.artifact.execute(&inputs)?;
            let exec_s = t_exec.elapsed_s();
            report.exec_latency.add(exec_s);

            let logits = outs[0].to_vec::<f32>()?;
            for (i, &label) in mb.labels.iter().enumerate() {
                let row = &logits[i * spec.classes..(i + 1) * spec.classes];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap();
                if argmax == label {
                    correct += 1;
                }
                total += 1;
            }

            // simulated per-batch latency on the target system: sampling
            // estimate + transfer model + forward-only GPU estimate (the
            // fused train step is fwd + ~2x fwd bwd + update, so fwd ≈ 1/3)
            let sim_sample = mb
                .layers
                .iter()
                .map(|l| (l.n_dst * l.fanout) as f64)
                .sum::<f64>()
                * self.cfg.system.sample_s_per_edge;
            let sim_fwd =
                crate::coordinator::costmodel::ComputeModel::from_spec(spec)
                    .train_step_s(&self.cfg.system)
                    / 3.0;
            report.breakdown_sim.sample_s += sim_sample;
            report.breakdown_sim.transfer_s += cost.time_s;
            report.breakdown_sim.train_s += sim_fwd;
            report.sim_latency.add(sim_sample + cost.time_s + sim_fwd);
            report.batches += 1;
        }
        report.accuracy = correct as f64 / total.max(1) as f64;
        Ok(report)
    }
}

//! The training coordinator: epoch orchestration, simulated-testbed cost
//! models, the discrete-event overlap engine (DESIGN.md §9), the power
//! model (Fig. 9), microbenchmark drivers (Figs. 6/7), and
//! table-formatted reporting.

pub mod costmodel;
pub mod inference;
pub mod microbench;
pub mod power;
pub mod report;
pub mod schedule;
pub mod serving;
pub mod simclock;
pub mod trainer;

pub use costmodel::ComputeModel;
pub use inference::{InferenceReport, InferenceRunner};
pub use power::{epoch_power, PowerReport};
pub use report::Table;
pub use schedule::{schedule_epoch, OverlapParams, OverlapReport};
pub use serving::{ServingEngine, ServingReport};
pub use simclock::{ResourceBusy, ResourceKind, SimResource};
pub use trainer::{Breakdown, DedupReport, EpochReport, Trainer};

//! The end-to-end trainer: graph -> sampler -> feature store -> PJRT step.
//!
//! Every epoch produces two time breakdowns (DESIGN.md §5):
//!
//! * **simulated** — the paper-testbed estimate: sampling and training via
//!   [`ComputeModel`], feature copy via the interconnect models.  This is
//!   what the Fig. 8 bench compares across access modes.
//! * **measured** — real wall-clock on this machine (sampling, gather
//!   memcpys, PJRT execution).  This is the end-to-end integration signal
//!   (the loss curve is real learning through the AOT artifacts).

use std::path::Path;

use crate::config::{AccessMode, RunConfig};
use crate::coordinator::costmodel::ComputeModel;
use crate::coordinator::power::{epoch_power, PowerReport};
use crate::error::{Error, Result};
use crate::featurestore::FeatureStore;
use crate::graph::{Csr, DatasetPreset};
use crate::runtime::state::{StepBatch, TrainState};
use crate::runtime::{ArtifactKind, LoadedArtifact, Manifest, Runtime};
use crate::sampler::NeighborSampler;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Epoch time breakdown (the stacked bars of paper Fig. 8).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    /// Neighbor sampling + subgraph construction.
    pub sample_s: f64,
    /// Feature gather + host->device transfer ("Feature Copy").
    pub transfer_s: f64,
    /// Forward/backward/update ("Training").
    pub train_s: f64,
    /// Everything else (batch assembly, bookkeeping).
    pub other_s: f64,
}

impl Breakdown {
    pub fn total_s(&self) -> f64 {
        self.sample_s + self.transfer_s + self.train_s + self.other_s
    }
}

/// One epoch's results.
#[derive(Clone, Debug, Default)]
pub struct EpochReport {
    pub steps: u64,
    pub breakdown_sim: Breakdown,
    pub breakdown_measured: Breakdown,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    pub bytes_on_link: u64,
    pub requests: u64,
    /// CPU seconds the transfer path consumed (simulated testbed).
    pub cpu_gather_s: f64,
    pub power: PowerReport,
}

impl EpochReport {
    pub fn mean_loss(&self) -> f32 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.losses.iter().sum::<f32>() / self.losses.len() as f32
    }

    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(0.0)
    }
}

/// End-to-end trainer over one (dataset, arch, mode, system) configuration.
pub struct Trainer {
    pub cfg: RunConfig,
    pub preset: DatasetPreset,
    pub scale: u32,
    graph: Csr,
    store: FeatureStore,
    compute: Option<ComputeModel>,
    artifact: Option<LoadedArtifact>,
    state: Option<TrainState>,
    rng: Rng,
}

impl Trainer {
    /// Build the full stack.  When `cfg.skip_train` is set the PJRT runtime
    /// is not loaded (pipeline/transfer accounting only — used by benches
    /// that sweep all 12 variants without paying 12 compilations).
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let preset = DatasetPreset::by_abbv(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset `{}`", cfg.dataset)))?;
        let scale = preset.scale_for_budget(cfg.scale, cfg.feature_budget);
        if scale != cfg.scale {
            log::info!(
                "dataset {}: scale raised {} -> {} to fit feature budget",
                preset.abbv,
                cfg.scale,
                scale
            );
        }
        let t = Timer::start();
        let graph = preset.build_graph(scale, cfg.seed)?;
        log::info!(
            "graph {}: {} nodes, {} edges (scale 1/{scale}) in {:.2}s",
            preset.abbv,
            graph.num_nodes(),
            graph.num_edges(),
            t.elapsed_s()
        );
        let store = FeatureStore::build(
            graph.num_nodes(),
            preset.feat_dim as usize,
            preset.classes,
            cfg.mode,
            &cfg.system,
            cfg.seed ^ 0xFEA7,
        )?;

        let (artifact, state, compute) = if cfg.skip_train {
            // No PJRT, but still read the manifest (when present) so the
            // simulated train/sample estimates use the artifact's true
            // shapes — benches sweep all variants without 12 compilations.
            let compute = Manifest::load(Path::new(&cfg.artifacts_dir))
                .ok()
                .and_then(|m| m.get(&cfg.artifact_name()).ok().cloned())
                .map(|spec| ComputeModel::from_spec(&spec));
            (None, None, compute)
        } else {
            let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
            let spec = manifest.get(&cfg.artifact_name())?;
            if spec.kind != ArtifactKind::Train {
                return Err(Error::Runtime(format!("{} is not a train artifact", spec.name)));
            }
            if spec.batch != cfg.batch || spec.fanouts != cfg.fanouts {
                return Err(Error::Config(format!(
                    "artifact {} built for batch {} fanouts {:?}; run config has {} {:?} \
                     (re-run `make artifacts` with matching flags)",
                    spec.name, spec.batch, spec.fanouts, cfg.batch, cfg.fanouts
                )));
            }
            if spec.in_dim != preset.feat_dim as usize {
                return Err(Error::Config(format!(
                    "artifact in_dim {} != dataset feat dim {}",
                    spec.in_dim, preset.feat_dim
                )));
            }
            let runtime = Runtime::cpu()?;
            let loaded = runtime.load(Path::new(&cfg.artifacts_dir), spec)?;
            let state = TrainState::init(spec, cfg.seed ^ 0x9A23)?;
            let compute = ComputeModel::from_spec(spec);
            (Some(loaded), Some(state), Some(compute))
        };

        let rng = Rng::new(cfg.seed);
        Ok(Trainer {
            cfg,
            preset,
            scale,
            graph,
            store,
            compute,
            artifact,
            state,
            rng,
        })
    }

    pub fn graph(&self) -> &Csr {
        &self.graph
    }

    pub fn store(&self) -> &FeatureStore {
        &self.store
    }

    /// Compute model (None when skip_train and no artifact was loaded).
    pub fn compute_model(&self) -> Option<&ComputeModel> {
        self.compute.as_ref()
    }

    /// Steps one epoch would run at full size.
    pub fn steps_per_epoch(&self) -> u64 {
        let by_graph = (self.graph.num_nodes() / self.cfg.batch) as u64;
        if self.cfg.steps_per_epoch > 0 {
            by_graph.min(self.cfg.steps_per_epoch as u64)
        } else {
            by_graph
        }
    }

    /// Run one training epoch.
    pub fn run_epoch(&mut self) -> Result<EpochReport> {
        let sampler = NeighborSampler::new(&self.graph, &self.cfg.fanouts, self.preset.classes);
        let mut rng = self.rng.fork(self.state.as_ref().map(|s| s.steps).unwrap_or(0));
        let seeds_all = sampler.epoch_seeds(self.cfg.batch, &mut rng);
        let max_steps = self.steps_per_epoch() as usize;

        let mut report = EpochReport::default();
        let dim = self.store.dim();
        let mut x0 = vec![0f32; 0];

        for seeds in seeds_all.into_iter().take(max_steps) {
            // --- sample (measured) ---
            let t = Timer::start();
            let mb = sampler.sample(&seeds, &mut rng);
            report.breakdown_measured.sample_s += t.elapsed_s();
            debug_assert!(mb.validate().is_ok());

            // --- gather + transfer ---
            let rows = mb.gather_rows();
            x0.resize(rows * dim, 0.0);
            let t = Timer::start();
            let cost = self.store.gather_into(&mb.src_nodes, &mut x0)?;
            report.breakdown_measured.transfer_s += t.elapsed_s();
            report.breakdown_sim.transfer_s += cost.time_s;
            report.cpu_gather_s += cost.cpu_time_s;
            report.bytes_on_link += cost.bytes_on_link;
            report.requests += cost.requests;

            // --- train (measured through PJRT; simulated via FLOP model) ---
            if let (Some(artifact), Some(state)) = (self.artifact.as_ref(), self.state.as_mut()) {
                let t = Timer::start();
                let batch = StepBatch {
                    x0: x0.clone(),
                    nbrs: mb.layers.iter().map(|l| l.nbr.clone()).collect(),
                    masks: mb.layers.iter().map(|l| l.mask.clone()).collect(),
                    labels: mb.labels.clone(),
                };
                let assemble_s = t.elapsed_s();
                report.breakdown_measured.other_s += assemble_s;
                let metrics = state.step(artifact, &batch)?;
                report.breakdown_measured.train_s += metrics.exec_s;
                report.losses.push(metrics.loss);
                report.accs.push(metrics.acc);
            }
            report.steps += 1;
        }

        // --- simulated-testbed sampling + training ---
        if let Some(cm) = &self.compute {
            report.breakdown_sim.sample_s = cm.sample_step_s(&self.cfg.system) * report.steps as f64;
            report.breakdown_sim.train_s = cm.train_step_s(&self.cfg.system) * report.steps as f64;
        } else {
            // skip_train: estimate from the sampler shape directly
            let slots: u64 = self
                .cfg
                .fanouts
                .iter()
                .rev()
                .scan(self.cfg.batch, |n_dst, &f| {
                    let s = (*n_dst * f) as u64;
                    *n_dst *= 1 + f;
                    Some(s)
                })
                .sum();
            report.breakdown_sim.sample_s =
                slots as f64 * self.cfg.system.sample_s_per_edge * report.steps as f64;
        }
        report.breakdown_sim.other_s = 0.02 * report.breakdown_sim.total_s();

        report.power = epoch_power(
            &self.cfg.system,
            &report.breakdown_sim,
            report.cpu_gather_s,
            report.bytes_on_link,
        );
        Ok(report)
    }

    /// Switch access mode in place (rebuilds the feature store only).
    pub fn set_mode(&mut self, mode: AccessMode) -> Result<()> {
        if mode == self.cfg.mode {
            return Ok(());
        }
        self.cfg.mode = mode;
        self.store = FeatureStore::build(
            self.graph.num_nodes(),
            self.preset.feat_dim as usize,
            self.preset.classes,
            mode,
            &self.cfg.system,
            self.cfg.seed ^ 0xFEA7,
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: AccessMode) -> RunConfig {
        RunConfig {
            dataset: "product".into(),
            mode,
            scale: 2048,
            feature_budget: 8 << 20,
            steps_per_epoch: 3,
            skip_train: true, // unit tests stay PJRT-free; integration covers it
            ..RunConfig::default()
        }
    }

    #[test]
    fn epoch_accounting_pyd_beats_py() {
        let mut t = Trainer::new(small_cfg(AccessMode::CpuGather)).unwrap();
        let py = t.run_epoch().unwrap();
        t.set_mode(AccessMode::UnifiedAligned).unwrap();
        let pyd = t.run_epoch().unwrap();
        assert_eq!(py.steps, 3);
        assert!(py.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
        assert!(py.cpu_gather_s > 0.0);
        assert_eq!(pyd.cpu_gather_s, 0.0);
    }

    #[test]
    fn measured_side_really_moves_bytes() {
        let mut t = Trainer::new(small_cfg(AccessMode::UnifiedAligned)).unwrap();
        let r = t.run_epoch().unwrap();
        assert!(r.breakdown_measured.sample_s > 0.0);
        assert!(r.breakdown_measured.transfer_s > 0.0);
        assert!(r.bytes_on_link > 0);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut cfg = small_cfg(AccessMode::CpuGather);
        cfg.dataset = "imagenet".into();
        assert!(Trainer::new(cfg).is_err());
    }
}
